"""Build-time training for the AOT artifacts.

Runs inside `make artifacts` only — python (and everything in this file) is
never on the request path. Training uses the pure-jnp oracle paths; the
exported artifacts use the Pallas kernel paths. The kernel tests assert the
two paths agree, so the weights transfer exactly.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, model


def _adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": 0}


def _adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def train_lm(steps=300, batch=16, seed=0, log_every=50):
    """Train TinyLM on the embedded corpus; returns (params, log).

    log is a list of (step, loss) pairs — the loss curve recorded in
    EXPERIMENTS.md per the end-to-end-validation requirement.
    """
    corpus = np.frombuffer(data.CORPUS.encode("utf-8"), dtype=np.uint8)
    corpus = corpus.astype(np.int32)
    rng = np.random.default_rng(seed)
    params = model.init_lm_params(jax.random.PRNGKey(seed))

    loss_grad = jax.jit(jax.value_and_grad(model.lm_loss))
    opt = _adam_init(params)
    step_fn = jax.jit(_adam_step)

    log = []
    t0 = time.time()
    for step in range(steps):
        starts = rng.integers(0, len(corpus) - model.SEQ_LEN - 1, size=batch)
        toks = np.stack([corpus[s:s + model.SEQ_LEN + 1] for s in starts])
        loss, grads = loss_grad(params, jnp.asarray(toks))
        params, opt = step_fn(params, grads, opt)
        if step % log_every == 0 or step == steps - 1:
            log.append((step, float(loss)))
            print(f"  lm step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    return params, log


def train_classifier(steps=400, batch=64, seed=0, log_every=100):
    """Train the MIST Stage-2 classifier; returns (params, train_acc, val_acc)."""
    texts, labels = data.classifier_dataset(seed=seed)
    feats = np.stack([model.featurize(t) for t in texts])
    n_val = len(texts) // 5
    f_tr, y_tr = feats[n_val:], labels[n_val:]
    f_va, y_va = feats[:n_val], labels[:n_val]

    params = model.init_classifier_params(jax.random.PRNGKey(seed + 1))
    loss_grad = jax.jit(jax.value_and_grad(model.classifier_loss))
    opt = _adam_init(params)
    step_fn = jax.jit(_adam_step)
    rng = np.random.default_rng(seed)

    for step in range(steps):
        idx = rng.integers(0, len(f_tr), size=batch)
        loss, grads = loss_grad(params, jnp.asarray(f_tr[idx]),
                                jnp.asarray(y_tr[idx]))
        params, opt = step_fn(params, grads, opt, 3e-3)
        if step % log_every == 0:
            print(f"  clf step {step:4d} loss {float(loss):.4f}")

    def acc(f, y):
        logits = model.classifier_forward(params, jnp.asarray(f))
        return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())

    return params, acc(f_tr, y_tr), acc(f_va, y_va)
