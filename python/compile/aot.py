"""AOT compile path: train, lower to HLO *text*, write artifacts/.

Run via `make artifacts` (no-op if artifacts are newer than the python
sources). Emits, into artifacts/:

  lm_b{1,4,8}.hlo.txt     TinyLM forward (weights baked as constants), one
                          executable per dynamic-batcher batch variant:
                          tokens [B, 64] i32 -> logits [B, 64, 256] f32
  classifier.hlo.txt      MIST Stage-2: feats [8, 512] f32 -> logits [8, 4]
  embedder.hlo.txt        feats [8, 512] f32 -> unit embeddings [8, 64]
  meta.json               dims, featurizer config, train metrics, loss curve,
                          golden featurizer/classifier vectors for the rust
                          cross-language tests

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` crate) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model, train

LM_BATCH_VARIANTS = (1, 4, 8)
CLS_BATCH = 8


def to_hlo_text(lowered) -> str:
    """Lower a jax .lower() result to XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    # Guard: without print_large_constants the printer elides weights as
    # `constant({...})`, which parses but executes as zeros on the rust side.
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def export_lm(lm_params, out_dir, use_pallas=True):
    paths = {}
    for b in LM_BATCH_VARIANTS:
        spec = jax.ShapeDtypeStruct((b, model.SEQ_LEN), jnp.int32)
        fn = lambda toks: (model.lm_forward(lm_params, toks,
                                            use_pallas=use_pallas),)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        path = os.path.join(out_dir, f"lm_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        paths[f"lm_b{b}"] = os.path.basename(path)
        print(f"  wrote {path} ({len(text)} chars)")
    return paths


def export_classifier(cls_params, out_dir, use_pallas=True):
    spec = jax.ShapeDtypeStruct((CLS_BATCH, model.FEAT_DIM), jnp.float32)
    fn = lambda feats: (model.classifier_forward(cls_params, feats,
                                                 use_pallas=use_pallas),)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    path = os.path.join(out_dir, "classifier.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def export_embedder(emb_params, out_dir):
    spec = jax.ShapeDtypeStruct((CLS_BATCH, model.FEAT_DIM), jnp.float32)
    fn = lambda feats: (model.embedder_forward(emb_params, feats),)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    path = os.path.join(out_dir, "embedder.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def golden_vectors(cls_params, emb_params):
    """Golden cross-language test vectors pinned by rust unit tests."""
    texts = [
        "patient john doe ssn 123-45-6789 diagnosed with diabetes",
        "what is the capital of france",
        "draft the agenda for the platform team standup",
    ]
    out = []
    for t in texts:
        f = model.featurize(t)
        logits = np.asarray(model.classifier_forward(
            cls_params, jnp.asarray(f[None, :])))[0]
        emb = np.asarray(model.embedder_forward(
            emb_params, jnp.asarray(f[None, :])))[0]
        nz = np.nonzero(f)[0][:8]
        out.append({
            "text": t,
            "feat_nonzero_idx": [int(i) for i in nz],
            "feat_nonzero_val": [round(float(f[i]), 6) for i in nz],
            "feat_l2": round(float(np.linalg.norm(f)), 6),
            "class_argmax": int(np.argmax(logits)),
            "emb_head": [round(float(x), 6) for x in emb[:4]],
        })
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--lm-steps", type=int, default=300)
    ap.add_argument("--clf-steps", type=int, default=400)
    ap.add_argument("--fast", action="store_true",
                    help="tiny step counts (CI smoke)")
    args = ap.parse_args()
    if args.fast:
        args.lm_steps, args.clf_steps = 20, 50

    os.makedirs(args.out, exist_ok=True)

    print("[1/5] training TinyLM on embedded corpus")
    lm_params, lm_log = train.train_lm(steps=args.lm_steps)
    print("[2/5] training MIST Stage-2 classifier")
    cls_params, tr_acc, va_acc = train.train_classifier(steps=args.clf_steps)
    print(f"  classifier acc train={tr_acc:.3f} val={va_acc:.3f}")
    emb_params = model.init_embedder_params(jax.random.PRNGKey(7))

    print("[3/5] exporting TinyLM HLO (pallas kernel path)")
    export_lm(lm_params, args.out)
    print("[4/5] exporting classifier + embedder HLO")
    export_classifier(cls_params, args.out)
    export_embedder(emb_params, args.out)

    print("[5/5] writing meta.json")
    meta = {
        "vocab": model.VOCAB,
        "seq_len": model.SEQ_LEN,
        "d_model": model.D_MODEL,
        "n_heads": model.N_HEADS,
        "n_layers": model.N_LAYERS,
        "feat_dim": model.FEAT_DIM,
        "ngram_sizes": list(model.NGRAM_SIZES),
        "n_classes": model.N_CLASSES,
        "embed_dim": model.EMBED_DIM,
        "lm_batch_variants": list(LM_BATCH_VARIANTS),
        "cls_batch": CLS_BATCH,
        "class_sensitivity": [0.2, 0.5, 0.8, 1.0],
        "lm_loss_curve": [[s, round(l, 4)] for s, l in lm_log],
        "classifier_train_acc": round(tr_acc, 4),
        "classifier_val_acc": round(va_acc, 4),
        "golden": golden_vectors(cls_params, emb_params),
    }
    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("artifacts complete")


if __name__ == "__main__":
    main()
