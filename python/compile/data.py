"""Training data for the AOT artifacts.

Everything here is synthetic/embedded so `make artifacts` is hermetic:
  - CORPUS: a tiny character-level corpus for TinyLM (themed on the paper's
    domains: islands/orchestration, healthcare, legal, code).
  - Classifier templates: generate labeled sensitivity examples matching the
    paper's four MIST Stage-2 classes (public 0.2 / internal 0.5 /
    confidential 0.8 / restricted 1.0).

The substitution "production workloads -> synthetic templates" is recorded in
DESIGN.md §2: the paper's routing behavior depends on the *score* MIST
assigns, not on the linguistic richness of the inputs.
"""

import numpy as np

CORPUS = """
The islands form an archipelago across the network ocean. Waves carry each
request from shore to horizon and back again. The lighthouse watches every
island and keeps the mesh alive with steady heartbeats. Mist settles over
the channel when data must cross a trust boundary, hiding names and places
while the shape of the conversation survives.

A request arrives at the shore. The router asks: how sensitive is this, how
much will it cost, how long will it take, and which islands can be trusted
with it? Privacy is not negotiable; the system fails closed rather than
leaking a secret to a distant cloud. Free local compute is spent before a
single paid token crosses the horizon.

The patient presented with elevated glucose and a history of hypertension.
The physician reviewed treatment options and adjusted the dosage. General
health advice: stay hydrated, sleep well, and exercise regularly. Common
complications of diabetes include neuropathy and retinopathy.

The firm holds ten terabytes of case law on its private server. Counsel
queries the index where the embeddings already live; the documents never
leave the building. Attorney and client speak under privilege, and the
router honors it.

fn route(request) { let score = waves.score(request); islands.filter(ok)
.min_by(score) } // compute to data, not data to compute. The scheduler
queues primary work locally, spills secondary work to the edge, and lets
burstable work ride the cloud when capacity runs low.
""".strip()


# (template, label) — label indexes {0: public, 1: internal, 2: confidential,
# 3: restricted}. Placeholders are filled from the word banks below.
TEMPLATES = [
    # -------- public (general knowledge, no org/person data) --------
    ("what is the capital of {country}", 0),
    ("explain how {tech} works in simple terms", 0),
    ("write a haiku about {nature}", 0),
    ("what are common complications of {disease}", 0),
    ("summarize the history of {tech}", 0),
    ("tips for staying healthy while traveling", 0),
    ("how do i sort a list in python", 0),
    ("what time zone is {country} in", 0),
    # -------- internal (non-public but non-sensitive) --------
    ("draft the agenda for the {team} team standup", 1),
    ("summarize the notes from yesterdays {team} sync", 1),
    ("refactor this helper function in the {team} service", 1),
    ("what did we decide about the {tech} migration", 1),
    ("update the onboarding doc for the {team} team", 1),
    ("estimate effort for the {tech} upgrade next sprint", 1),
    ("search medical literature for {disease} treatment guidelines", 1),
    ("summarize recent {disease} research guidelines for the clinic", 1),
    # -------- confidential (personal data) --------
    ("email {person} at {email} about the offer letter", 2),
    ("call {person} on {phone} regarding the invoice", 2),
    ("my name is {person} and i live in {city}", 2),
    ("{person} reported the issue from ip 10.0.0.{num}", 2),
    ("salary review for {person} is scheduled friday", 2),
    ("the candidate {person} interviewed for the {team} role", 2),
    # -------- restricted (regulated: PHI / financial / identifiers) --------
    ("patient {person} ssn {ssn} diagnosed with {disease}", 3),
    ("analyze treatment options for patient {person} with {disease}", 3),
    ("charge card {card} for {person} account", 3),
    ("patient mrn {num}{num} prescribed {drug} {num} mg daily", 3),
    ("wire transfer from account {account} routing {routing}", 3),
    ("{person} hba1c results elevated, adjust {drug} dosage", 3),
]

WORDS = {
    "country": ["france", "japan", "brazil", "kenya", "norway", "india"],
    "tech": ["kubernetes", "rust", "jax", "raft", "vector databases", "tls"],
    "nature": ["islands", "tides", "mist", "the horizon", "lighthouses"],
    "disease": ["diabetes", "hypertension", "asthma", "migraine", "anemia"],
    "team": ["platform", "billing", "search", "mobile", "infra"],
    "person": ["john doe", "jane smith", "arun patel", "maria garcia",
               "wei chen", "fatima khan"],
    "city": ["chicago", "mumbai", "berlin", "osaka", "lagos", "austin"],
    "drug": ["metformin", "lisinopril", "insulin", "atorvastatin"],
}


def _fill(template: str, rng: np.random.Generator) -> str:
    out = template
    for key, bank in WORDS.items():
        while "{" + key + "}" in out:
            out = out.replace("{" + key + "}", bank[rng.integers(len(bank))], 1)
    out = out.replace("{email}", f"user{rng.integers(100)}@example.com")
    out = out.replace("{phone}", f"555-{rng.integers(100,999)}-{rng.integers(1000,9999)}")
    out = out.replace("{ssn}", f"{rng.integers(100,999)}-{rng.integers(10,99)}-{rng.integers(1000,9999)}")
    out = out.replace("{card}", "4111-1111-1111-" + str(rng.integers(1000, 9999)))
    out = out.replace("{account}", str(rng.integers(10**9, 10**10 - 1)))
    out = out.replace("{routing}", str(rng.integers(10**8, 10**9 - 1)))
    while "{num}" in out:
        out = out.replace("{num}", str(rng.integers(10, 99)), 1)
    return out


def classifier_dataset(n_per_template=40, seed=0):
    """Generate (texts, labels) for the MIST Stage-2 classifier."""
    rng = np.random.default_rng(seed)
    texts, labels = [], []
    for template, label in TEMPLATES:
        for _ in range(n_per_template):
            texts.append(_fill(template, rng))
            labels.append(label)
    order = rng.permutation(len(texts))
    return [texts[i] for i in order], np.array([labels[i] for i in order],
                                               dtype=np.int32)


# Documents for the data-locality / RAG experiments (embedded "case law").
RAG_DOCS = [
    "contract dispute over delivery timelines in maritime shipping",
    "precedent on data privacy obligations for cloud storage providers",
    "employment agreement non-compete clause enforceability ruling",
    "patent infringement claim regarding distributed routing algorithms",
    "liability for autonomous vehicle sensor failures on highways",
    "medical malpractice standard of care for remote diagnosis",
    "intellectual property assignment in open source contributions",
    "negligence claim for inadequate network security controls",
    "arbitration clause enforceability in consumer software licenses",
    "regulatory compliance for cross border financial data transfers",
    "trade secret misappropriation by departing employees",
    "class action over misleading subscription renewal practices",
]
