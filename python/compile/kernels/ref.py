"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance under pytest/hypothesis sweeps
(python/tests/test_kernel.py). They are also used as the *training-time*
implementation (training runs the plain-jnp path; the AOT-served artifact
runs the Pallas path, and the equality of the two is what the kernel tests
establish).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """Multi-head scaled dot-product attention, reference implementation.

    Args:
      q, k, v: [BH, T, D] arrays (batch*heads flattened into the leading dim).
      causal: apply a lower-triangular causal mask.

    Returns:
      [BH, T, D] attention output, same dtype as q.
    """
    orig_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], jnp.float32))
    logits = jnp.einsum("btd,bsd->bts", q, k) * scale
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bts,bsd->btd", probs, v)
    return out.astype(orig_dtype)


def mlp_ref(x, w1, b1, w2, b2):
    """Fused two-layer MLP with ReLU, reference implementation.

    Args:
      x: [B, F] input features.
      w1: [F, H], b1: [H], w2: [H, O], b2: [O].

    Returns:
      [B, O] logits in float32.
    """
    x = x.astype(jnp.float32)
    h = jnp.maximum(x @ w1.astype(jnp.float32) + b1.astype(jnp.float32), 0.0)
    return h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
