"""L1 Pallas kernel: fused two-layer MLP (linear -> ReLU -> linear).

Used by the MIST Stage-2 sensitivity classifier (and by the TinyLM feed
forward blocks). Fusing the two matmuls and the activation into one kernel
keeps the [block_b, H] hidden activations resident in VMEM instead of
round-tripping them through HBM — the same reasoning a GPU implementation
would apply to shared memory, re-expressed as a Pallas BlockSpec schedule
(DESIGN.md §Hardware-Adaptation).

Grid: (B // block_b,); each instance computes a [block_b, O] output tile.
Weights are small enough (512x128 + 128xO floats < 300 KB) to map fully into
VMEM per instance, which is the right call on TPU too for these shapes.

interpret=True is REQUIRED on this CPU image (Mosaic custom-calls cannot run
on the CPU PJRT plugin). Oracle: kernels.ref.mlp_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jnp.maximum(x @ w1_ref[...].astype(jnp.float32)
                    + b1_ref[...].astype(jnp.float32), 0.0)
    o_ref[...] = (h @ w2_ref[...].astype(jnp.float32)
                  + b2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def mlp(x, w1, b1, w2, b2, *, block_b=8, interpret=True):
    """Fused MLP forward over [B, F] inputs via Pallas.

    Matches kernels.ref.mlp_ref. block_b must divide B (callers pad the
    batch; the AOT classifier artifact uses a fixed B so this always holds).
    """
    b, f = x.shape
    h = w1.shape[1]
    o = w2.shape[1]
    block_b = min(block_b, b)
    if b % block_b:
        raise ValueError(f"B={b} must be divisible by block_b={block_b}")

    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_mlp_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, o), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
