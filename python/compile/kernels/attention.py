"""L1 Pallas kernel: tiled causal multi-head attention (flash-style).

This is the compute hot spot of the TinyLM the islands serve. It is written
as a Pallas kernel with an explicit HBM<->VMEM schedule expressed through
BlockSpecs, in the flash-attention online-softmax style:

  grid = (BH, T // BLOCK_Q)
  - each program instance owns one (head, q-block) tile,
  - K and V stream through VMEM one BLOCK_K tile at a time inside a
    fori_loop, maintaining running max / running sum / accumulator,
  - causal masking is applied per (q, k) tile pair via iota comparison, and
    whole k-tiles strictly above the diagonal are skipped.

TPU mapping notes (see DESIGN.md §Hardware-Adaptation):
  - VMEM footprint per program instance =
      Q tile  BLOCK_Q*D*4  +  K/V tiles 2*BLOCK_K*D*4  +  acc BLOCK_Q*D*4
      + softmax state 2*BLOCK_Q*4 bytes.
    For the shipped TinyLM (T=64, D=16, BLOCK_Q=BLOCK_K=32) that is ~8.5 KB,
    vastly under the ~16 MB/core VMEM budget; the blocks are kept small only
    because the model is tiny. The *shape* of the schedule (stream K/V, keep
    Q + acc resident) is the one that scales to real model sizes.
  - The matmuls are [BLOCK_Q,D]x[D,BLOCK_K] and [BLOCK_Q,BLOCK_K]x[BLOCK_K,D];
    on a real TPU these would be zero-padded to the 128-lane MXU tile. We
    document rather than pad because interpret=True (mandatory on this CPU
    image) executes via numpy where padding only adds work.

interpret=True is REQUIRED here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Correctness is
established against kernels.ref.attention_ref by python/tests/test_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k,
                      seq_len, causal):
    """One (head, q-block) program instance of flash attention."""
    qi = pl.program_id(1)  # q-block index within the sequence
    q = q_ref[...].astype(jnp.float32)  # [block_q, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    q = q * scale

    num_k_blocks = seq_len // block_k

    # Running online-softmax state.
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)         # running max
    l0 = jnp.zeros((block_q,), jnp.float32)                 # running sum
    acc0 = jnp.zeros((block_q, d), jnp.float32)             # output accum

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # absolute q rows

    def body(ki, carry):
        m, l, acc = carry
        k_tile = k_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # [block_q, block_k]
        if causal:
            k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # Rescale previous accumulator, fold in the new tile.
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    if causal:
        # Tiles strictly above the diagonal contribute nothing; skip them.
        # The last k-block that intersects rows of q-block `qi` is
        # floor(((qi+1)*block_q - 1) / block_k).
        last = (qi * block_q + block_q - 1) // block_k + 1
    else:
        last = num_k_blocks
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def attention(q, k, v, *, causal=True, block_q=32, block_k=32,
              interpret=True):
    """Tiled causal attention over [BH, T, D] tensors via Pallas.

    Matches kernels.ref.attention_ref. Block sizes must divide T; callers
    with short sequences should shrink the blocks (the AOT path uses
    min(T, 32)).
    """
    bh, t, d = q.shape
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    if t % block_q or t % block_k:
        raise ValueError(f"T={t} must be divisible by blocks {block_q},{block_k}")

    kernel = functools.partial(
        _attention_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=t,
        causal=causal,
    )
    grid = (bh, t // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Q: one [block_q, d] tile per program instance.
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            # K, V: the whole sequence for this head is mapped; the kernel
            # streams tiles of it via pl.dslice inside the fori_loop. This
            # expresses "K/V live in HBM, tiles staged into VMEM on demand".
            pl.BlockSpec((None, t, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, t, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
