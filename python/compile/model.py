"""L2: JAX compute graphs served by the islands, calling the L1 kernels.

Three models, all AOT-lowered to HLO text by aot.py and executed from the
rust coordinator through PJRT (python never runs on the request path):

  1. TinyLM           — character-level transformer LM; the inference
                        workload every island (SHORE / edge / HORIZON) serves.
  2. Classifier       — MIST Stage-2 "local small language model": hashed
                        char-n-gram features -> fused-MLP -> 4 sensitivity
                        classes (public / internal / confidential / restricted).
  3. Embedder         — hashed-n-gram features -> projection -> L2-normalized
                        64-d embedding for the vector-store substrate
                        (data-locality / RAG experiments).

The hashed n-gram featurizer defined here is re-implemented byte-for-byte in
rust (rust/src/runtime/features.rs); python/tests/test_model.py and the rust
unit tests pin the same golden vectors so the two can never drift.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import attention as attention_kernel
from compile.kernels import mlp as mlp_kernel
from compile.kernels import ref as kernels_ref

# ---------------------------------------------------------------------------
# Shared model hyperparameters (mirrored in artifacts/meta.json for rust).
# ---------------------------------------------------------------------------
VOCAB = 256          # byte-level tokenizer
SEQ_LEN = 64         # fixed context window of the AOT artifacts
D_MODEL = 64
N_HEADS = 4
HEAD_DIM = D_MODEL // N_HEADS
N_LAYERS = 2
D_FF = 128

FEAT_DIM = 512       # hashed n-gram feature buckets
NGRAM_SIZES = (2, 3)
N_CLASSES = 4        # public / internal / confidential / restricted
CLASSIFIER_HIDDEN = 128
EMBED_DIM = 64

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1


# ---------------------------------------------------------------------------
# Featurizer (mirrored in rust/src/runtime/features.rs).
# ---------------------------------------------------------------------------
def fnv1a(data: bytes) -> int:
    """64-bit FNV-1a over a byte string."""
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def featurize(text: str) -> np.ndarray:
    """Hashed char-n-gram features: lowercase -> byte {2,3}-grams -> FNV-1a
    buckets mod FEAT_DIM -> counts -> L2 normalize. MUST match the rust
    implementation exactly."""
    data = text.lower().encode("utf-8")
    vec = np.zeros(FEAT_DIM, dtype=np.float32)
    for n in NGRAM_SIZES:
        for i in range(max(0, len(data) - n + 1)):
            vec[fnv1a(data[i:i + n]) % FEAT_DIM] += 1.0
    norm = float(np.linalg.norm(vec))
    if norm > 0.0:
        vec /= norm
    return vec


# ---------------------------------------------------------------------------
# TinyLM
# ---------------------------------------------------------------------------
def init_lm_params(key):
    """Initialize TinyLM parameters (dict pytree)."""
    keys = jax.random.split(key, 4 + N_LAYERS)
    scale = 0.02
    params = {
        "tok_emb": jax.random.normal(keys[0], (VOCAB, D_MODEL)) * scale,
        "pos_emb": jax.random.normal(keys[1], (SEQ_LEN, D_MODEL)) * scale,
        "ln_f_g": jnp.ones(D_MODEL),
        "ln_f_b": jnp.zeros(D_MODEL),
        "head": jax.random.normal(keys[2], (D_MODEL, VOCAB)) * scale,
        "blocks": [],
    }
    for li in range(N_LAYERS):
        k = jax.random.split(keys[4 + li], 8)
        params["blocks"].append({
            "ln1_g": jnp.ones(D_MODEL), "ln1_b": jnp.zeros(D_MODEL),
            "wq": jax.random.normal(k[0], (D_MODEL, D_MODEL)) * scale,
            "wk": jax.random.normal(k[1], (D_MODEL, D_MODEL)) * scale,
            "wv": jax.random.normal(k[2], (D_MODEL, D_MODEL)) * scale,
            "wo": jax.random.normal(k[3], (D_MODEL, D_MODEL)) * scale,
            "ln2_g": jnp.ones(D_MODEL), "ln2_b": jnp.zeros(D_MODEL),
            "w1": jax.random.normal(k[4], (D_MODEL, D_FF)) * scale,
            "b1": jnp.zeros(D_FF),
            "w2": jax.random.normal(k[5], (D_FF, D_MODEL)) * scale,
            "b2": jnp.zeros(D_MODEL),
        })
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attn_block(x, blk, use_pallas):
    """Multi-head causal self-attention over x: [B, T, D_MODEL]."""
    b, t, _ = x.shape
    q = x @ blk["wq"]
    k = x @ blk["wk"]
    v = x @ blk["wv"]

    def split(z):  # [B,T,D] -> [B*H, T, HEAD_DIM]
        z = z.reshape(b, t, N_HEADS, HEAD_DIM).transpose(0, 2, 1, 3)
        return z.reshape(b * N_HEADS, t, HEAD_DIM)

    q, k, v = split(q), split(k), split(v)
    if use_pallas:
        o = attention_kernel.attention(q, k, v, causal=True,
                                       block_q=min(32, t), block_k=min(32, t))
    else:
        o = kernels_ref.attention_ref(q, k, v, causal=True)
    o = o.reshape(b, N_HEADS, t, HEAD_DIM).transpose(0, 2, 1, 3)
    o = o.reshape(b, t, D_MODEL)
    return o @ blk["wo"]


def _ff_block(x, blk, use_pallas):
    b, t, _ = x.shape
    if use_pallas:
        flat = x.reshape(b * t, D_MODEL)
        out = mlp_kernel.mlp(flat, blk["w1"], blk["b1"], blk["w2"], blk["b2"],
                             block_b=min(32, b * t))
        return out.reshape(b, t, D_MODEL)
    return kernels_ref.mlp_ref(
        x.reshape(b * t, D_MODEL), blk["w1"], blk["b1"], blk["w2"], blk["b2"]
    ).reshape(b, t, D_MODEL)


def lm_forward(params, tokens, use_pallas=False):
    """TinyLM forward: tokens [B, T] int32 -> logits [B, T, VOCAB] f32.

    use_pallas selects the L1 kernel path (AOT artifacts) vs the jnp oracle
    path (training). Both paths are asserted equal by the kernel tests.
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:t][None, :, :]
    for blk in params["blocks"]:
        x = x + _attn_block(_layer_norm(x, blk["ln1_g"], blk["ln1_b"]), blk,
                            use_pallas)
        x = x + _ff_block(_layer_norm(x, blk["ln2_g"], blk["ln2_b"]), blk,
                          use_pallas)
    x = _layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    return x @ params["head"]


def lm_loss(params, tokens):
    """Next-token cross-entropy over a [B, T+1] token batch."""
    logits = lm_forward(params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# Sensitivity classifier (MIST Stage-2)
# ---------------------------------------------------------------------------
def init_classifier_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (FEAT_DIM, CLASSIFIER_HIDDEN)) * 0.05,
        "b1": jnp.zeros(CLASSIFIER_HIDDEN),
        "w2": jax.random.normal(k2, (CLASSIFIER_HIDDEN, N_CLASSES)) * 0.05,
        "b2": jnp.zeros(N_CLASSES),
    }


def classifier_forward(params, feats, use_pallas=False):
    """feats [B, FEAT_DIM] -> class logits [B, N_CLASSES]."""
    if use_pallas:
        return mlp_kernel.mlp(feats, params["w1"], params["b1"],
                              params["w2"], params["b2"],
                              block_b=min(8, feats.shape[0]))
    return kernels_ref.mlp_ref(feats, params["w1"], params["b1"],
                               params["w2"], params["b2"])


def classifier_loss(params, feats, labels):
    logits = classifier_forward(params, feats)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


# ---------------------------------------------------------------------------
# Embedder (vector-store substrate)
# ---------------------------------------------------------------------------
def init_embedder_params(key):
    # A fixed random projection is a valid (Johnson-Lindenstrauss) embedder
    # for the cosine-similarity vector store; no training needed.
    return {"proj": jax.random.normal(key, (FEAT_DIM, EMBED_DIM)) / np.sqrt(FEAT_DIM)}


def embedder_forward(params, feats):
    """feats [B, FEAT_DIM] -> unit-norm embeddings [B, EMBED_DIM]."""
    e = feats @ params["proj"]
    norm = jnp.sqrt((e * e).sum(-1, keepdims=True) + 1e-12)
    return e / norm
