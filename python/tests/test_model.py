"""L2 model tests: featurizer goldens, TinyLM shape/causality, classifier."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data, model


# ---------------------------------------------------------------------------
# featurizer — must match rust/src/runtime/features.rs exactly
# ---------------------------------------------------------------------------
def test_fnv1a_golden():
    # Golden values pinned in the rust unit tests too (features.rs).
    assert model.fnv1a(b"ab") == 0x089C4407B545986A
    assert model.fnv1a(b"") == 0xCBF29CE484222325
    assert model.fnv1a(b"islandrun") % model.FEAT_DIM == 233


def test_featurize_empty_and_short():
    assert model.featurize("").sum() == 0.0
    assert model.featurize("a").sum() == 0.0  # no 2-grams in 1 byte
    v = model.featurize("ab")  # exactly one 2-gram
    assert np.isclose(np.linalg.norm(v), 1.0)
    assert (v > 0).sum() == 1


def test_featurize_case_insensitive():
    np.testing.assert_array_equal(model.featurize("Hello World"),
                                  model.featurize("hello world"))


def test_featurize_unit_norm():
    for text in ["hello", "patient john doe", data.CORPUS[:200]]:
        v = model.featurize(text)
        assert np.isclose(np.linalg.norm(v), 1.0, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.text(min_size=2, max_size=80))
def test_featurize_deterministic_and_bounded(text):
    v1, v2 = model.featurize(text), model.featurize(text)
    np.testing.assert_array_equal(v1, v2)
    assert v1.shape == (model.FEAT_DIM,)
    n = np.linalg.norm(v1)
    assert n == 0.0 or np.isclose(n, 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# TinyLM
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_params():
    return model.init_lm_params(jax.random.PRNGKey(0))


def test_lm_forward_shape(lm_params):
    toks = jnp.zeros((2, model.SEQ_LEN), jnp.int32)
    logits = model.lm_forward(lm_params, toks)
    assert logits.shape == (2, model.SEQ_LEN, model.VOCAB)
    assert bool(jnp.isfinite(logits).all())


def test_lm_causality(lm_params):
    """Changing token t must not affect logits at positions < t."""
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, model.SEQ_LEN), 0, model.VOCAB)
    l1 = model.lm_forward(lm_params, toks)
    toks2 = toks.at[0, 40].set((toks[0, 40] + 1) % model.VOCAB)
    l2 = model.lm_forward(lm_params, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :40]), np.asarray(l2[:, :40]),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(l1[:, 40:]) - np.asarray(l2[:, 40:])).max() > 1e-6


def test_lm_pallas_path_matches_ref_path(lm_params):
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, model.SEQ_LEN),
                              0, model.VOCAB)
    l_ref = model.lm_forward(lm_params, toks, use_pallas=False)
    l_pal = model.lm_forward(lm_params, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_pal),
                               rtol=5e-5, atol=5e-5)


def test_lm_loss_decreases_quickly():
    """A couple of adam steps on one batch must reduce loss (trainability)."""
    from compile import train
    params, log = train.train_lm(steps=8, batch=8, log_every=7)
    assert log[-1][1] < log[0][1]


# ---------------------------------------------------------------------------
# classifier + embedder
# ---------------------------------------------------------------------------
def test_classifier_learns_labels():
    from compile import train
    params, tr_acc, va_acc = train.train_classifier(steps=150)
    assert tr_acc > 0.9
    assert va_acc > 0.85


def test_classifier_dataset_balanced():
    texts, labels = data.classifier_dataset(n_per_template=10)
    counts = np.bincount(labels, minlength=4)
    assert counts.min() > 0
    # classes are template-balanced within 2x of each other
    assert counts.max() <= 2 * counts.min()


def test_embedder_unit_norm_and_locality():
    params = model.init_embedder_params(jax.random.PRNGKey(7))
    feats = np.stack([model.featurize(t) for t in data.RAG_DOCS[:4]])
    emb = np.asarray(model.embedder_forward(params, jnp.asarray(feats)))
    norms = np.linalg.norm(emb, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)
    # identical text -> identical embedding; different text -> different
    e1 = np.asarray(model.embedder_forward(
        params, jnp.asarray(feats[:1])))[0]
    np.testing.assert_allclose(e1, emb[0], atol=1e-6)
    assert np.abs(emb[0] - emb[1]).max() > 1e-3
