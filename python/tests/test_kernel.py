"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: hypothesis sweeps
shapes/dtypes and asserts allclose against ref.py. The AOT artifacts are
exported through the same kernel code paths these tests pin.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as ak
from compile.kernels import mlp as mk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4, 8]),
    t=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_f32(bh, t, d, causal, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (_rand(kk, (bh, t, d), jnp.float32) for kk in keys)
    got = ak.attention(q, k, v, causal=causal,
                       block_q=min(16, t), block_k=min(16, t))
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(jnp.float32))


@settings(max_examples=8, deadline=None)
@given(
    t=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_bf16(t, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (_rand(kk, (4, t, 16), jnp.bfloat16) for kk in keys)
    got = ak.attention(q, k, v, block_q=min(16, t), block_k=min(16, t))
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(jnp.bfloat16))


@pytest.mark.parametrize("block_q,block_k", [(8, 8), (8, 16), (16, 8),
                                             (32, 32), (64, 64)])
def test_attention_block_shape_invariance(block_q, block_k):
    """Output must not depend on the tiling schedule."""
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (_rand(kk, (2, 64, 16), jnp.float32) for kk in keys)
    got = ak.attention(q, k, v, block_q=block_q, block_k=block_k)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Changing future K/V must not change past outputs."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(kk, (1, 32, 16), jnp.float32) for kk in keys)
    out1 = np.asarray(ak.attention(q, k, v, block_q=8, block_k=8))
    k2 = k.at[:, 20:, :].set(99.0)
    v2 = v.at[:, 20:, :].set(-99.0)
    out2 = np.asarray(ak.attention(q, k2, v2, block_q=8, block_k=8))
    np.testing.assert_allclose(out1[:, :20], out2[:, :20], rtol=1e-6,
                               atol=1e-6)
    assert np.abs(out1[:, 20:] - out2[:, 20:]).max() > 1e-3


def test_attention_rejects_indivisible_blocks():
    q = jnp.zeros((1, 24, 8))
    with pytest.raises(ValueError):
        ak.attention(q, q, q, block_q=16, block_k=16)


def test_attention_softmax_rows_are_convex_combinations():
    """Each output row lies in the convex hull of V rows (softmax weights)."""
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(kk, (1, 16, 8), jnp.float32) for kk in keys)
    out = np.asarray(ak.attention(q, k, v, causal=False,
                                  block_q=8, block_k=8))[0]
    vmin, vmax = np.asarray(v)[0].min(0), np.asarray(v)[0].max(0)
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 4, 8, 16, 32]),
    f=st.sampled_from([16, 64, 512]),
    h=st.sampled_from([8, 32, 128]),
    o=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_matches_ref(b, f, h, o, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(keys[0], (b, f), jnp.float32)
    w1 = _rand(keys[1], (f, h), jnp.float32) * 0.1
    b1 = _rand(keys[2], (h,), jnp.float32) * 0.1
    w2 = _rand(keys[3], (h, o), jnp.float32) * 0.1
    b2 = _rand(keys[4], (o,), jnp.float32) * 0.1
    got = mk.mlp(x, w1, b1, w2, b2, block_b=min(8, b))
    want = ref.mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mlp_block_invariance():
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    x = _rand(keys[0], (16, 64), jnp.float32)
    w1, b1 = _rand(keys[1], (64, 32), jnp.float32), jnp.zeros(32)
    w2, b2 = _rand(keys[2], (32, 4), jnp.float32), jnp.zeros(4)
    outs = [np.asarray(mk.mlp(x, w1, b1, w2, b2, block_b=bb))
            for bb in (1, 2, 4, 8, 16)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-6, atol=1e-6)


def test_attention_uniform_when_keys_identical():
    """Identical K rows -> uniform softmax -> output = mean of visible V."""
    t, d = 16, 8
    q = jax.random.normal(jax.random.PRNGKey(4), (1, t, d))
    k = jnp.ones((1, t, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, t, d))
    out = np.asarray(ak.attention(q, k, v, causal=True, block_q=8, block_k=8))
    for pos in [0, 7, 15]:
        want = np.asarray(v)[0, : pos + 1].mean(0)
        np.testing.assert_allclose(out[0, pos], want, rtol=1e-5, atol=1e-5)


def test_attention_longer_than_default_block():
    """T=128 exceeds the 32-wide default blocks: grid must tile correctly."""
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (_rand(kk, (2, 128, 16), jnp.float32) for kk in keys)
    got = ak.attention(q, k, v)  # default block 32 -> grid (2, 4)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_attention_causal_vs_full_differ():
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (_rand(kk, (1, 16, 8), jnp.float32) for kk in keys)
    causal = np.asarray(ak.attention(q, k, v, causal=True, block_q=8, block_k=8))
    full = np.asarray(ak.attention(q, k, v, causal=False, block_q=8, block_k=8))
    # last row sees everything either way
    np.testing.assert_allclose(causal[0, -1], full[0, -1], rtol=1e-5, atol=1e-5)
    # first row differs (sees only itself under causal)
    assert np.abs(causal[0, 0] - full[0, 0]).max() > 1e-4


def test_mlp_relu_nonlinearity_active():
    """The fused kernel must actually apply ReLU (not be a linear map)."""
    x = jnp.array([[1.0, -1.0]])
    w1 = jnp.eye(2)
    b1 = jnp.zeros(2)
    w2 = jnp.ones((2, 1))
    b2 = jnp.zeros(1)
    # relu([1,-1]) = [1,0] -> sum = 1 (a linear map would give 0)
    out = np.asarray(mk.mlp(x, w1, b1, w2, b2, block_b=1))
    np.testing.assert_allclose(out, [[1.0]], rtol=1e-6)
