"""AOT export tests: HLO text interchange + meta.json integrity.

Operate on the artifacts/ directory if present (built by `make artifacts`);
the lowering-only tests build tiny throwaway modules so they run standalone.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_module():
    fn = lambda x: (x * 2.0 + 1.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    # HLO text essentials the rust-side parser relies on.
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "parameter(0)" in text
    # return_tuple=True: root is a tuple (rust unwraps with to_tuple1)
    assert "tuple(" in text or "(f32[2,2])" in text


def test_to_hlo_text_pallas_lowering_has_no_custom_call():
    """interpret=True must lower to plain HLO (no Mosaic custom-call),
    otherwise the CPU PJRT client cannot execute the artifact."""
    from compile.kernels import attention as ak
    fn = lambda q, k, v: (ak.attention(q, k, v, block_q=8, block_k=8),)
    spec = jax.ShapeDtypeStruct((2, 16, 8), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec, spec))
    assert "custom-call" not in text.lower().replace("custom_call", "custom-call") \
        or "mosaic" not in text.lower()
    assert "HloModule" in text


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="artifacts/ not built (run `make artifacts`)")


@needs_artifacts
def test_meta_json_schema():
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    assert meta["vocab"] == model.VOCAB
    assert meta["seq_len"] == model.SEQ_LEN
    assert meta["feat_dim"] == model.FEAT_DIM
    assert meta["lm_batch_variants"] == [1, 4, 8]
    assert meta["class_sensitivity"] == [0.2, 0.5, 0.8, 1.0]
    assert len(meta["golden"]) == 3
    assert meta["classifier_val_acc"] > 0.8
    # loss curve recorded and decreasing overall
    curve = meta["lm_loss_curve"]
    assert len(curve) >= 2 and curve[-1][1] < curve[0][1]


@needs_artifacts
def test_all_artifacts_present_and_are_hlo_text():
    for name in ["lm_b1", "lm_b4", "lm_b8", "classifier", "embedder"]:
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(2000)
        assert "HloModule" in head


@needs_artifacts
def test_artifacts_contain_real_constants():
    """Guard against the print_large_constants pitfall: elided weights parse
    fine but execute as zeros on the rust side (see aot.to_hlo_text)."""
    for name in ["lm_b1", "classifier", "embedder"]:
        path = os.path.join(ART, f"{name}.hlo.txt")
        with open(path) as f:
            text = f.read()
        assert "{...}" not in text, f"{name} has elided constants"
        # weights present -> file is at least hundreds of KB for the LM
        if name == "lm_b1":
            assert len(text) > 500_000, len(text)


@needs_artifacts
def test_golden_vectors_reproducible():
    """meta.json goldens must match a fresh featurize() run (cross-language
    anchor: rust pins the same numbers)."""
    import numpy as np
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    for g in meta["golden"]:
        v = model.featurize(g["text"])
        nz = np.nonzero(v)[0][:8]
        assert [int(i) for i in nz] == g["feat_nonzero_idx"]
        for i, val in zip(g["feat_nonzero_idx"], g["feat_nonzero_val"]):
            assert abs(float(v[i]) - val) < 1e-5
