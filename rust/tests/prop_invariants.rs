//! Property-based tests (via `util::minicheck`) on the coordinator's core
//! invariants: routing safety, batching conservation, sanitization
//! reversibility, trust composition, state-machine sanity.

use islandrun::agents::mist::sanitize::PlaceholderMap;
use islandrun::agents::tide::hysteresis::{Hysteresis, Preference};
use islandrun::agents::waves::pareto::{on_front, Point};
use islandrun::agents::waves::{IslandState, Waves};
use islandrun::config::json::Json;
use islandrun::config::{Config, Weights};
use islandrun::runtime::{BatchPolicy, Batcher};
use islandrun::substrate::tokenizer;
use islandrun::types::{
    Certification, CostModel, Island, IslandId, Jurisdiction, LinkKind, PriorityTier, Request, TrustTier,
};
use islandrun::util::minicheck::{all, check, ensure, CaseResult, Config as CheckCfg};
use islandrun::util::Rng;

fn random_island(rng: &mut Rng, id: u32) -> Island {
    let tier = *rng.pick(&[TrustTier::Personal, TrustTier::PrivateEdge, TrustTier::Cloud]);
    Island {
        id: IslandId(id),
        name: format!("rand-{id}"),
        tier,
        latency_ms: rng.range_f64(1.0, 500.0),
        cost: match rng.below(3) {
            0 => CostModel::Free,
            1 => CostModel::Fixed(rng.range_f64(0.0, 0.01)),
            _ => CostModel::PerRequest(rng.range_f64(0.001, 0.05)),
        },
        privacy: match tier {
            TrustTier::Personal => 1.0,
            TrustTier::PrivateEdge => rng.range_f64(0.6, 0.9),
            TrustTier::Cloud => rng.range_f64(0.2, 0.5),
        },
        certification: *rng.pick(&[Certification::Iso27001, Certification::Soc2, Certification::SelfCertified]),
        jurisdiction: *rng.pick(&[Jurisdiction::SameCountry, Jurisdiction::EuGdpr, Jurisdiction::Foreign]),
        capacity_slots: if rng.chance(0.3) { None } else { Some(1 + rng.below(8)) },
        link: *rng.pick(&[LinkKind::Loopback, LinkKind::Lan, LinkKind::Wan, LinkKind::Bluetooth, LinkKind::Cellular]),
        battery: if rng.chance(0.3) { Some(rng.f64()) } else { None },
        datasets: vec![],
        models: vec!["tinylm".into()],
    }
}

/// Core safety property — Def. 3 / Guarantee 1: for ANY mesh, ANY request,
/// ANY capacities and preferences, the router never selects an island with
/// P_j < s_r.
#[test]
fn prop_router_never_violates_privacy_constraint() {
    check(
        "privacy-constraint",
        CheckCfg { cases: 400, ..CheckCfg::default() },
        |rng, size| {
            let n = 1 + rng.below(size.max(1).min(16));
            let states: Vec<IslandState> = (0..n)
                .map(|i| IslandState { island: random_island(rng, i as u32), capacity: rng.f64(), online: true, degraded: false })
                .collect();
            let s_r = *rng.pick(&[0.2, 0.3, 0.5, 0.8, 0.9, 1.0]);
            let priority = *rng.pick(&[PriorityTier::Primary, PriorityTier::Secondary, PriorityTier::Burstable]);
            let pref = if rng.chance(0.5) { Preference::Local } else { Preference::Cloud };
            let budget = if rng.chance(0.2) { 0.0 } else { f64::INFINITY };
            (states, s_r, priority, pref, budget, rng.f64())
        },
        |(states, s_r, priority, pref, budget, lc)| {
            let waves = Waves::new(Config::default());
            let r = Request::new(1, "prop test prompt").with_priority(*priority);
            let d = waves.route(&r, *s_r, states, *lc, *pref, *budget);
            match d.target() {
                None => CaseResult::Pass,
                Some(id) => {
                    let island = &states.iter().find(|s| s.island.id == id).unwrap().island;
                    ensure(island.privacy >= *s_r, || {
                        format!("P={} < s_r={} (island {})", island.privacy, s_r, island.name)
                    })
                }
            }
        },
    );
}

/// Routing is deterministic: same inputs → same decision.
#[test]
fn prop_router_deterministic() {
    check(
        "router-deterministic",
        CheckCfg { cases: 150, ..CheckCfg::default() },
        |rng, size| {
            let n = 1 + rng.below(size.max(1).min(12));
            let states: Vec<IslandState> =
                (0..n).map(|i| IslandState { island: random_island(rng, i as u32), capacity: rng.f64(), online: true, degraded: false }).collect();
            (states, rng.f64())
        },
        |(states, lc)| {
            let waves = Waves::new(Config::default());
            let r = Request::new(1, "same prompt");
            let a = waves.route(&r, 0.5, states, *lc, Preference::Local, f64::INFINITY);
            let b = waves.route(&r, 0.5, states, *lc, Preference::Local, f64::INFINITY);
            ensure(a == b, || format!("{a:?} != {b:?}"))
        },
    );
}

/// §VI.C: with strictly positive weights, the Eq. 1 argmin among eligible
/// islands lies on the Pareto front of (cost, latency, 1-privacy).
#[test]
fn prop_scalarized_choice_is_pareto_optimal() {
    check(
        "pareto-optimality",
        CheckCfg { cases: 200, ..CheckCfg::default() },
        |rng, size| {
            let n = 2 + rng.below(size.max(2).min(10));
            let islands: Vec<Island> = (0..n).map(|i| random_island(rng, i as u32)).collect();
            let w = Weights {
                cost: 0.1 + rng.f64(),
                latency: 0.1 + rng.f64(),
                privacy: 0.1 + rng.f64(),
            };
            (islands, w)
        },
        |(islands, w)| {
            let tokens = 80;
            let best = islands
                .iter()
                .min_by(|a, b| {
                    islandrun::agents::waves::scoring::eq1_score(a, tokens, w)
                        .partial_cmp(&islandrun::agents::waves::scoring::eq1_score(b, tokens, w))
                        .unwrap()
                })
                .unwrap();
            let points: Vec<Point> = islands.iter().map(|i| Point::of(i, tokens)).collect();
            ensure(on_front(&points, best.id), || format!("argmin {} off the Pareto front", best.name))
        },
    );
}

/// Def. 4: sanitize∘desanitize == identity, and the sanitized text carries
/// no detectable entity above the target level.
#[test]
fn prop_sanitize_round_trip() {
    let people = ["john doe", "jane smith", "arun patel", "maria garcia"];
    let cities = ["chicago", "berlin", "osaka", "lagos"];
    let conditions = ["diabetes", "asthma", "anemia"];
    check(
        "sanitize-round-trip",
        CheckCfg { cases: 300, ..CheckCfg::default() },
        |rng, size| {
            let mut text = String::new();
            for _ in 0..(1 + rng.below(size.max(1).min(6))) {
                match rng.below(5) {
                    0 => text.push_str(&format!("patient {} ", rng.pick(&people))),
                    1 => text.push_str(&format!("in {} ", rng.pick(&cities))),
                    2 => text.push_str(&format!("with {} ", rng.pick(&conditions))),
                    3 => text.push_str(&format!("ssn {}-{}-{} ", rng.range_u64(100, 999), rng.range_u64(10, 99), rng.range_u64(1000, 9999))),
                    _ => text.push_str("and general words follow "),
                }
            }
            (text, rng.next_u64())
        },
        |(text, seed)| {
            let mut map = PlaceholderMap::new(*seed);
            let sanitized = map.sanitize(text, 0.4);
            all(vec![
                ensure(PlaceholderMap::verify_clean(&sanitized, 0.4), || format!("dirty: {sanitized}")),
                ensure(map.desanitize(&sanitized) == *text, || {
                    format!("round trip broke: '{}' -> '{}' -> '{}'", text, sanitized, map.desanitize(&sanitized))
                }),
            ])
        },
    );
}

/// Unicode fuzz for the sanitization pipeline: random mixed-script strings
/// through detect → sanitize → verify_clean → desanitize must never panic,
/// never leave an above-threshold entity, report only char-boundary spans,
/// and round-trip placeholder-free text byte-for-byte. This is the
/// regression net for the old `to_lowercase()`-offset bug, where a single
/// `İ`/`ẞ` before an entity shifted every span.
#[test]
fn prop_unicode_sanitize_never_panics_and_round_trips() {
    // entity terms in one fixed casing so desanitize is an exact inverse
    let entity_terms = [
        "john doe",
        "jane smith",
        "jane müller",
        "arun patel",
        "maria garcia",
        "chicago",
        "berlin",
        "osaka",
        "diabetes",
        "asthma",
        "metformin",
        "acme corp",
        "ssn 123-45-6789",
        "card 4111 1111 1111 1111",
        "a@b.co",
    ];
    // Unicode confusion: chars whose case maps change byte length (İ, ẞ),
    // multi-byte letters, combining marks, emoji, CJK, RTL — everything
    // that broke original-string slicing with lowered-text offsets. No
    // brackets (they would collide with placeholder syntax).
    let confusion = [
        "İstanbul",
        "İİİ",
        "ẞtraße",
        "ß",
        "ümit",
        "naïve",
        "e\u{0301}clair",
        "🏝️",
        "🏥💉",
        "日本語テキスト",
        "данные",
        "مرحبا",
        "Ωmega",
        "ﬁﬂ",
        "z\u{0300}\u{0301}\u{0302}",
    ];
    let filler = ["and", "then", "we", "discussed", "the", "plan", "quietly", "again"];
    check(
        "unicode-sanitize",
        CheckCfg { cases: 400, ..CheckCfg::default() },
        |rng, size| {
            let mut text = String::new();
            for _ in 0..(1 + rng.below(2 + size.max(1))) {
                match rng.below(6) {
                    0 | 1 => text.push_str(rng.pick(&entity_terms)),
                    2 | 3 => text.push_str(rng.pick(&confusion)),
                    _ => text.push_str(rng.pick(&filler)),
                }
                text.push(' ');
            }
            let level = *rng.pick(&[0.3, 0.45, 0.55, 0.7, 0.95]);
            (text, level, rng.next_u64())
        },
        |(text, level, seed)| {
            // must not panic on any of these, ever
            let entities = islandrun::agents::mist::entities::detect(text);
            for e in &entities {
                if !text.is_char_boundary(e.start) || !text.is_char_boundary(e.end) {
                    return CaseResult::Fail(format!("span off char boundary: {e:?} in {text:?}"));
                }
                if text[e.start..e.end] != e.text {
                    return CaseResult::Fail(format!("span/text mismatch: {e:?} in {text:?}"));
                }
            }
            let mut map = PlaceholderMap::new(*seed);
            let sanitized = map.sanitize(text, *level);
            all(vec![
                ensure(PlaceholderMap::verify_clean(&sanitized, *level), || {
                    format!("dirty at {level}: {sanitized:?} from {text:?}")
                }),
                ensure(map.desanitize(&sanitized) == *text, || {
                    format!("round trip broke: {:?} -> {:?} -> {:?}", text, sanitized, map.desanitize(&sanitized))
                }),
            ])
        },
    );
}

/// Eq. 2: trust composition is conservative — never above any component.
#[test]
fn prop_trust_composition_conservative() {
    check(
        "trust-composition",
        CheckCfg { cases: 200, ..CheckCfg::default() },
        |rng, _| random_island(rng, 0),
        |island| {
            let t = island.trust();
            all(vec![
                ensure(t <= island.tier.base_trust() + 1e-12, || "above base".into()),
                ensure(t <= island.certification.score() + 1e-12, || "above cert".into()),
                ensure(t <= island.jurisdiction.score() + 1e-12, || "above jurisdiction".into()),
                ensure(island.trust_product() <= t + 1e-12, || "product above min".into()),
            ])
        },
    );
}

/// Hysteresis: transition count never exceeds the number of dead-zone
/// boundary crossings in the input (the whole point of the dead zone).
#[test]
fn prop_hysteresis_transitions_bounded() {
    check(
        "hysteresis-bounded",
        CheckCfg { cases: 200, ..CheckCfg::default() },
        |rng, size| (0..(size * 4)).map(|_| rng.f64()).collect::<Vec<f64>>(),
        |samples| {
            let mut h = Hysteresis::new(0.70, 0.80);
            for &s in samples {
                h.observe(s);
            }
            // count potential crossings: samples strictly below low or above high
            let extremes = samples.iter().filter(|&&s| s < 0.70 || s > 0.80).count() as u64;
            ensure(h.transitions() <= extremes, || {
                format!("{} transitions from {} extreme samples", h.transitions(), extremes)
            })
        },
    );
}

/// Batcher conservation: what goes in comes out exactly once, in FIFO
/// order, in chunks no larger than the policy cap.
#[test]
fn prop_batcher_conservation_and_order() {
    check(
        "batcher-conservation",
        CheckCfg { cases: 200, ..CheckCfg::default() },
        |rng, size| {
            let n = rng.below(size.max(1) * 2) + 1;
            let cap = 1 + rng.below(8);
            (n, cap)
        },
        |&(n, cap)| {
            let policy =
                BatchPolicy { max_batch: cap, max_wait: std::time::Duration::from_secs(0), ..BatchPolicy::default() };
            let mut b = Batcher::new(policy);
            for i in 0..n {
                b.push(i);
            }
            let mut drained = Vec::new();
            while !b.is_empty() {
                let batch = b.take_batch();
                if batch.is_empty() || batch.len() > cap {
                    return CaseResult::Fail(format!("batch size {} cap {cap}", batch.len()));
                }
                drained.extend(batch);
            }
            ensure(drained == (0..n).collect::<Vec<_>>(), || "lost or reordered items".into())
        },
    );
}

/// JSON round-trip: parse(to_string(v)) == v for random value trees.
#[test]
fn prop_json_round_trip() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        if depth == 0 {
            return match rng.below(4) {
                0 => Json::Null,
                1 => Json::Bool(rng.chance(0.5)),
                2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
            };
        }
        match rng.below(2) {
            0 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4)).map(|i| (format!("k{i}"), gen_json(rng, depth - 1))).collect(),
            ),
        }
    }
    check(
        "json-round-trip",
        CheckCfg { cases: 300, ..CheckCfg::default() },
        |rng, size| gen_json(rng, (size % 4).max(1)),
        |v| {
            let text = v.to_string();
            match Json::parse(&text) {
                Ok(back) => ensure(back == *v, || format!("{v} != {back}")),
                Err(e) => CaseResult::Fail(format!("parse error {e} on {text}")),
            }
        },
    );
}

/// Tokenizer framing invariants: fixed length, decode inverse on short
/// ASCII, left-truncation keeps the suffix.
#[test]
fn prop_tokenizer_framing() {
    check(
        "tokenizer-framing",
        CheckCfg { cases: 300, ..CheckCfg::default() },
        |rng, size| {
            let len = rng.below(size.max(1) * 3) + 1;
            let s: String = (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            s
        },
        |s| {
            let ids = tokenizer::encode_fixed(s, 64);
            let decoded = tokenizer::decode(&ids);
            let expect: String = s.chars().rev().take(64).collect::<Vec<_>>().into_iter().rev().collect();
            all(vec![
                ensure(ids.len() == 64, || "length".into()),
                ensure(decoded == expect, || format!("'{decoded}' != '{expect}'")),
            ])
        },
    );
}

/// Cost monotonicity: more tokens never cost less.
#[test]
fn prop_cost_monotone_in_tokens() {
    check(
        "cost-monotone",
        CheckCfg { cases: 200, ..CheckCfg::default() },
        |rng, _| (random_island(rng, 0), 1 + rng.below(1000), 1 + rng.below(1000)),
        |(island, a, b)| {
            let (lo, hi) = (*a.min(b), *a.max(b));
            ensure(island.request_cost(lo) <= island.request_cost(hi) + 1e-12, || {
                format!("cost({lo}) > cost({hi})")
            })
        },
    );
}
