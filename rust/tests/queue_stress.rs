//! Queue stress: N producers race the bounded admission queue while the
//! worker pool drains it.
//!
//! Pins the request-lifecycle invariants that must hold under contention,
//! independent of interleaving:
//! - no ticket is lost or double-resolved: every enqueue resolves exactly
//!   once (served, fail-closed reject, or shed) and the
//!   `ticket_double_resolved` counter stays 0,
//! - every request that consumed an id — including queue-full and
//!   deadline-expired sheds — leaves exactly one audit entry,
//! - the cost ledger equals Σ per-outcome costs even under shedding (shed
//!   requests are never charged),
//! - cross-session co-routed requests demonstrably coalesce into shared
//!   execute groups (fewer groups than requests; max group size > 1),
//! - queue ordering is honored: Primary drains ahead of Burstable.
//!
//! Producer count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job.

use std::collections::HashMap;
use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, Outcome, SubmitRequest, Ticket};
use islandrun::substrate::trace::{priority_for, prompt_for};
use islandrun::types::PriorityTier;
use islandrun::util::Rng;

const PER_PRODUCER: usize = 50;

fn producers() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(8)
}

fn stress_orchestrator(seed: u64, queue_capacity: usize, serve_workers: usize) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the stress test exercises the queue lifecycle, not admission policy:
    // a saturating rate limit or budget would turn submissions away and
    // hide the invariants under test
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.queue_capacity = queue_capacity;
    cfg.serve_workers = serve_workers;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

#[test]
fn racing_producers_lose_no_ticket_and_account_every_cost() {
    let producers = producers();
    let orch = stress_orchestrator(601, 100_000, 4);
    Arc::clone(&orch).start_queue();
    let handles: Vec<_> = (0..producers)
        .map(|t| {
            let orch = Arc::clone(&orch);
            std::thread::spawn(move || {
                let session = orch.open_session(&format!("qstress-{t}"));
                let mut rng = Rng::new(17 ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let tickets: Vec<Ticket> = (0..PER_PRODUCER)
                    .map(|i| {
                        let class = class_for(i);
                        let submit = SubmitRequest::new(prompt_for(class, &mut rng))
                            .priority(priority_for(class))
                            .deadline_ms(1e12); // generous: this test is not about shedding
                        let ticket = orch.enqueue(session, submit);
                        orch.advance(5.0);
                        ticket
                    })
                    .collect();
                tickets.into_iter().map(|t| t.wait().expect("no ticket may error")).collect::<Vec<Outcome>>()
            })
        })
        .collect();
    let outcomes: Vec<Outcome> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let total = producers * PER_PRODUCER;
    assert_eq!(outcomes.len(), total);

    // 1. no ticket lost or double-resolved
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    assert_eq!(orch.metrics.counter_value("enqueued"), total as u64);

    // 2. request ids unique under contention
    let mut ids: Vec<u64> = outcomes.iter().map(|o| o.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total, "request ids must be unique");

    // 3. exactly one audit entry per enqueued request, ids matching
    assert_eq!(orch.audit.len(), total);
    let mut audit_ids: Vec<u64> = orch.audit.entries().iter().map(|e| e.request_id).collect();
    audit_ids.sort_unstable();
    audit_ids.dedup();
    assert_eq!(audit_ids, ids, "audit trail must cover exactly the enqueued ids");

    // 4. ledger equals Σ costs, per user and global
    let expected_total: f64 = outcomes.iter().map(|o| o.cost).sum();
    let tolerance = 1e-9 * (1.0 + expected_total.abs());
    assert!(
        (orch.ledger.total() - expected_total).abs() < tolerance,
        "ledger total {} != outcome sum {}",
        orch.ledger.total(),
        expected_total
    );
    let user_of: HashMap<u64, String> = orch.audit.entries().into_iter().map(|e| (e.request_id, e.user)).collect();
    for t in 0..producers {
        let user = format!("qstress-{t}");
        let expected_user: f64 =
            outcomes.iter().filter(|o| user_of.get(&o.request_id) == Some(&user)).map(|o| o.cost).sum();
        assert!(
            (orch.ledger.spent(&user) - expected_user).abs() < tolerance,
            "user {user}: ledger {} != outcome sum {}",
            orch.ledger.spent(&user),
            expected_user
        );
    }

    // 5. rejected requests are never charged and always carry a reason
    let entries: HashMap<u64, _> = orch.audit.entries().into_iter().map(|e| (e.request_id, e)).collect();
    for out in &outcomes {
        if out.decision.target().is_none() {
            assert_eq!(out.cost, 0.0, "rejected request {} was charged", out.request_id);
            assert!(entries[&out.request_id].reject_reason.is_some());
        }
    }

    // 6. the trail stays compliance-clean under queue-path contention
    assert!(orch.audit.violations(0.9, 0.9).is_empty(), "privacy constraint violated on the queue path");
}

#[test]
fn bounded_queue_sheds_overflow_with_exactly_one_audit_entry_each() {
    // capacity 8, workers started only after the flood: exactly 24 of the
    // 32 enqueues find the queue full, deterministically
    let orch = stress_orchestrator(602, 8, 2);
    let sessions: Vec<u64> = (0..4).map(|u| orch.open_session(&format!("shedder-{u}"))).collect();
    let tickets: Vec<Ticket> = (0..32)
        .map(|i| orch.enqueue(sessions[i % sessions.len()], SubmitRequest::new("hello world").deadline_ms(1e12)))
        .collect();
    assert_eq!(orch.metrics.counter_value("rejected_queue_full"), 24);
    assert_eq!(orch.queue_depth(), 8);

    Arc::clone(&orch).start_queue();
    let outcomes: Vec<Outcome> = tickets.iter().map(|t| t.wait().unwrap()).collect();
    let shed = outcomes.iter().filter(|o| o.decision.target().is_none()).count();
    assert_eq!(shed, 24, "every overflow enqueue resolves as a shed reject");
    assert_eq!(outcomes.len() - shed, 8, "everything that fit the queue is served");

    // exactly one audit entry per shed request, all flagged as sheds
    assert_eq!(orch.audit.len(), 32);
    let sheds = orch.audit.sheds();
    assert_eq!(sheds.len(), 24);
    let mut shed_ids: Vec<u64> = sheds.iter().map(|e| e.request_id).collect();
    shed_ids.sort_unstable();
    shed_ids.dedup();
    assert_eq!(shed_ids.len(), 24, "one audit entry per shed request");

    // ledger still equals Σ costs under shedding (sheds are free)
    let expected: f64 = outcomes.iter().map(|o| o.cost).sum();
    assert!((orch.ledger.total() - expected).abs() < 1e-9 * (1.0 + expected.abs()));
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
}

#[test]
fn cross_session_corouted_requests_coalesce_into_shared_groups() {
    // 64 identical low-sensitivity requests from 8 different sessions are
    // parked before the (single) worker starts: each drained batch groups
    // co-routed requests ACROSS sessions into shared execute groups
    let orch = stress_orchestrator(603, 1024, 1);
    let sessions: Vec<u64> = (0..8).map(|u| orch.open_session(&format!("batcher-{u}"))).collect();
    let tickets: Vec<Ticket> = (0..64)
        .map(|i| orch.enqueue(sessions[i % sessions.len()], SubmitRequest::new("hello world").deadline_ms(1e12)))
        .collect();
    assert_eq!(orch.queue_depth(), 64);
    Arc::clone(&orch).start_queue();
    let outcomes: Vec<Outcome> = tickets.iter().map(|t| t.wait().unwrap()).collect();
    let served = outcomes.iter().filter(|o| o.decision.target().is_some()).count();
    assert!(served > 0);

    // coalescing evidence: strictly fewer execute groups than requests, and
    // at least one group held multiple cross-session requests (on the Real
    // backend each such group is one `execute_batch` call; the Sim backend
    // records the same grouping through these metrics)
    let groups = orch.metrics.counter_value("batch_groups");
    assert!(groups > 0);
    assert!(groups < outcomes.len() as u64, "no coalescing happened: {groups} groups for {} requests", outcomes.len());
    let sizes = orch.metrics.histogram("batch_group_size").unwrap();
    assert!(sizes.max() >= 2.0, "no group held more than one request (max {})", sizes.max());
    assert_eq!(orch.audit.len(), 64);
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
}

#[test]
fn primary_requests_drain_ahead_of_burstable() {
    // park burstable arrivals first, then primary ones; a single worker
    // must still serve every primary request before any burstable one
    let orch = stress_orchestrator(604, 1024, 1);
    let s = orch.open_session("prioritizer");
    let enqueue = |prompt: &str, tier: PriorityTier| {
        let tickets: Vec<Ticket> =
            (0..4).map(|_| orch.enqueue(s, SubmitRequest::new(prompt).priority(tier).deadline_ms(1e12))).collect();
        tickets
    };
    let burstable = enqueue("hello world", PriorityTier::Burstable);
    let primary = enqueue("patient john doe ssn 123-45-6789", PriorityTier::Primary);
    Arc::clone(&orch).start_queue();
    let primary_ids: Vec<u64> = primary.iter().map(|t| t.wait().unwrap().request_id).collect();
    let burstable_ids: Vec<u64> = burstable.iter().map(|t| t.wait().unwrap().request_id).collect();

    // audit entries are appended in drain order: every primary id must
    // appear before every burstable id
    let order: Vec<u64> = orch.audit.entries().iter().map(|e| e.request_id).collect();
    let pos = |id: &u64| order.iter().position(|x| x == id).expect("audited");
    let last_primary = primary_ids.iter().map(pos).max().unwrap();
    let first_burstable = burstable_ids.iter().map(pos).min().unwrap();
    assert!(
        last_primary < first_burstable,
        "primary must drain first: primary <= {last_primary}, burstable from {first_burstable}, order {order:?}"
    );
}
