//! Trace ↔ lifecycle consistency under mixed concurrent traffic.
//!
//! With sampling forced wide open (head rate 1.0), every ticket resolved
//! through the queue path must leave exactly one complete trace in the
//! sink's ring, and that trace must agree with the rest of the telemetry:
//! - one kept trace per resolution, every trace id unique,
//! - the root span is closed by a terminal whose outcome/reason pair is
//!   drawn from the typed [`Resolution`] vocabulary,
//! - every child span nests inside the root's interval and hangs off the
//!   root (flat tree, no dangling parents),
//! - the analytics ring and the trace ring name the same trace ids with
//!   the same outcome/reason pairs — the correlation contract,
//! - all 15 [`Resolution`] variants (plus the out-of-band
//!   `failed/unknown_session` pair) close traces, and the tail policy
//!   keeps every non-served trace even at head rate 0.
//!
//! Producer count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, Outcome, Resolution, SubmitRequest, Ticket};
use islandrun::substrate::trace::{priority_for, prompt_for};
use islandrun::telemetry::{CompletedTrace, TraceConfig, TraceSink};
use islandrun::util::Rng;

const PER_PRODUCER: usize = 30;
const PRE_CANCELLED: usize = 6;
const INVALID: usize = 3;

fn producers() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
}

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // admission policy is not under test; sampling is forced wide open so
    // the one-trace-per-resolution invariant is exact, not probabilistic
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.queue_capacity = 100_000;
    cfg.serve_workers = 4;
    cfg.trace_enabled = true;
    cfg.trace_head_rate = 1.0;
    cfg.trace_ring_capacity = 100_000;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

fn assert_well_formed(t: &CompletedTrace) {
    assert_eq!(t.root.name, "request");
    assert!(t.root.parent.is_none(), "locally-minted roots have no remote parent");
    assert!(t.root.end_ms >= t.root.start_ms, "terminal must close the root: {t:?}");
    assert!(
        Resolution::ALL.iter().any(|r| (r.class(), r.reason()) == (t.outcome, t.reason))
            || (t.outcome, t.reason) == ("failed", "unknown_session"),
        "({}, {}) is outside the terminal vocabulary",
        t.outcome,
        t.reason
    );
    for s in &t.spans {
        assert_eq!(s.parent, Some(t.root.id), "child spans hang off the root: {t:?}");
        assert!(
            s.start_ms >= t.root.start_ms && s.end_ms <= t.root.end_ms,
            "span {} [{}, {}] escapes root [{}, {}]",
            s.name,
            s.start_ms,
            s.end_ms,
            t.root.start_ms,
            t.root.end_ms
        );
        assert!(s.end_ms >= s.start_ms, "span {} runs backwards", s.name);
    }
}

#[test]
fn every_resolved_ticket_leaves_exactly_one_complete_trace() {
    let producers = producers();
    let orch = orchestrator(733);

    // --- phase 0: parked tickets cancelled before any worker exists ------
    let pre_session = orch.open_session("precancel");
    let pre_cancelled: Vec<Ticket> = (0..PRE_CANCELLED)
        .map(|_| {
            let t = orch.enqueue(pre_session, SubmitRequest::new("hello world").deadline_ms(1e12));
            t.cancel();
            t
        })
        .collect();

    // --- phase 1: queued tickets from many threads, valid and degenerate -
    Arc::clone(&orch).start_queue();
    let outcomes: Arc<Mutex<Vec<Outcome>>> = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..producers)
        .map(|p| {
            let orch = Arc::clone(&orch);
            let outcomes = Arc::clone(&outcomes);
            std::thread::spawn(move || {
                let session = orch.open_session(&format!("traced-{p}"));
                let mut rng = Rng::new(29 ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut tickets = Vec::new();
                for i in 0..PER_PRODUCER {
                    let class = class_for(i);
                    let req = SubmitRequest::new(prompt_for(class, &mut rng))
                        .priority(priority_for(class))
                        .deadline_ms(1e12);
                    tickets.push(orch.enqueue(session, req));
                    orch.advance(5.0);
                }
                for _ in 0..INVALID {
                    tickets.push(orch.enqueue(session, SubmitRequest::new("degenerate").max_new_tokens(0)));
                }
                let local: Vec<Outcome> =
                    tickets.into_iter().map(|t| t.wait().expect("no ticket may be lost")).collect();
                outcomes.lock().unwrap().extend(local);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut outcomes = Arc::try_unwrap(outcomes).expect("workers joined").into_inner().unwrap();
    outcomes.extend(pre_cancelled.iter().map(|t| t.wait().expect("pre-cancelled tickets resolve")));

    let total = producers * (PER_PRODUCER + INVALID) + PRE_CANCELLED;
    assert_eq!(outcomes.len(), total);

    // --- invariant 1: one kept trace per resolution, ids unique ----------
    assert_eq!(orch.traces.started(), total as u64, "every enqueue opens exactly one root");
    assert_eq!(orch.traces.kept(), total as u64, "head rate 1.0 keeps every trace");
    assert_eq!(orch.traces.sampled_out(), 0);
    let traces = orch.traces.snapshot();
    assert_eq!(traces.len(), total, "the ring was sized to hold the whole run");
    let mut ids: Vec<String> = traces.iter().map(|t| t.trace_id.to_hex()).collect();
    ids.sort();
    let before = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), before, "trace ids must be unique across the run");

    // --- invariant 2: every trace is a well-formed closed tree -----------
    for t in &traces {
        assert_well_formed(t);
    }
    let reasons: BTreeSet<(&str, &str)> = traces.iter().map(|t| (t.outcome, t.reason)).collect();
    for pair in [("served", "ok"), ("shed", "invalid_request"), ("cancelled", "while_queued")] {
        assert!(reasons.contains(&pair), "the mix must exercise {pair:?}, got {reasons:?}");
    }

    // --- invariant 3: traces and analytics events correlate 1:1 ----------
    assert_eq!(orch.analytics.dropped(), 0, "the mix must fit the analytics ring");
    let events = orch.analytics.snapshot();
    assert_eq!(events.len(), total, "one analytics event per resolution");
    let by_id: BTreeMap<String, (&str, &str)> =
        traces.iter().map(|t| (t.trace_id.to_hex(), (t.outcome, t.reason))).collect();
    for ev in &events {
        let id = ev.trace_id.as_deref().expect("kept traces stamp their id on the event");
        let &(outcome, reason) = by_id.get(id).expect("event names a kept trace");
        assert_eq!((ev.outcome, ev.reason), (outcome, reason), "event and trace agree on the terminal");
    }
    let event_ids: BTreeSet<&str> = events.iter().filter_map(|e| e.trace_id.as_deref()).collect();
    assert_eq!(event_ids.len(), total, "no two events share a trace");

    // --- lifecycle bookkeeping stays intact under the mix ----------------
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    assert_eq!(orch.audit.len(), total, "one audit entry per consumed id");
}

/// islandlint R6 (`span-discipline`) companion: every [`Resolution`]
/// variant is driven through `end_request_span` explicitly, so the end
/// reasons the serving paths emit are all proven representable and kept.
#[test]
fn all_fifteen_resolution_variants_close_traces() {
    let sink = TraceSink::new(TraceConfig { enabled: true, head_rate: 1.0, ring_capacity: 64 }, 7);
    for (i, r) in Resolution::ALL.iter().enumerate() {
        let ctx = TraceSink::start(&sink, i as f64, None);
        ctx.set_user("variant");
        let hex = ctx.end_request_span(i as f64 + 1.0, r.class(), r.reason());
        assert!(hex.is_some(), "head-kept trace must report its id for {r:?}");
    }
    assert_eq!(sink.kept(), 15);
    let kept: BTreeSet<(&str, &str)> = sink.snapshot().iter().map(|t| (t.outcome, t.reason)).collect();
    let expected: BTreeSet<(&str, &str)> = Resolution::ALL.iter().map(|r| (r.class(), r.reason())).collect();
    assert_eq!(kept, expected, "all 15 variants must appear as end reasons");
}

#[test]
fn tail_policy_keeps_every_non_served_trace_at_head_rate_zero() {
    let sink = TraceSink::new(TraceConfig { enabled: true, head_rate: 0.0, ring_capacity: 64 }, 7);
    for (i, r) in Resolution::ALL.iter().enumerate() {
        let ctx = TraceSink::start(&sink, i as f64, None);
        ctx.end_request_span(i as f64 + 1.0, r.class(), r.reason());
    }
    // the single Served variant is head-sampled out; every failure is kept
    assert_eq!(sink.kept(), 14, "tail sampling must keep all non-served traces");
    assert_eq!(sink.sampled_out(), 1);
    assert!(sink.snapshot().iter().all(|t| t.outcome != "served"));
}

#[test]
fn front_door_sheds_leave_complete_traces() {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.queue_capacity = 1;
    cfg.trace_enabled = true;
    cfg.trace_head_rate = 1.0;
    cfg.trace_ring_capacity = 64;
    let fleet = Fleet::new(preset_personal_group(), 91);
    let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 91));
    let session = orch.open_session("front-door");
    // no workers: the first enqueue parks, the rest shed queue_full
    let _parked = orch.enqueue(session, SubmitRequest::new("parks in the queue").deadline_ms(1e12));
    for _ in 0..2 {
        let t = orch.enqueue(session, SubmitRequest::new("finds the queue full").deadline_ms(1e12));
        let out = t.wait().expect("queue-full sheds resolve immediately");
        assert_eq!(out.resolution.reason(), "queue_full");
    }
    // unknown session: refused before a request id exists, still traced
    let t = orch.enqueue(9_999, SubmitRequest::new("no such session"));
    assert!(t.wait().is_err());
    let traces = orch.traces.snapshot();
    let sheds: Vec<&CompletedTrace> = traces.iter().filter(|t| t.reason == "queue_full").collect();
    assert_eq!(sheds.len(), 2, "every queue-full shed leaves a kept trace");
    for t in &sheds {
        assert_well_formed(t);
        assert!(
            t.spans.iter().any(|s| s.name == "admission"),
            "queue-full sheds passed admission first: {t:?}"
        );
        assert_eq!(t.user, "front-door");
    }
    assert!(
        traces.iter().any(|t| (t.outcome, t.reason) == ("failed", "unknown_session")),
        "the unknown-session refusal closes its trace out-of-band: {traces:?}"
    );
}
