//! Concurrency stress: 16 threads x 100 requests through `Arc<Orchestrator>`.
//!
//! Pins the serving-core invariants that must hold under contention,
//! independent of interleaving:
//! - request ids are globally unique (atomic allocation),
//! - the audit log holds exactly one entry per admitted submission,
//! - ledger totals equal the sum of per-request costs (per user and global),
//! - the metrics counters partition admitted work into served + rejected.
//!
//! Thread count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job (liveness
//! races sometimes only reproduce under optimized timing).

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::run_closed_loop;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator};

const PER_THREAD: usize = 100;

fn threads() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

fn stress_orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the stress test exercises the pipeline, not admission policy: a
    // saturating rate limit or budget would turn submissions away and hide
    // the invariants under test
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

#[test]
fn sixteen_threads_hundred_requests_invariants() {
    let threads = threads();
    let orch = stress_orchestrator(101);
    let report = run_closed_loop(&orch, threads, PER_THREAD, 3);
    let total = threads * PER_THREAD;

    // nothing refused: with the limiter and budget out of the way every
    // submission must come back as an Outcome
    assert_eq!(report.errors, 0, "unexpected submit errors");
    assert_eq!(report.outcomes.len(), total);

    // 1. request ids unique
    let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total, "request ids must be unique under contention");

    // 2. exactly one audit entry per submitted request, ids matching
    assert_eq!(orch.audit.len(), total);
    let mut audit_ids: Vec<u64> = orch.audit.entries().iter().map(|e| e.request_id).collect();
    audit_ids.sort_unstable();
    audit_ids.dedup();
    assert_eq!(audit_ids, ids, "audit trail must cover exactly the submitted ids");

    // 3. ledger totals match the sum of per-request costs
    let expected_total: f64 = report.outcomes.iter().map(|o| o.cost).sum();
    let tolerance = 1e-9 * (1.0 + expected_total.abs());
    assert!(
        (orch.ledger.total() - expected_total).abs() < tolerance,
        "ledger total {} != outcome sum {}",
        orch.ledger.total(),
        expected_total
    );
    let user_of: std::collections::HashMap<u64, String> =
        orch.audit.entries().into_iter().map(|e| (e.request_id, e.user)).collect();
    for t in 0..threads {
        let user = format!("loadgen-{t}");
        let expected_user: f64 = report
            .outcomes
            .iter()
            .filter(|o| user_of.get(&o.request_id) == Some(&user))
            .map(|o| o.cost)
            .sum();
        assert!(
            (orch.ledger.spent(&user) - expected_user).abs() < tolerance,
            "user {user}: ledger {} != outcome sum {}",
            orch.ledger.spent(&user),
            expected_user
        );
    }

    // 4. metrics partition admitted work
    let served = orch.metrics.counter_value("requests_served");
    let rejected = orch.metrics.counter_value("rejected_fail_closed");
    assert_eq!(served as usize, report.served());
    assert_eq!(rejected as usize, report.rejected());
    assert_eq!((served + rejected) as usize, total);
    assert_eq!(orch.metrics.counter_value("rate_limited"), 0);

    // 5. the trail stays compliance-clean even under contention
    assert!(orch.audit.violations(0.9, 0.9).is_empty(), "privacy constraint violated under load");
}

#[test]
fn stress_run_is_repeatable() {
    // two runs with the same seeds produce the same id SET sizes and the
    // same audit cardinality (interleavings differ; the invariants do not)
    for _ in 0..2 {
        let orch = stress_orchestrator(202);
        let report = run_closed_loop(&orch, 8, 50, 9);
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 400);
        assert_eq!(orch.audit.len(), 400);
    }
}
