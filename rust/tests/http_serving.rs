//! Loopback integration tests for the HTTP/1.1 network serving surface:
//! every scenario drives a real `TcpListener` on `127.0.0.1:0` through the
//! crate's own minimal client, so the bytes on the wire are the bytes the
//! server parses.
//!
//! Pinned invariants:
//! - submit → poll round-trips a typed resolution; submit → stream relays
//!   token events to a terminal record; cancel is cooperative,
//! - the boundary is fail-closed: 401 before any body interpretation (no
//!   request id, no audit entry), 429 off the per-key token bucket with
//!   the `rejected_rate_limited` counter bumped, 400 + exactly one audit
//!   entry for malformed/invalid JSON,
//! - unknown and TTL-reaped tickets answer 404 (`tickets_reaped` counts),
//! - ticket ids are scoped to the submitting key: another tenant's poll,
//!   stream, or cancel answers 404 exactly like an unknown id,
//! - framing ambiguities (Transfer-Encoding, duplicate Content-Length)
//!   are rejected fail-closed; oversized bodies answer 413,
//! - a mid-stream client disconnect cancels the request cooperatively and
//!   still leaves exactly one audit entry,
//! - graceful drain loses no admitted ticket and refuses new connections,
//! - `/metrics` is a lintable Prometheus exposition carrying the per-route
//!   http series; `/healthz` reports Lighthouse liveness,
//! - tracing rides the wire: a valid inbound `traceparent` is adopted (and
//!   echoed on the submit response), a malformed one fails open to a fresh
//!   root, and `GET /v1/traces/:id` serves the completed span tree scoped
//!   to the submitting key — a foreign key misses like an unknown id.
//!
//! Producer count for the concurrency scenario is overridable via
//! `ISLANDRUN_STRESS_THREADS` so the CI release-mode stress job can push
//! harder than the debug test job.

use std::sync::Arc;
use std::time::{Duration, Instant};

use islandrun::agents::mist::Mist;
use islandrun::config::json::Json;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::run_open_loop_http;
use islandrun::islands::Fleet;
use islandrun::server::http::client::HttpClient;
use islandrun::server::{Backend, HttpConfig, HttpServer, Orchestrator};
use islandrun::telemetry::lint_exposition;

const KEY: &str = "test-key";
const POLL_DEADLINE: Duration = Duration::from_secs(30);

fn orchestrator() -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // these tests exercise the wire surface; admission policy is opened
    // wide except where a scenario says otherwise (the 429 test tightens
    // the HTTP front door instead)
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), 77);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 77))
}

fn wide_open() -> HttpConfig {
    HttpConfig { rate_per_sec: 1e9, burst: 1e9, ..HttpConfig::default() }
}

fn start(config: HttpConfig) -> (Arc<Orchestrator>, HttpServer) {
    let orch = orchestrator();
    let grants = vec![(KEY.to_string(), "http-tester".to_string())];
    let server = HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, config).expect("bind loopback");
    (orch, server)
}

fn submit_body(prompt: &str, max_new_tokens: f64) -> Json {
    Json::obj(vec![
        ("prompt", Json::str(prompt)),
        ("max_new_tokens", Json::num(max_new_tokens)),
        ("deadline_ms", Json::num(1e12)),
    ])
}

fn submit(client: &mut HttpClient, body: &Json) -> u64 {
    let resp = client.request("POST", "/v1/submit", Some(KEY), Some(body)).expect("submit");
    assert_eq!(resp.status, 200, "submit refused: {}", String::from_utf8_lossy(&resp.body));
    resp.json().expect("submit response is JSON").get("ticket").as_i64().expect("ticket id") as u64
}

/// Poll `GET /v1/tickets/:id` until `done` and return the full response
/// JSON (`outcome` or `error` key set).
fn poll_until_done(client: &mut HttpClient, id: u64) -> Json {
    let path = format!("/v1/tickets/{id}");
    let give_up = Instant::now() + POLL_DEADLINE;
    loop {
        let resp = client.request("GET", &path, Some(KEY), None).expect("poll");
        assert_eq!(resp.status, 200, "poll failed: {}", String::from_utf8_lossy(&resp.body));
        let json = resp.json().expect("poll response is JSON");
        if json.get("done").as_bool() == Some(true) {
            return json;
        }
        assert!(Instant::now() < give_up, "ticket {id} never resolved");
        std::thread::sleep(Duration::from_micros(300));
    }
}

#[test]
fn submit_then_poll_round_trips_a_typed_resolution() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let id = submit(&mut client, &submit_body("hello over the wire", 8.0));
    let done = poll_until_done(&mut client, id);
    let out = done.get("outcome");
    assert_eq!(out.get("outcome").as_str(), Some("served"), "wide-open server must serve: {done:?}");
    assert!(out.get("island").as_str().unwrap_or("").starts_with("island-"));
    assert!(out.get("tokens_generated").as_i64().unwrap_or(0) > 0);
    let request_id = out.get("request_id").as_i64().expect("request id") as u64;
    assert!(orch.audit.contains(request_id));
    assert_eq!(orch.audit.len(), 1, "exactly one audit entry per request");
    server.shutdown();
}

#[test]
fn stream_relays_token_events_to_a_terminal_record() {
    let (_orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let id = submit(&mut client, &submit_body("stream me some tokens", 6.0));
    let (status, events) = client.stream_events(&format!("/v1/stream/{id}"), Some(KEY)).unwrap();
    assert_eq!(status, 200);
    assert!(events.len() >= 2, "at least one token event plus the terminal record: {events:?}");
    assert_eq!(events.first().map(|(n, _)| n.as_str()), Some("first"));
    assert_eq!(events.last().map(|(n, _)| n.as_str()), Some("done"));
    // the stream keeps the connection reusable: poll the same ticket on it
    let done = poll_until_done(&mut client, id);
    assert_eq!(done.get("outcome").get("outcome").as_str(), Some("served"));
    server.shutdown();
}

#[test]
fn cancel_endpoint_cancels_cooperatively() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // a decode long enough that the cancel always lands mid-flight
    let id = submit(&mut client, &submit_body("long running decode", 5_000_000.0));
    let resp = client.request("POST", &format!("/v1/tickets/{id}/cancel"), Some(KEY), None).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().unwrap().get("cancelled").as_bool(), Some(true));
    let done = poll_until_done(&mut client, id);
    assert_eq!(done.get("outcome").get("outcome").as_str(), Some("cancelled"), "{done:?}");
    assert_eq!(orch.audit.len(), 1, "cancelled requests still audit exactly once");
    server.shutdown();
}

#[test]
fn unauthenticated_requests_are_refused_before_any_side_effect() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let body = submit_body("should never be read", 4.0);
    for key in [None, Some("wrong-key")] {
        let resp = client.request("POST", "/v1/submit", key, Some(&body)).unwrap();
        assert_eq!(resp.status, 401);
        for path in ["/v1/tickets/1", "/v1/stream/1"] {
            assert_eq!(client.request("GET", path, key, None).unwrap().status, 401);
        }
        assert_eq!(client.request("POST", "/v1/tickets/1/cancel", key, None).unwrap().status, 401);
    }
    assert!(orch.audit.is_empty(), "401s must not consume request ids or audit entries");
    assert_eq!(server.tickets_registered(), 0);
    server.shutdown();
}

#[test]
fn rate_limited_submits_answer_429_and_count() {
    // burst of exactly 1 and a refill slow enough to never matter
    let (orch, server) = start(HttpConfig { rate_per_sec: 1e-9, burst: 1.0, ..HttpConfig::default() });
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let id = submit(&mut client, &submit_body("first one through", 4.0));
    let resp = client.request("POST", "/v1/submit", Some(KEY), Some(&submit_body("bucket is dry", 4.0))).unwrap();
    assert_eq!(resp.status, 429);
    assert_eq!(resp.json().unwrap().get("reason").as_str(), Some("rate_limited"));
    assert_eq!(orch.metrics.counter_value("rejected_rate_limited"), 1);
    // only the admitted request ever reaches the orchestrator
    poll_until_done(&mut client, id);
    assert_eq!(orch.audit.len(), 1);
    server.shutdown();
}

#[test]
fn malformed_and_invalid_submits_are_fail_closed_400s_with_one_audit_entry() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let cases: [&[u8]; 3] = [
        b"{not json",                                // unparseable
        br#"{"prompt": "x", "max_new_tokens": 0}"#,  // parses, fails validate()
        br#"{"prompt": "x", "turbo": true}"#,        // unknown field
    ];
    for (i, &body) in cases.iter().enumerate() {
        let resp = client.request_raw("POST", "/v1/submit", Some(KEY), Some(body)).unwrap();
        assert_eq!(resp.status, 400, "case {i}");
        let json = resp.json().expect("400 body is JSON");
        assert!(json.get("error").as_str().is_some());
        let request_id = json.get("request_id").as_i64().expect("400 consumed a request id") as u64;
        assert!(orch.audit.contains(request_id), "case {i} must audit");
        assert_eq!(orch.audit.len(), i + 1, "exactly one audit entry per rejected submit");
    }
    assert_eq!(server.tickets_registered(), 0, "no ticket for a rejected submit");
    server.shutdown();
}

#[test]
fn unknown_and_reaped_tickets_answer_404() {
    let (orch, server) = start(HttpConfig { ticket_ttl_ms: 25, rate_per_sec: 1e9, burst: 1e9, ..HttpConfig::default() });
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let resp = client.request("GET", "/v1/tickets/999", Some(KEY), None).unwrap();
    assert_eq!(resp.status, 404, "never-issued id");
    let id = submit(&mut client, &submit_body("short lived", 4.0));
    poll_until_done(&mut client, id);
    std::thread::sleep(Duration::from_millis(120)); // past the 25ms TTL
    let resp = client.request("GET", &format!("/v1/tickets/{id}"), Some(KEY), None).unwrap();
    assert_eq!(resp.status, 404, "resolved ticket past its TTL is reaped");
    assert!(orch.metrics.counter_value("tickets_reaped") >= 1);
    assert_eq!(server.tickets_registered(), 0);
    server.shutdown();
}

#[test]
fn tickets_are_scoped_to_the_submitting_key() {
    let orch = orchestrator();
    let grants = vec![
        ("key-a".to_string(), "tenant-a".to_string()),
        ("key-b".to_string(), "tenant-b".to_string()),
    ];
    let server = HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, wide_open()).expect("bind loopback");
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // a decode long enough that the ticket is still live while B probes it
    let body = submit_body("tenant A's private request", 5_000_000.0);
    let resp = client.request("POST", "/v1/submit", Some("key-a"), Some(&body)).unwrap();
    assert_eq!(resp.status, 200);
    let id = resp.json().unwrap().get("ticket").as_i64().unwrap() as u64;
    // ids are sequential: B presenting a valid key must still miss, and
    // miss exactly like an unknown id (404, no existence oracle)
    let poll = format!("/v1/tickets/{id}");
    assert_eq!(client.request("GET", &poll, Some("key-b"), None).unwrap().status, 404);
    assert_eq!(client.request("GET", &format!("/v1/stream/{id}"), Some("key-b"), None).unwrap().status, 404);
    assert_eq!(client.request("POST", &format!("/v1/tickets/{id}/cancel"), Some("key-b"), None).unwrap().status, 404);
    // B's probes had no side effect: A still owns a live, pollable ticket
    let resp = client.request("GET", &poll, Some("key-a"), None).unwrap();
    assert_eq!(resp.status, 200, "the owner still reaches the ticket");
    assert_eq!(resp.json().unwrap().get("done").as_bool(), Some(false), "B's cancel must not have landed");
    // A cancels its own ticket and reads the terminal resolution
    assert_eq!(client.request("POST", &format!("/v1/tickets/{id}/cancel"), Some("key-a"), None).unwrap().status, 200);
    let give_up = Instant::now() + POLL_DEADLINE;
    loop {
        let json = client.request("GET", &poll, Some("key-a"), None).unwrap().json().unwrap();
        if json.get("done").as_bool() == Some(true) {
            assert_eq!(json.get("outcome").get("outcome").as_str(), Some("cancelled"));
            break;
        }
        assert!(Instant::now() < give_up, "owner's cancel never resolved");
        std::thread::sleep(Duration::from_micros(300));
    }
    server.shutdown();
}

/// Poll `GET /v1/traces/:id` until the completed trace is kept (the
/// terminal fires on a worker thread, so the tree can trail the ticket's
/// resolution by a beat) and return it.
fn fetch_trace(client: &mut HttpClient, key: &str, trace_id: &str) -> Json {
    let path = format!("/v1/traces/{trace_id}");
    let give_up = Instant::now() + POLL_DEADLINE;
    loop {
        let resp = client.request("GET", &path, Some(key), None).expect("trace fetch");
        if resp.status == 200 {
            return resp.json().expect("trace response is JSON");
        }
        assert_eq!(resp.status, 404, "trace fetch may only miss, never error");
        assert!(Instant::now() < give_up, "trace {trace_id} never appeared");
        std::thread::sleep(Duration::from_micros(300));
    }
}

#[test]
fn submit_adopts_inbound_traceparent_and_serves_the_span_tree() {
    let (_orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // client-minted W3C context: the server must join it, not start fresh
    let tp = "00-0123456789abcdef0123456789abcdef-00f067aa0ba902b7-01";
    let resp = client
        .request_traced("POST", "/v1/submit", Some(KEY), Some(&submit_body("trace me over the wire", 6.0)), Some(tp))
        .unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let json = resp.json().unwrap();
    let id = json.get("ticket").as_i64().expect("ticket id") as u64;
    let trace_id = json.get("trace_id").as_str().expect("submit returns the trace id").to_string();
    assert_eq!(trace_id, "0123456789abcdef0123456789abcdef", "valid inbound traceparent is adopted");
    let echoed = resp.header("traceparent").expect("submit echoes traceparent").to_string();
    assert!(echoed.contains(&trace_id), "echoed header carries the adopted trace id: {echoed}");
    poll_until_done(&mut client, id);
    let tree = fetch_trace(&mut client, KEY, &trace_id);
    assert_eq!(tree.get("trace_id").as_str(), Some(trace_id.as_str()));
    assert_eq!(tree.get("outcome").as_str(), Some("served"), "{tree:?}");
    assert_eq!(tree.get("user").as_str(), Some("http-tester"));
    let root = tree.get("root");
    assert!(root.get("span_id").as_str().is_some());
    let spans = tree.get("spans").as_arr().expect("child spans");
    for name in ["queue_wait", "route", "decode"] {
        assert!(
            spans.iter().any(|s| s.get("name").as_str() == Some(name)),
            "{name} span missing from {tree:?}"
        );
    }
    // every child nests inside the request root's interval
    let (t0, t1) = (root.get("start_ms").as_f64().unwrap(), root.get("end_ms").as_f64().unwrap());
    for s in spans {
        assert!(s.get("start_ms").as_f64().unwrap() >= t0 && s.get("end_ms").as_f64().unwrap() <= t1);
    }
    server.shutdown();
}

#[test]
fn trace_lookup_is_owner_scoped_and_malformed_traceparent_fails_open() {
    let orch = orchestrator();
    let grants =
        vec![("key-a".to_string(), "tenant-a".to_string()), ("key-b".to_string(), "tenant-b".to_string())];
    let server = HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, wide_open()).expect("bind loopback");
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // garbage traceparent: fail open to a fresh root, never a refusal
    let resp = client
        .request_traced("POST", "/v1/submit", Some("key-a"), Some(&submit_body("private trace", 4.0)), Some("not-a-traceparent"))
        .unwrap();
    assert_eq!(resp.status, 200, "malformed traceparent must not refuse the submit");
    let json = resp.json().unwrap();
    let id = json.get("ticket").as_i64().unwrap() as u64;
    let trace_id = json.get("trace_id").as_str().expect("fresh root minted").to_string();
    assert_eq!(trace_id.len(), 32, "canonical 128-bit hex id");
    let give_up = Instant::now() + POLL_DEADLINE;
    loop {
        let json = client.request("GET", &format!("/v1/tickets/{id}"), Some("key-a"), None).unwrap().json().unwrap();
        if json.get("done").as_bool() == Some(true) {
            break;
        }
        assert!(Instant::now() < give_up, "ticket never resolved");
        std::thread::sleep(Duration::from_micros(300));
    }
    let tree = fetch_trace(&mut client, "key-a", &trace_id);
    assert_eq!(tree.get("user").as_str(), Some("tenant-a"));
    let path = format!("/v1/traces/{trace_id}");
    // a foreign key misses exactly like an unknown id — no existence oracle
    assert_eq!(client.request("GET", &path, Some("key-b"), None).unwrap().status, 404);
    assert_eq!(
        client.request("GET", "/v1/traces/ffffffffffffffffffffffffffffffff", Some("key-a"), None).unwrap().status,
        404
    );
    assert_eq!(client.request("GET", &path, None, None).unwrap().status, 401, "traces require auth");
    assert_eq!(client.request("POST", &path, Some("key-a"), None).unwrap().status, 405);
    server.shutdown();
}

/// Write raw bytes at the server and return the status line's code — for
/// framing-level requests the well-behaved client cannot emit.
fn raw_status(addr: std::net::SocketAddr, request: &str) -> u16 {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    let status = text.split_whitespace().nth(1).expect("status line");
    status.parse().expect("numeric status")
}

#[test]
fn framing_ambiguities_are_rejected_fail_closed() {
    let (orch, server) = start(wide_open());
    // chunked upload: accepting it as zero-length would smuggle the body
    // bytes as the next pipelined request
    assert_eq!(
        raw_status(server.addr(), "POST /v1/submit HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
        400
    );
    // duplicate Content-Length: RFC 9112 §6.3 framing ambiguity
    assert_eq!(
        raw_status(server.addr(), "POST /v1/submit HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\n"),
        400
    );
    // over the body cap: the dedicated status, distinguishable from 400
    let oversized = format!("POST /v1/submit HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 2 * 1024 * 1024);
    assert_eq!(raw_status(server.addr(), &oversized), 413);
    assert!(orch.audit.is_empty(), "framing rejections happen before any request id is consumed");
    server.shutdown();
}

#[test]
fn mid_stream_disconnect_cancels_the_request() {
    let (orch, server) = start(wide_open());
    let mut submitter = HttpClient::connect(server.addr()).unwrap();
    let id = submit(&mut submitter, &submit_body("stream to be abandoned", 5_000_000.0));
    let mut watcher = HttpClient::connect(server.addr()).unwrap();
    let status = watcher.start_stream(&format!("/v1/stream/{id}"), Some(KEY)).unwrap();
    assert_eq!(status, 200);
    let first = watcher.read_event().unwrap().expect("at least one event before the disconnect");
    assert_eq!(first.0, "first");
    watcher.disconnect();
    drop(watcher);
    // the server's next relay write fails, which must cancel cooperatively
    let done = poll_until_done(&mut submitter, id);
    assert_eq!(done.get("outcome").get("outcome").as_str(), Some("cancelled"), "{done:?}");
    let request_id = done.get("outcome").get("request_id").as_i64().unwrap() as u64;
    assert!(orch.audit.contains(request_id));
    assert_eq!(orch.audit.len(), 1, "disconnect-cancel audits exactly once");
    server.shutdown();
}

#[test]
fn graceful_drain_loses_no_admitted_ticket() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    const N: usize = 16;
    for i in 0..N {
        submit(&mut client, &submit_body(&format!("drain me {i}"), 4.0));
    }
    let addr = server.addr();
    server.shutdown();
    // the orchestrator outlives the server: every admitted ticket resolves
    let give_up = Instant::now() + Duration::from_secs(10);
    while orch.audit.len() < N {
        assert!(Instant::now() < give_up, "drain lost tickets: {}/{N} audited", orch.audit.len());
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(orch.audit.len(), N);
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    assert!(std::net::TcpStream::connect(addr).is_err(), "drained server must refuse new connections");
}

#[test]
fn metrics_endpoint_is_a_lintable_exposition_with_http_series() {
    let (_orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let id = submit(&mut client, &submit_body("observable", 4.0));
    poll_until_done(&mut client, id);
    // unauthenticated scrape, per standard Prometheus practice
    let resp = client.request("GET", "/metrics", None, None).unwrap();
    assert_eq!(resp.status, 200);
    let text = String::from_utf8(resp.body).unwrap();
    lint_exposition(&text).expect("exposition must lint clean");
    for needle in [
        "islandrun_http_requests_total",
        "route=\"submit\"",
        "route=\"ticket\"",
        "islandrun_http_request_ms",
        "islandrun_http_active_connections",
        "islandrun_rejected_rate_limited_total",
        "islandrun_tickets_reaped_total",
    ] {
        assert!(text.contains(needle), "{needle} missing from:\n{text}");
    }
    server.shutdown();
}

#[test]
fn healthz_reports_lighthouse_liveness() {
    let (_orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let resp = client.request("GET", "/healthz", None, None).unwrap();
    assert_eq!(resp.status, 200);
    let json = resp.json().unwrap();
    assert_eq!(json.get("status").as_str(), Some("ok"));
    assert_eq!(json.get("islands").as_i64(), Some(7));
    assert_eq!(json.get("islands_online").as_i64(), Some(7));
    assert_eq!(json.get("draining").as_bool(), Some(false));
    server.shutdown();
}

#[test]
fn routing_errors_answer_without_side_effects() {
    let (orch, server) = start(wide_open());
    let mut client = HttpClient::connect(server.addr()).unwrap();
    assert_eq!(client.request("GET", "/v1/nope", Some(KEY), None).unwrap().status, 404);
    assert_eq!(client.request("GET", "/v1/submit", Some(KEY), None).unwrap().status, 405, "wrong method");
    assert_eq!(client.request("POST", "/metrics", None, None).unwrap().status, 405);
    assert!(orch.audit.is_empty());
    server.shutdown();
}

#[test]
fn concurrent_submitters_lose_nothing_over_the_wire() {
    let producers: usize =
        std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let orch = orchestrator();
    let grants: Vec<(String, String)> =
        (0..8).map(|k| (format!("stress-key-{k}"), format!("http-stress-{k}"))).collect();
    let server = HttpServer::start(Arc::clone(&orch), "127.0.0.1:0", &grants, wide_open()).expect("bind loopback");
    let keys: Vec<String> = grants.iter().map(|(k, _)| k.clone()).collect();
    const PER_PRODUCER: usize = 25;
    let report = run_open_loop_http(server.addr(), &keys, producers, PER_PRODUCER, 42);
    let total = producers * PER_PRODUCER;
    assert_eq!(report.attempted, total);
    assert_eq!(report.errors, 0, "no request may be lost on the wire");
    assert_eq!(report.served + report.rejected, total);
    assert_eq!(orch.audit.len(), total, "exactly one audit entry per wire submission");
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    server.shutdown();
}
