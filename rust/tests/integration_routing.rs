//! Integration: WAVES routing composed with LIGHTHOUSE, TIDE and the fleet
//! simulator — scenario-level behavior from the paper's §I.A and §III.D,
//! plus the policy knobs (deadline, jurisdiction floor, model pin,
//! sensitivity floor) exercised end-to-end through the server surface
//! (`SubmitRequest` → orchestrator → outcome).

use islandrun::agents::lighthouse::Lighthouse;
use islandrun::agents::mist::Mist;
use islandrun::agents::tide::hysteresis::Preference;
use islandrun::agents::tide::monitor::{LoadProgram, MetricsSource};
use islandrun::agents::tide::Tide;
use islandrun::agents::waves::{Decision, IslandState, Waves};
use islandrun::baselines::{all_policies, PolicyDecision};
use islandrun::config::{preset, preset_personal_group, Config};
use islandrun::eval::{run_policy, RunOpts};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::substrate::trace::{healthcare_day, paper_mix};
use islandrun::types::{Island, IslandId, PriorityTier, Request, TrustTier};

fn states_at(cap: f64) -> Vec<IslandState> {
    preset_personal_group()
        .into_iter()
        .map(|island| {
            let c = if island.unbounded() { 1.0 } else { cap };
            IslandState { island, capacity: c, online: true, degraded: false }
        })
        .collect()
}

#[test]
fn lighthouse_feeds_waves_only_online_islands() {
    let lh = Lighthouse::new(1, 500.0, 3);
    for i in preset_personal_group() {
        lh.register_owned(i, 0.0);
    }
    // cloud islands stop heartbeating
    for id in 0..5u32 {
        lh.beat(IslandId(id), 2_000.0);
    }
    lh.tick(2_000.0);
    let islands = lh.islands();
    assert_eq!(islands.len(), 5);
    let waves = Waves::new(Config::default());
    let states: Vec<IslandState> =
        islands.into_iter().map(|island| IslandState { island, capacity: 1.0, online: true, degraded: false }).collect();
    // a burstable low-sensitivity request cannot use (offline) cloud;
    // it must still route somewhere live
    let r = Request::new(1, "what is jax").with_priority(PriorityTier::Burstable);
    let d = waves.route(&r, 0.2, &states, 0.2, Preference::Local, f64::INFINITY);
    let target = d.target().expect("routed to a live island");
    assert!(states.iter().any(|s| s.island.id == target));
}

#[test]
fn tide_preference_flows_into_routing() {
    let mut cfg = Config::default();
    cfg.tide_period_ms = 100;
    let mut tide = Tide::new(&cfg, MetricsSource::synthetic(LoadProgram::constant(0.9)));
    for s in 0..5 {
        tide.tick(s as f64 * 100.0);
    }
    assert_eq!(tide.preference(), Preference::Cloud);
    let waves = Waves::new(cfg);
    let r = Request::new(1, "summarize the platform sync notes").with_priority(PriorityTier::Secondary);
    let d = waves.route(&r, 0.5, &states_at(0.6), tide.capacity(), tide.preference(), f64::INFINITY);
    // with cloud preference and s_r=0.5, private edge (P=0.8) is the target
    let islands = preset_personal_group();
    let t = islands.iter().find(|i| Some(i.id) == d.target()).unwrap();
    assert_ne!(t.tier, TrustTier::Cloud, "P=0.4 cloud fails the 0.5 constraint");
    assert_ne!(t.link, islandrun::types::LinkKind::Loopback, "cloud preference avoids loopback");
}

#[test]
fn healthcare_preset_respects_hipaa_over_full_day() {
    let trace = healthcare_day(2000, 5);
    let mut policy = all_policies(&Config::default()).remove(0); // islandrun
    let st = run_policy(policy.as_mut(), &trace, preset("healthcare").unwrap(), 5, RunOpts::default());
    assert_eq!(st.privacy_violations, 0);
    assert_eq!(st.rejections, 0);
    assert!(st.local_share > 0.15, "PHI work must hold the workstation: {}", st.local_share);
}

#[test]
fn legal_preset_routes_rag_to_firm_server() {
    let specs = preset("legal").unwrap();
    let fleet = Fleet::new(specs.clone(), 6);
    let waves = Waves::new(Config::default());
    let r = Request::new(1, "find precedent about shipping contracts").with_dataset("case_law");
    let d = waves.route(&r, 0.8, &fleet.states(), 1.0, Preference::Local, f64::INFINITY);
    let target = specs.iter().find(|i| Some(i.id) == d.target()).unwrap();
    assert_eq!(target.name, "firm-server");
}

#[test]
fn mixed_workload_all_policies_complete() {
    let trace = paper_mix(500, 9);
    for mut policy in all_policies(&Config::default()) {
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 9, RunOpts::default());
        assert_eq!(st.requests, 500, "{}", st.policy);
        assert!(
            st.rejections + st.latencies_ms.len() == 500,
            "{}: every request must be decided",
            st.policy
        );
    }
}

#[test]
fn mist_agent_feeds_router_constraint() {
    let mist = Mist::heuristic();
    let waves = Waves::new(Config::default());
    let sensitive = Request::new(1, "patient john doe ssn 123-45-6789 dosage review");
    let s_r = mist.analyze(&sensitive).score;
    assert!(s_r >= 0.9);
    let d = waves.route(&sensitive, s_r, &states_at(0.9), 0.9, Preference::Local, f64::INFINITY);
    let islands = preset_personal_group();
    let t = islands.iter().find(|i| Some(i.id) == d.target()).unwrap();
    assert!(t.privacy >= 0.9);
}

#[test]
fn failsafe_vs_reject_distinction() {
    let waves = Waves::new(Config::default());
    // privacy satisfiable, capacity exhausted → failsafe local queue
    let r = Request::new(1, "patient data").with_priority(PriorityTier::Primary);
    match waves.route(&r, 0.9, &states_at(0.0), 0.0, Preference::Local, f64::INFINITY) {
        Decision::FailsafeLocal(rt) => assert_eq!(rt.target_privacy, 1.0),
        other => panic!("expected failsafe, got {other:?}"),
    }
    // privacy unsatisfiable → reject regardless of capacity
    let cloud_only: Vec<IslandState> = states_at(1.0).into_iter().filter(|s| s.island.privacy < 0.5).collect();
    match waves.route(&r, 0.9, &cloud_only, 1.0, Preference::Local, f64::INFINITY) {
        Decision::Reject { .. } => {}
        other => panic!("expected reject, got {other:?}"),
    }
}

#[test]
fn baseline_policies_expose_paper_failure_modes() {
    // §XI.A: each baseline fails exactly the way the paper says.
    let trace = paper_mix(1000, 12);
    let opts = RunOpts { interarrival_ms: 4.0, ..RunOpts::default() };
    let mut results = std::collections::BTreeMap::new();
    for mut policy in all_policies(&Config::default()) {
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 12, opts);
        results.insert(st.policy.to_string(), st);
    }
    // cloud-only: violates privacy for all non-low requests
    assert!(results["cloud-only"].privacy_violations >= 700);
    // local-only: zero violations but heavy queueing under load
    assert_eq!(results["local-only"].privacy_violations, 0);
    assert!(results["local-only"].mean_queue_ms > results["islandrun"].mean_queue_ms);
    // islandrun: clean on both axes
    assert_eq!(results["islandrun"].privacy_violations, 0);
    // static policy: silently violates under pressure
    assert!(results["static-policy"].privacy_violations > 0);
}

#[test]
fn cost_ordering_matches_paper_expectation() {
    // free local compute first → islandrun must be far cheaper than cloud-only
    let trace = paper_mix(800, 13);
    let mut ir = all_policies(&Config::default()).remove(0);
    let st_ir = run_policy(ir.as_mut(), &trace, preset_personal_group(), 13, RunOpts::default());
    let mut co = all_policies(&Config::default()).remove(1);
    let st_co = run_policy(co.as_mut(), &trace, preset_personal_group(), 13, RunOpts::default());
    assert!(
        st_ir.cost_per_1k() < 0.25 * st_co.cost_per_1k(),
        "islandrun ${:.2} vs cloud-only ${:.2}",
        st_ir.cost_per_1k(),
        st_co.cost_per_1k()
    );
}

// --- the policy knobs end-to-end: SubmitRequest → orchestrator → outcome ---

fn orchestrator_over(islands: Vec<Island>, seed: u64) -> Orchestrator {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(Fleet::new(islands, seed)), seed)
}

#[test]
fn deadline_constrained_request_avoids_high_rtt_islands_end_to_end() {
    let islands = preset_personal_group();
    // without a deadline, a burstable request under local pressure offloads
    // to a high-RTT cloud island…
    let orch = orchestrator_over(islands.clone(), 41);
    orch.saturate_bounded_islands(0.99);
    let s = orch.open_session("deadline-free");
    let free = orch
        .submit_request(s, SubmitRequest::new("what is the capital of france").priority(PriorityTier::Burstable))
        .unwrap();
    let free_island = islands.iter().find(|i| Some(i.id) == free.decision.target()).unwrap();
    assert!(free_island.latency_ms > 150.0, "expected cloud offload, got {}", free_island.name);

    // …but a 150 ms latency budget keeps it off every island whose base RTT
    // already breaks the deadline
    let orch = orchestrator_over(islands.clone(), 42);
    orch.saturate_bounded_islands(0.99);
    let s = orch.open_session("deadline-bound");
    let bound = orch
        .submit_request(
            s,
            SubmitRequest::new("what is the capital of france").priority(PriorityTier::Burstable).deadline_ms(150.0),
        )
        .unwrap();
    let target = islands.iter().find(|i| Some(i.id) == bound.decision.target()).unwrap();
    assert!(target.latency_ms <= 150.0, "deadline-bound request landed on {} ({} ms)", target.name, target.latency_ms);
}

#[test]
fn jurisdiction_floor_excludes_noncompliant_tiers_end_to_end() {
    let islands = preset_personal_group();
    let orch = orchestrator_over(islands.clone(), 43);
    orch.saturate_bounded_islands(0.99);
    let s = orch.open_session("gdpr");
    // same pressure as above: the unconstrained request offloads to a
    // Foreign-jurisdiction cloud island, the constrained one must not
    let constrained = orch
        .submit_request(
            s,
            SubmitRequest::new("summarize the eu customer record")
                .priority(PriorityTier::Burstable)
                .min_jurisdiction(0.9),
        )
        .unwrap();
    let target = islands.iter().find(|i| Some(i.id) == constrained.decision.target()).unwrap();
    assert!(
        target.jurisdiction.score() >= 0.9,
        "jurisdiction floor violated: {} scores {}",
        target.name,
        target.jurisdiction.score()
    );

    // an unsatisfiable floor fails closed instead of degrading
    let out = orch
        .submit_request(s, SubmitRequest::new("q").priority(PriorityTier::Secondary).min_jurisdiction(1.1))
        .unwrap();
    assert!(matches!(out.decision, Decision::Reject { .. }), "{:?}", out.decision);
}

#[test]
fn model_pin_routes_only_to_serving_islands_end_to_end() {
    let mut islands = preset_personal_group();
    islands[4].models.push("llama-13b".to_string()); // only the private edge serves it
    let orch = orchestrator_over(islands.clone(), 44);
    let s = orch.open_session("pinner");
    let out = orch
        .submit_request(s, SubmitRequest::new("run this on the big model").model("llama-13b"))
        .unwrap();
    assert_eq!(out.decision.target(), Some(islands[4].id), "{:?}", out.decision);

    // a model nobody serves fails closed and is audited
    let out = orch.submit_request(s, SubmitRequest::new("q").model("gpt-97")).unwrap();
    assert!(matches!(out.decision, Decision::Reject { .. }), "{:?}", out.decision);
    assert!(!orch.audit.entries().is_empty());
}

#[test]
fn enqueue_surface_honors_the_same_knobs() {
    // the non-blocking path exposes the identical constraint surface: a
    // jurisdiction-floored ticket never lands on a Foreign island
    let islands = preset_personal_group();
    let orch = std::sync::Arc::new(orchestrator_over(islands.clone(), 45));
    std::sync::Arc::clone(&orch).start_queue();
    orch.saturate_bounded_islands(0.99);
    let s = orch.open_session("queued-gdpr");
    let ticket = orch.enqueue(
        s,
        SubmitRequest::new("summarize the eu customer record").priority(PriorityTier::Burstable).min_jurisdiction(0.9),
    );
    let out = ticket.wait().unwrap();
    if let Some(id) = out.decision.target() {
        let target = islands.iter().find(|i| i.id == id).unwrap();
        assert!(target.jurisdiction.score() >= 0.9, "queued request leaked to {}", target.name);
    } else {
        panic!("expected the floor to be satisfiable: {:?}", out.decision);
    }
}

#[test]
fn policy_decision_enum_is_total() {
    // every policy returns a decision for every input (no panics) even on
    // a degenerate single-island mesh
    let single = vec![IslandState { island: preset_personal_group().remove(0), capacity: 1.0, online: true, degraded: false }];
    let r = Request::new(1, "q");
    for mut p in all_policies(&Config::default()) {
        let _ = p.route(&r, 0.5, &single, 1.0);
    }
    // and on an empty mesh, policies reject rather than panic
    for mut p in all_policies(&Config::default()) {
        match p.route(&r, 0.5, &[], 0.0) {
            PolicyDecision::Reject => {}
            PolicyDecision::Island(_) => panic!("{} routed on empty mesh", p.name()),
        }
    }
}
