//! End-to-end integration: PJRT engine over real AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a notice) when artifacts/ is absent so `cargo test` works
//! on a fresh checkout.

use std::path::Path;

use islandrun::runtime::{features, Engine};

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(engine) = engine() else { return };
    let meta = engine.meta();
    assert_eq!(meta.vocab, 256);
    assert_eq!(meta.seq_len, 64);
    assert_eq!(meta.lm_batch_variants, vec![1, 4, 8]);
}

#[test]
fn lm_generates_text_deterministically() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let out = h.generate(vec!["the islands ".to_string()], 12).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].tokens_generated, 12);
    assert!(!out[0].text.is_empty());
    // greedy decode is deterministic
    let out2 = h.generate(vec!["the islands ".to_string()], 12).unwrap();
    assert_eq!(out[0].text, out2[0].text);
}

#[test]
fn lm_batch_variants_agree() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let single = h.generate(vec!["the lighthouse".to_string()], 8).unwrap();
    let batch = h
        .generate(
            vec![
                "the lighthouse".to_string(),
                "waves carry".to_string(),
                "the patient".to_string(),
                "fn route".to_string(),
            ],
            8,
        )
        .unwrap();
    assert_eq!(batch.len(), 4);
    // same prompt must decode the same text regardless of batch variant
    assert_eq!(single[0].text, batch[0].text);
}

#[test]
fn classifier_separates_sensitivity_classes() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let probs = h
        .classify(vec![
            "patient john doe ssn 123-45-6789 diagnosed with diabetes".to_string(),
            "what is the capital of france".to_string(),
            "draft the agenda for the platform team standup".to_string(),
        ])
        .unwrap();
    assert_eq!(probs.len(), 3);
    for p in &probs {
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "probs not normalized: {p:?}");
    }
    // class order: 0 public, 1 internal, 2 confidential, 3 restricted
    let argmax = |p: &[f32]| p.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    assert_eq!(argmax(&probs[0]), 3, "PHI text must be restricted: {:?}", probs[0]);
    assert_eq!(argmax(&probs[1]), 0, "general knowledge must be public: {:?}", probs[1]);
    assert_eq!(argmax(&probs[2]), 1, "standup agenda must be internal: {:?}", probs[2]);
}

#[test]
fn classifier_matches_meta_goldens() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let meta = engine.meta().clone();
    let texts: Vec<String> = meta.golden.iter().map(|g| g.text.clone()).collect();
    let probs = h.classify(texts).unwrap();
    for (g, p) in meta.golden.iter().zip(&probs) {
        let argmax = p.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, g.class_argmax, "text: {}", g.text);
    }
}

#[test]
fn rust_featurizer_matches_python_goldens() {
    let Some(engine) = engine() else { return };
    for g in &engine.meta().golden {
        let v = features::featurize(&g.text);
        let nz: Vec<usize> = (0..v.len()).filter(|&i| v[i] > 0.0).take(8).collect();
        assert_eq!(nz, g.feat_nonzero_idx, "nonzero index mismatch for '{}'", g.text);
        for (&i, &val) in g.feat_nonzero_idx.iter().zip(&g.feat_nonzero_val) {
            assert!((v[i] as f64 - val).abs() < 1e-5, "value mismatch at {i} for '{}'", g.text);
        }
    }
}

#[test]
fn embedder_matches_meta_goldens_and_is_unit_norm() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let meta = engine.meta().clone();
    let texts: Vec<String> = meta.golden.iter().map(|g| g.text.clone()).collect();
    let embs = h.embed(texts).unwrap();
    for (g, e) in meta.golden.iter().zip(&embs) {
        let n: f32 = e.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-3, "norm={n}");
        for (i, &want) in g.emb_head.iter().enumerate() {
            assert!((e[i] as f64 - want).abs() < 1e-4, "emb[{i}] {} vs {want} for '{}'", e[i], g.text);
        }
    }
}

#[test]
fn raw_forward_timing_positive() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    for b in [1usize, 4, 8] {
        let ms = h.raw_forward(b).unwrap();
        assert!(ms > 0.0 && ms < 60_000.0, "b={b} ms={ms}");
    }
}
