//! Integration: the privacy pipeline end to end — MIST scoring, typed
//! placeholder sanitization across trust boundaries, session coherence and
//! the paper's three §VIII.D guarantees.

use islandrun::agents::mist::sanitize::PlaceholderMap;
use islandrun::agents::mist::{Mist, Stage2};
use islandrun::config::{preset_healthcare, preset_personal_group, Config};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, SubmitRequest};
use islandrun::types::PriorityTier;

fn sim(islands: Vec<islandrun::types::Island>, seed: u64) -> Orchestrator {
    Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(Fleet::new(islands, seed)), seed)
}

#[test]
fn guarantee1_privacy_preservation_over_long_session() {
    // Guarantee 1: selected island always satisfies P >= s_r.
    let islands = preset_personal_group();
    let orch = sim(islands.clone(), 31);
    let s = orch.open_session("alice");
    let mut rng = islandrun::util::Rng::new(5);
    for i in 0..120 {
        let class = match i % 3 {
            0 => islandrun::substrate::trace::SensClass::High,
            1 => islandrun::substrate::trace::SensClass::Moderate,
            _ => islandrun::substrate::trace::SensClass::Low,
        };
        let prompt = islandrun::substrate::trace::prompt_for(class, &mut rng);
        let out = orch
            .submit_request(s, SubmitRequest::new(&prompt).priority(islandrun::substrate::trace::priority_for(class)))
            .expect("admitted");
        if let Some(id) = out.decision.target() {
            let island = islands.iter().find(|x| x.id == id).unwrap();
            assert!(island.privacy >= out.s_r, "req {i}: P={} < s_r={}", island.privacy, out.s_r);
        }
        orch.advance(300.0);
    }
}

#[test]
fn guarantee2_context_sanitization_on_every_downward_crossing() {
    let islands = preset_healthcare();
    let orch = sim(islands.clone(), 32);
    let s = orch.open_session("dr");
    // sensitive turn on the workstation
    let t1 = orch
        .submit_request(
            s,
            SubmitRequest::new("patient john doe ssn 123-45-6789 with diabetes").priority(PriorityTier::Primary),
        )
        .unwrap();
    assert!(!t1.sanitized);
    // push follow-ups off the workstation
    orch.saturate_bounded_islands(0.99);
    let t2 = orch
        .submit_request(s, SubmitRequest::new("suggest general wellness resources").priority(PriorityTier::Burstable))
        .unwrap();
    let target = islands.iter().find(|i| Some(i.id) == t2.decision.target()).unwrap();
    assert!(target.privacy < 1.0);
    assert!(t2.sanitized, "downward crossing must sanitize");
    // sanitized view must not contain the identifiers
    let visible = orch
        .sessions
        .with_mut(s, |sess| sess.placeholders.sanitize("patient john doe ssn 123-45-6789 with diabetes", target.privacy))
        .unwrap();
    assert!(!visible.contains("john doe") && !visible.contains("123-45-6789"), "{visible}");
    assert!(PlaceholderMap::verify_clean(&visible, target.privacy), "{visible}");
}

#[test]
fn guarantee3_data_locality_never_exfiltrates() {
    let mut islands = preset_personal_group();
    islands[3].datasets.push("phi_db".to_string()); // home NAS holds the data
    let orch = sim(islands.clone(), 33);
    let s = orch.open_session("nurse");
    for _ in 0..30 {
        let out = orch
            .submit_request(
                s,
                SubmitRequest::new("query the phi records for trends")
                    .priority(PriorityTier::Secondary)
                    .dataset("phi_db"),
            )
            .unwrap();
        let target = out.decision.target().expect("dataset exists on an island");
        assert_eq!(target, islands[3].id, "requests must follow the data");
        orch.advance(2_000.0);
    }
}

#[test]
fn desanitized_responses_keep_conversation_coherent() {
    let islands = preset_personal_group();
    let orch = sim(islands, 34);
    let s = orch.open_session("alice");
    orch
        .submit_request(s, SubmitRequest::new("patient jane smith has hypertension").priority(PriorityTier::Primary))
        .unwrap();
    // force offload; the sim response echoes placeholders back
    orch.saturate_bounded_islands(0.99);
    let out = orch
        .submit_request(s, SubmitRequest::new("thanks, anything else to monitor").priority(PriorityTier::Burstable))
        .unwrap();
    assert!(out.sanitized);
    // stored history view (what the user sees) contains original entities,
    // never placeholder tokens
    orch.sessions
        .with(s, |sess| {
            for turn in &sess.history {
                if turn.role == islandrun::types::Role::User {
                    assert!(!turn.text.contains("[PERSON_"), "{}", turn.text);
                }
            }
        })
        .unwrap();
}

use islandrun::util::collapse_digit_runs;

/// Def. 4 under failover: a request that first sanitized for the private
/// edge (P=0.8) and then failed over to cloud (P=0.4) must transmit the
/// same wire text as a cold sanitization at 0.4 — the incremental cache
/// re-sanitizes from the cached clean form, and that form must be coherent
/// with sanitizing fresh.
#[test]
fn failover_to_lower_privacy_island_matches_fresh_sanitization() {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    cfg.failover_retry_budget = 4;
    let islands = preset_healthcare();
    let orch = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(islandrun::islands::Fleet::new(islands.clone(), 71)), 71);
    let s = orch.open_session("dr");

    // turn 1: PHI on the workstation (P=1.0), no sanitization
    let t1 = orch
        .submit_request(
            s,
            SubmitRequest::new("patient john doe ssn 123-45-6789 has diabetes").priority(PriorityTier::Primary),
        )
        .unwrap();
    assert_eq!(t1.decision.target(), Some(islands[0].id));
    assert!(!t1.sanitized);
    orch.advance(500.0);

    // saturate the workstation so follow-ups offload to the PHI edge
    orch.set_island_load(islands[0].id, 0.99);
    let t2 = orch
        .submit_request(s, SubmitRequest::new("what should we monitor generally").priority(PriorityTier::Burstable))
        .unwrap();
    assert_eq!(t2.decision.target(), Some(islands[1].id), "expected the 0.8 edge, got {:?}", t2.decision);
    assert!(t2.sanitized, "1.0 -> 0.8 crossing must sanitize");
    orch.advance(500.0);

    // the edge dies silently; the next follow-up is routed there, fails at
    // execute, and fails over DOWN to cloud (0.4) — re-sanitized from the
    // cached 0.8-level form
    orch.silent_crash_island(islands[1].id);
    let t3 = orch
        .submit_request(s, SubmitRequest::new("anything else to watch for").priority(PriorityTier::Burstable))
        .unwrap();
    assert_eq!(t3.decision.target(), Some(islands[2].id), "expected cloud after failover, got {:?}", t3.decision);
    assert!(t3.sanitized);
    assert!(orch.metrics.counter_value("failovers") >= 1);
    assert_eq!(orch.metrics.counter_value("sanitized_requests"), 2);

    // cache coherence: the 0.4-level cache (what went over the wire) must
    // equal a cold sanitization of the same original history at 0.4,
    // modulo the session-random placeholder ids
    let (original, cached) = orch
        .sessions
        .with(s, |sess| {
            let cached = sess.sanitized.turns_at(islands[2].privacy).expect("0.4 cache populated").to_vec();
            (sess.history.clone(), cached)
        })
        .unwrap();
    let mut fresh_map = PlaceholderMap::new(0xF4E5);
    let fresh = islandrun::agents::mist::sanitize::sanitize_history(&original[..cached.len()], islands[2].privacy, &mut fresh_map);
    assert_eq!(cached.len(), 4, "t3 snapshot covered both earlier turn pairs");
    for (c, f) in cached.iter().zip(&fresh) {
        assert_eq!(collapse_digit_runs(&c.text), collapse_digit_runs(&f.text), "cached {c:?} vs fresh {f:?}");
        assert_eq!(c.role, f.role);
    }
    // and nothing above the cloud's level survives in the cached form
    for turn in &cached {
        assert!(PlaceholderMap::verify_clean(&turn.text, islands[2].privacy), "{turn:?}");
    }
}

/// The per-session cache makes repeat crossings O(delta): alternating
/// sensitive (workstation) and benign (edge) turns, each crossing
/// sanitizes only the turns appended since the previous crossing.
#[test]
fn repeat_crossings_sanitize_only_the_delta() {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 1e9;
    let islands = preset_healthcare();
    let orch = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(islandrun::islands::Fleet::new(islands.clone(), 72)), 72);
    let s = orch.open_session("dr");
    // keep the workstation effectively full so benign turns offload to the
    // 0.8 edge; Primary still lands on it as the failsafe local pick
    orch.set_island_load(islands[0].id, 0.99);

    for i in 0..3 {
        let phi = format!("patient john doe ssn 123-45-678{i} has diabetes");
        let t_phi = orch.submit_request(s, SubmitRequest::new(&phi).priority(PriorityTier::Primary)).unwrap();
        assert_eq!(t_phi.decision.target(), Some(islands[0].id), "round {i}: {:?}", t_phi.decision);
        assert!(!t_phi.sanitized);
        orch.advance(500.0);
        let t_gen = orch
            .submit_request(s, SubmitRequest::new("what should we monitor generally").priority(PriorityTier::Burstable))
            .unwrap();
        assert_eq!(t_gen.decision.target(), Some(islands[1].id), "round {i}: {:?}", t_gen.decision);
        assert!(t_gen.sanitized);
        orch.advance(500.0);
    }

    // three crossings at the same level: the first is cold (2 turns +
    // prompt), each later one transforms exactly its 4-turn delta + prompt
    // and reuses the cached prefix — 3 + 5 + 5 scanned vs 21 without the
    // cache
    assert_eq!(orch.metrics.counter_value("sanitized_requests"), 3);
    assert_eq!(orch.metrics.counter_value("sanitized_turns"), 13);
    assert_eq!(orch.metrics.counter_value("sanitized_turns_reused"), 8);
}

#[test]
fn mist_engine_and_heuristic_agree_on_extremes() {
    // when artifacts exist, the real classifier and the heuristic must agree
    // on clearly-restricted and clearly-public prompts (the classes the
    // router's constraints hinge on)
    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = islandrun::runtime::Engine::load(dir).unwrap();
    let real = Mist::new(Stage2::Classifier(engine.handle()));
    let heur = Mist::heuristic();
    for (text, min, max) in [
        ("patient john doe ssn 123-45-6789 diagnosed with diabetes", 0.9, 1.0),
        ("what is the capital of france", 0.0, 0.3),
    ] {
        for (name, mist) in [("real", &real), ("heuristic", &heur)] {
            let s = mist.analyze_text(text).score;
            assert!((min..=max).contains(&s), "{name} scored {s} for '{text}'");
        }
    }
}

#[test]
fn fail_closed_beats_availability_everywhere() {
    // remove every island that could satisfy a restricted request: ALL
    // submissions must reject; none may fall through to cloud
    let islands: Vec<_> = preset_personal_group().into_iter().filter(|i| i.privacy < 0.9).collect();
    let orch = sim(islands, 35);
    let s = orch.open_session("alice");
    for _ in 0..10 {
        let out = orch
            .submit_request(s, SubmitRequest::new("patient john doe ssn 123-45-6789").priority(PriorityTier::Primary))
            .unwrap();
        assert!(matches!(out.decision, islandrun::agents::waves::Decision::Reject { .. }));
        orch.advance(100.0);
    }
    assert_eq!(orch.metrics.counter_value("rejected_fail_closed"), 10);
}
