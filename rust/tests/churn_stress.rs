//! Churn stress: islands crash / revive / leave / rejoin while 16 threads
//! submit through `Arc<Orchestrator>`.
//!
//! Pins the dynamic-membership invariants that must hold under contention,
//! independent of interleaving:
//! - no request is silently lost: every admitted request ends in exactly
//!   one audit entry — success, failover-success, or exhausted-retries
//!   reject — and `submit` never errors because of churn,
//! - request ids stay globally unique,
//! - the cost ledger equals the sum of per-outcome costs (per user and
//!   global): dead islands never charge,
//! - failover accounting is consistent: the `failovers` metric equals the
//!   sum of per-entry failover counts, and per-island failover counters sum
//!   to the same total,
//! - no outcome claims an island that was never part of the mesh.
//!
//! Thread count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job.

use std::collections::HashMap;
use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::{run_closed_loop_churn, Churn};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator};
use islandrun::types::IslandId;

const PER_THREAD: usize = 60;

fn threads() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

fn stress_orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // the stress test exercises the pipeline under churn, not admission
    // policy: a saturating rate limit or budget would turn submissions away
    // and hide the invariants under test
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

#[test]
fn churn_under_load_loses_no_request() {
    let threads = threads();
    let orch = stress_orchestrator(303);
    let churn = Churn { crash_prob: 0.35, revive_prob: 0.5, leave_prob: 0.08, step_ms: 1, announced_fraction: 0.5 };
    let (report, churn_stats) = run_closed_loop_churn(&orch, threads, PER_THREAD, 7, Some(churn));
    let total = threads * PER_THREAD;

    // churn must never surface as submit errors: with the limiter and
    // budget out of the way, every submission comes back as an Outcome
    // (served, fail-closed reject, or exhausted-retries reject)
    assert_eq!(report.errors, 0, "churn leaked as submit errors");
    assert_eq!(report.outcomes.len(), total);
    assert_eq!(report.served() + report.rejected(), total);

    // the run actually churned (step 1ms over a multi-hundred-request run)
    assert!(churn_stats.crashes > 0, "churn driver never crashed an island: {churn_stats:?}");

    // 1. request ids unique under contention + churn
    let mut ids: Vec<u64> = report.outcomes.iter().map(|o| o.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total, "request ids must be unique");

    // 2. exactly one audit entry per admitted request, ids matching
    assert_eq!(orch.audit.len(), total, "audit trail must have exactly one entry per request");
    let mut audit_ids: Vec<u64> = orch.audit.entries().iter().map(|e| e.request_id).collect();
    audit_ids.sort_unstable();
    audit_ids.dedup();
    assert_eq!(audit_ids, ids, "audit trail must cover exactly the submitted ids");

    // 3. every outcome is in exactly one bucket, and the audit entry agrees
    let entries: HashMap<u64, _> = orch.audit.entries().into_iter().map(|e| (e.request_id, e)).collect();
    for out in &report.outcomes {
        let e = &entries[&out.request_id];
        match out.decision.target() {
            Some(island) => {
                assert_eq!(e.island, Some(island), "audit island mismatch for {}", out.request_id);
                assert!(e.reject_reason.is_none());
            }
            None => {
                assert!(e.island.is_none());
                assert!(e.reject_reason.is_some(), "reject without reason for {}", out.request_id);
                assert_eq!(out.cost, 0.0, "rejected request was charged");
            }
        }
    }

    // 4. ledger equals Σ costs, per user and global — dead islands never
    // charge and failed attempts are free
    let expected_total: f64 = report.outcomes.iter().map(|o| o.cost).sum();
    let tolerance = 1e-9 * (1.0 + expected_total.abs());
    assert!(
        (orch.ledger.total() - expected_total).abs() < tolerance,
        "ledger total {} != outcome sum {}",
        orch.ledger.total(),
        expected_total
    );
    for t in 0..threads {
        let user = format!("loadgen-{t}");
        let expected_user: f64 = report
            .outcomes
            .iter()
            .filter(|o| entries.get(&o.request_id).map(|e| e.user == user).unwrap_or(false))
            .map(|o| o.cost)
            .sum();
        assert!(
            (orch.ledger.spent(&user) - expected_user).abs() < tolerance,
            "user {user}: ledger {} != outcome sum {}",
            orch.ledger.spent(&user),
            expected_user
        );
    }

    // 5. failover accounting is internally consistent
    let failovers_metric = orch.metrics.counter_value("failovers");
    assert_eq!(orch.audit.total_failovers(), failovers_metric, "audit failovers != failovers metric");
    let per_island: u64 =
        orch.metrics.counter_children("failovers_by_island").into_iter().map(|(_, n)| n).sum();
    assert_eq!(per_island, failovers_metric, "per-island failover counters must sum to the total");

    // 6. no outcome claims an island outside the original mesh
    let known: Vec<IslandId> = preset_personal_group().iter().map(|i| i.id).collect();
    for e in entries.values() {
        if let Some(island) = e.island {
            assert!(known.contains(&island), "unknown island {island:?} in audit trail");
        }
    }

    // 7. the trail stays compliance-clean even under churn: failover hops
    // never land sensitive requests on low-privacy islands
    assert!(orch.audit.violations(0.9, 0.9).is_empty(), "privacy constraint violated under churn");
}

#[test]
fn harsh_churn_with_slow_revival_still_accounts_everything() {
    // islands die fast and come back slowly: a large fraction of requests
    // must take the reject path, and accounting still balances
    let orch = stress_orchestrator(404);
    let churn = Churn { crash_prob: 0.6, revive_prob: 0.2, leave_prob: 0.0, step_ms: 1, announced_fraction: 0.0 };
    let (report, _) = run_closed_loop_churn(&orch, 8, 40, 11, Some(churn));
    assert_eq!(report.errors, 0);
    assert_eq!(report.outcomes.len(), 320);
    assert_eq!(orch.audit.len(), 320);
    let expected: f64 = report.outcomes.iter().map(|o| o.cost).sum();
    assert!((orch.ledger.total() - expected).abs() < 1e-9 * (1.0 + expected.abs()));
}

#[test]
fn churn_run_is_repeatable() {
    // same seeds → same id-set sizes and audit cardinality (interleavings
    // and churn timing differ; the invariants do not)
    for _ in 0..2 {
        let orch = stress_orchestrator(505);
        let (report, _) = run_closed_loop_churn(&orch, 8, 30, 13, Some(Churn::default()));
        assert_eq!(report.errors, 0);
        assert_eq!(report.outcomes.len(), 240);
        assert_eq!(orch.audit.len(), 240);
    }
}
