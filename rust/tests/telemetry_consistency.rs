//! Telemetry ↔ outcome consistency under mixed concurrent traffic.
//!
//! Every terminal resolution must be counted exactly once in the
//! `requests_resolved{outcome,reason}` family and logged exactly once in the
//! analytics ring; served requests must land exactly once in the latency
//! histograms (fleet-wide and per-island). The stress mix covers blocking
//! submits, queued tickets, cancel-while-queued, and invalid requests, then
//! pins:
//! - Σ outcome-labeled counters == tickets/submissions resolved,
//! - histogram sample counts == served requests (fleet and per-island),
//! - one analytics event per resolution, with outcome/reason pairs drawn
//!   from the same typed [`Resolution`] vocabulary as the counters,
//! - `render_prometheus()` passes the format lint and exposes the island /
//!   tier / outcome label sets.
//!
//! Producer count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::eval::loadgen::class_for;
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, Outcome, Resolution, SubmitRequest, Ticket};
use islandrun::substrate::trace::{priority_for, prompt_for};
use islandrun::telemetry::lint_exposition;
use islandrun::util::Rng;

const PER_PRODUCER: usize = 40;
const QUEUED: usize = 24;
const PRE_CANCELLED: usize = 6;
const INVALID: usize = 3;

fn producers() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
}

fn orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // admission policy is not under test: a saturating rate limit or budget
    // would shed traffic through paths this test wants to count explicitly
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.queue_capacity = 100_000;
    cfg.serve_workers = 4;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

#[test]
fn every_resolution_is_counted_logged_and_exposable() {
    let producers = producers();
    let orch = orchestrator(611);

    // --- phase 0: parked tickets cancelled before any worker exists ------
    let pre_session = orch.open_session("precancel");
    let pre_cancelled: Vec<Ticket> = (0..PRE_CANCELLED)
        .map(|_| {
            let t = orch.enqueue(pre_session, SubmitRequest::new("hello world").deadline_ms(1e12));
            t.cancel();
            t
        })
        .collect();

    // --- phase 1: blocking submits + queued tickets from many threads ----
    Arc::clone(&orch).start_queue();
    let outcomes: Arc<Mutex<Vec<Outcome>>> = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..producers)
        .map(|p| {
            let orch = Arc::clone(&orch);
            let outcomes = Arc::clone(&outcomes);
            std::thread::spawn(move || {
                let session = orch.open_session(&format!("mixed-{p}"));
                let mut rng = Rng::new(17 ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut local = Vec::new();
                let mut tickets = Vec::new();
                for i in 0..PER_PRODUCER {
                    let class = class_for(i);
                    let req = SubmitRequest::new(prompt_for(class, &mut rng)).priority(priority_for(class));
                    local.push(orch.submit_request(session, req).expect("blocking submit resolves"));
                    orch.advance(5.0);
                }
                for i in 0..QUEUED / producers.max(1) {
                    let class = class_for(i);
                    let req = SubmitRequest::new(prompt_for(class, &mut rng))
                        .priority(priority_for(class))
                        .deadline_ms(1e12);
                    tickets.push(orch.enqueue(session, req));
                    orch.advance(5.0);
                }
                for _ in 0..INVALID {
                    local.push(
                        orch.submit_request(session, SubmitRequest::new("degenerate").max_new_tokens(0))
                            .expect("invalid requests shed, they do not error"),
                    );
                }
                for t in tickets {
                    local.push(t.wait().expect("no ticket may be lost"));
                }
                outcomes.lock().unwrap().extend(local);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut outcomes = Arc::try_unwrap(outcomes).expect("workers joined").into_inner().unwrap();
    outcomes.extend(pre_cancelled.iter().map(|t| t.wait().expect("pre-cancelled tickets resolve")));

    // --- invariant 1: Σ outcome-labeled counters == resolutions ----------
    let total = outcomes.len() as u64;
    let children = orch.metrics.counter_children("requests_resolved");
    let counted: u64 = children.iter().map(|(_, n)| n).sum();
    assert_eq!(counted, total, "requests_resolved must count each resolution exactly once");
    assert_eq!(orch.metrics.counter_value("requests_resolved"), total);
    // per-(outcome, reason) pair, the counter matches the outcomes
    let mut by_pair: BTreeMap<(&str, &str), u64> = BTreeMap::new();
    for out in &outcomes {
        *by_pair.entry((out.resolution.class(), out.resolution.reason())).or_default() += 1;
    }
    for (labels, n) in &children {
        let pair = (labels[0].as_str(), labels[1].as_str());
        assert!(
            Resolution::ALL.iter().any(|r| (r.class(), r.reason()) == pair),
            "label pair {pair:?} is outside the typed Resolution vocabulary"
        );
        let expected = by_pair.iter().find(|((c, r), _)| (*c, *r) == pair).map(|(_, n)| *n).unwrap_or(0);
        assert_eq!(*n, expected, "counter {pair:?} disagrees with outcomes");
    }
    assert!(
        outcomes.iter().any(|o| o.resolution == Resolution::Served),
        "the mix must serve something for the histogram invariants to bite"
    );

    // --- invariant 2: histogram samples == served requests ---------------
    let served = outcomes.iter().filter(|o| o.resolution == Resolution::Served).count() as u64;
    assert_eq!(orch.metrics.counter_value("requests_served"), served);
    let latency = orch.metrics.histogram("latency_ms").expect("latency_ms registered");
    assert_eq!(latency.count(), served, "latency_ms samples must equal served requests");
    let island_children = orch.metrics.histogram_children("island_latency_ms");
    let island_samples: u64 = island_children.iter().map(|(_, h)| h.count()).sum();
    assert_eq!(island_samples, served, "per-island latency samples must sum to served requests");
    let served_by_island: u64 = orch.metrics.counter_children("served_by_island").iter().map(|(_, n)| n).sum();
    assert_eq!(served_by_island, served);
    for (labels, _) in &island_children {
        assert_eq!(labels.len(), 3, "island series carry island/tier/privacy labels");
        assert!(labels[0].starts_with("island-"), "{labels:?}");
        assert!(["personal", "private-edge", "cloud"].contains(&labels[1].as_str()), "{labels:?}");
        assert!(labels[2].parse::<f64>().is_ok(), "{labels:?}");
    }

    // --- invariant 3: one analytics event per resolution -----------------
    assert_eq!(orch.analytics.dropped(), 0, "the mix must fit the default ring");
    let events = orch.analytics.snapshot();
    assert_eq!(events.len() as u64, total, "one analytics event per resolved request");
    let mut event_pairs: BTreeMap<(&str, &str), u64> = BTreeMap::new();
    for ev in &events {
        *event_pairs.entry((ev.outcome, ev.reason)).or_default() += 1;
    }
    assert_eq!(event_pairs, by_pair, "analytics events must mirror the outcome counters");
    for ev in &events {
        if ev.outcome == "served" {
            assert!(ev.island.is_some() && ev.tier.is_some(), "served events carry island evidence");
            assert!(ev.resolved_ms.is_finite());
        }
    }

    // --- invariant 4: the exposition is valid and fully labeled ----------
    let text = orch.metrics.render_prometheus();
    lint_exposition(&text).expect("render_prometheus must pass the format lint");
    assert!(text.contains("islandrun_requests_resolved_total{outcome=\"served\",reason=\"ok\"}"), "{text}");
    assert!(text.contains("islandrun_island_latency_ms_bucket{island=\"island-"), "per-island buckets missing");
    assert!(text.contains("islandrun_requests_served_total"), "unlabeled counters must render");
    assert!(text.contains("le=\"+Inf\""), "histograms must close with +Inf");

    // --- lifecycle bookkeeping stays intact under the mix ----------------
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    assert_eq!(orch.audit.len(), outcomes.len(), "one audit entry per consumed id");
}

/// islandlint R4 (`resolution-coverage`) companion: every [`Resolution`]
/// variant is named here explicitly — not via `Resolution::ALL` alone — so the
/// static-analysis pass can prove each variant is asserted on in at least one
/// test. For each variant we pin that (a) its outcome counter cell is
/// pre-registered before any traffic flows (a typo'd reason can never mint a
/// fresh zero cell at bump time) and (b) its `reason` label survives into the
/// Prometheus exposition.
#[test]
fn every_resolution_variant_has_a_preregistered_cell_and_renders() {
    use islandrun::server::{CancelPoint, FailReason, ShedReason};

    let orch = orchestrator(612);

    // Named explicitly, one per line: this list is the R4 test-side ledger.
    let variants: [Resolution; 15] = [
        Resolution::Served,
        Resolution::Shed(ShedReason::QueueFull),
        Resolution::Shed(ShedReason::DeadlineExpired),
        Resolution::Shed(ShedReason::InvalidRequest),
        Resolution::Shed(ShedReason::RateLimited),
        Resolution::Shed(ShedReason::WorkerPanic),
        Resolution::Shed(ShedReason::Shutdown),
        Resolution::Cancelled(CancelPoint::WhileQueued),
        Resolution::Cancelled(CancelPoint::BeforeExecution),
        Resolution::Cancelled(CancelPoint::MidDecode),
        Resolution::Cancelled(CancelPoint::DeadlineMidDecode),
        Resolution::Failed(FailReason::FailClosed),
        Resolution::Failed(FailReason::FailoverExhausted),
        Resolution::Failed(FailReason::ExecutionError),
        Resolution::Failed(FailReason::SessionClosed),
    ];
    assert_eq!(variants, Resolution::ALL, "the explicit ledger must mirror Resolution::ALL");

    let children = orch.metrics.counter_children("requests_resolved");
    let text = orch.metrics.render_prometheus();
    lint_exposition(&text).expect("render_prometheus must pass the format lint");
    for r in variants {
        let pair = (r.class(), r.reason());
        assert!(
            children.iter().any(|(labels, _)| (labels[0].as_str(), labels[1].as_str()) == pair),
            "no pre-registered requests_resolved cell for {pair:?}"
        );
        let series = format!(
            "islandrun_requests_resolved_total{{outcome=\"{}\",reason=\"{}\"}}",
            r.class(),
            r.reason()
        );
        assert!(text.contains(&series), "exposition is missing {series}");
    }
}
