//! Mid-decode cancellation stress: producers race tight-deadline requests,
//! caller cancels, and normal traffic through the continuous-batching step
//! loops, with virtual time frozen so every deadline expiry happens *on the
//! decode cursor*, mid-generation — never at the queue-expiry check.
//!
//! Pins the cancellation invariants that must hold under contention:
//! - every cancelled ticket resolves exactly once (`ticket_double_resolved`
//!   stays 0, every `wait()` returns),
//! - every consumed id leaves exactly one audit entry, and cancelled
//!   outcomes match the typed cancellation audit view one-to-one,
//! - the ledger equals Σ per-outcome costs — a cancelled request is charged
//!   exactly its prefill + decoded tokens, never its full budget,
//! - a deadline expiring mid-generation stops the decode early
//!   (`tokens_generated` strictly below the budget) and frees the slot: the
//!   batch-occupancy metric shows slots being shared and re-used,
//! - a ticket cancelled while still parked resolves without routing.
//!
//! Producer count is overridable via `ISLANDRUN_STRESS_THREADS` so the CI
//! release-mode stress job can push harder than the debug test job.

use std::sync::Arc;

use islandrun::agents::mist::Mist;
use islandrun::config::{preset_personal_group, Config};
use islandrun::islands::Fleet;
use islandrun::server::{Backend, Orchestrator, Outcome, SubmitRequest, Ticket, TokenEvent};

const PER_PRODUCER: usize = 30;
const PRE_CANCELLED: usize = 8;
const PRE_BURST: usize = 8;
/// Token budget no island can decode inside the doomed deadline (fastest
/// per-token rate in the preset is 1.2 virtual ms → 512 tokens ≥ 614 ms).
const DOOMED_TOKENS: usize = 512;
const DOOMED_DEADLINE_MS: f64 = 300.0;

fn producers() -> usize {
    std::env::var("ISLANDRUN_STRESS_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(16)
}

fn stress_orchestrator(seed: u64) -> Arc<Orchestrator> {
    let mut cfg = Config::default();
    // admission policy is not under test: a saturating rate limit or budget
    // would turn submissions away and hide the cancellation invariants
    cfg.rate_limit_rps = 1e9;
    cfg.budget_ceiling = 1e9;
    cfg.queue_capacity = 100_000;
    cfg.serve_workers = 4;
    let fleet = Fleet::new(preset_personal_group(), seed);
    Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), seed))
}

#[test]
fn mid_decode_cancellation_under_contention_keeps_every_invariant() {
    let producers = producers();
    let orch = stress_orchestrator(701);

    // --- phase 0 (deterministic): cancel while parked -------------------
    // enqueued and cancelled before any worker exists, so the drain MUST
    // observe the flag before routing
    let pre_session = orch.open_session("precancel");
    let pre_cancelled: Vec<Ticket> = (0..PRE_CANCELLED)
        .map(|_| {
            let t = orch.enqueue(pre_session, SubmitRequest::new("hello world").deadline_ms(1e12));
            t.cancel();
            t
        })
        .collect();
    // a parked burst of identical co-routed requests: the first drain to
    // reach them pops the whole batch at once, so the step loop provably
    // holds a multi-request in-flight batch (occupancy invariant below)
    let burst_session = orch.open_session("preburst");
    let pre_burst: Vec<Ticket> = (0..PRE_BURST)
        .map(|_| orch.enqueue(burst_session, SubmitRequest::new("hello world").deadline_ms(1e12).max_new_tokens(8)))
        .collect();

    Arc::clone(&orch).start_queue();

    // --- phase 1 (racing): fast / doomed / caller-cancel mix ------------
    // NOTE: virtual time is never advanced. The queue-expiry check (now >
    // deadline_at) therefore never fires; a doomed request can only die on
    // its decode cursor, mid-generation, inside the step loop.
    let handles: Vec<_> = (0..producers)
        .map(|t| {
            let orch = Arc::clone(&orch);
            std::thread::spawn(move || {
                let session = orch.open_session(&format!("cstress-{t}"));
                let tickets: Vec<Ticket> = (0..PER_PRODUCER)
                    .map(|i| match i % 6 {
                        // plenty of budget: completes and streams tokens
                        0 | 1 | 2 => orch
                            .enqueue(session, SubmitRequest::new("hello world").deadline_ms(1e12).max_new_tokens(8)),
                        // doomed: the deadline lands mid-decode, always
                        3 | 4 => orch.enqueue(
                            session,
                            SubmitRequest::new("summarize my week please")
                                .deadline_ms(DOOMED_DEADLINE_MS)
                                .max_new_tokens(DOOMED_TOKENS),
                        ),
                        // racer: caller cancel races the step loop — may
                        // land while queued, before execution, mid-decode,
                        // or after completion (then it is a no-op)
                        _ => {
                            let ticket = orch.enqueue(
                                session,
                                SubmitRequest::new("tell me a long story").deadline_ms(1e12).max_new_tokens(512),
                            );
                            ticket.cancel();
                            ticket
                        }
                    })
                    .collect();
                tickets.into_iter().map(|t| t.wait().expect("no ticket may error")).collect::<Vec<Outcome>>()
            })
        })
        .collect();

    // --- probe: the streaming surface end-to-end ------------------------
    let probe_session = orch.open_session("probe");
    let probe = orch.enqueue(probe_session, SubmitRequest::new("hello world").deadline_ms(1e12).max_new_tokens(8));
    let events: Vec<TokenEvent> = probe.stream().collect();
    assert!(matches!(events.first(), Some(TokenEvent::First { .. })), "stream must open with First: {events:?}");
    assert!(matches!(events.last(), Some(TokenEvent::Done)), "a served stream ends with Done: {events:?}");
    let probe_out = probe.wait().unwrap();
    assert!(!probe_out.cancelled());
    assert_eq!(probe_out.tokens_generated, 8);

    let mut outcomes: Vec<Outcome> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    outcomes.extend(pre_cancelled.iter().map(|t| t.wait().expect("pre-cancelled tickets resolve cleanly")));
    outcomes.extend(pre_burst.iter().map(|t| t.wait().expect("burst tickets resolve cleanly")));
    outcomes.push(probe_out);
    let total = producers * PER_PRODUCER + PRE_CANCELLED + PRE_BURST + 1;
    assert_eq!(outcomes.len(), total);

    // 1. no ticket lost or double-resolved
    assert_eq!(orch.metrics.counter_value("ticket_double_resolved"), 0);
    assert_eq!(orch.metrics.counter_value("enqueued"), total as u64);

    // 2. exactly one audit entry per consumed id, ids matching outcomes
    let mut ids: Vec<u64> = outcomes.iter().map(|o| o.request_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total, "request ids must be unique");
    assert_eq!(orch.audit.len(), total);
    let mut audit_ids: Vec<u64> = orch.audit.entries().iter().map(|e| e.request_id).collect();
    audit_ids.sort_unstable();
    audit_ids.dedup();
    assert_eq!(audit_ids, ids, "audit trail must cover exactly the enqueued ids");

    // 3. ledger equals Σ outcome costs: cancels charge their partial decode
    // and nothing more, sheds and pre-execution cancels charge nothing
    let expected_total: f64 = outcomes.iter().map(|o| o.cost).sum();
    let tolerance = 1e-9 * (1.0 + expected_total.abs());
    assert!(
        (orch.ledger.total() - expected_total).abs() < tolerance,
        "ledger total {} != outcome sum {}",
        orch.ledger.total(),
        expected_total
    );

    // 4. every doomed request died on its decode cursor, before its budget
    let doomed_total = (producers * PER_PRODUCER * 2 / 6) as u64;
    assert_eq!(orch.metrics.counter_value("cancelled_deadline_mid_decode"), doomed_total);
    let cancelled: Vec<&Outcome> = outcomes.iter().filter(|o| o.cancelled()).collect();
    assert!(cancelled.len() as u64 >= doomed_total + PRE_CANCELLED as u64, "got {} cancelled", cancelled.len());
    for out in &cancelled {
        assert!(out.tokens_generated < DOOMED_TOKENS, "cancel must stop decode early: {}", out.tokens_generated);
        if out.decision.target().is_none() {
            assert_eq!(out.cost, 0.0, "a cancel that never reached an island is free");
            assert_eq!(out.tokens_generated, 0);
        }
    }

    // 5. cancelled outcomes and the cancelled:-scoped audit view agree 1:1
    let cancellations = orch.audit.cancellations();
    assert_eq!(cancellations.len(), cancelled.len());
    let mut cancel_ids: Vec<u64> = cancellations.iter().map(|e| e.request_id).collect();
    cancel_ids.sort_unstable();
    let mut outcome_cancel_ids: Vec<u64> = cancelled.iter().map(|o| o.request_id).collect();
    outcome_cancel_ids.sort_unstable();
    assert_eq!(cancel_ids, outcome_cancel_ids);

    // 6. the parked cancels resolved without routing (and count the racers
    // whose cancel also landed before routing, if any)
    assert!(orch.metrics.counter_value("cancelled_while_queued") >= PRE_CANCELLED as u64);
    for t in &pre_cancelled {
        let out = t.wait().unwrap();
        assert!(out.cancelled());
        assert_eq!(out.cost, 0.0);
        assert!(out.decision.target().is_none(), "cancelled-while-queued must never route");
    }

    // 7. freed slots are re-used: the step loops ran with shared batches
    let occupancy = orch.metrics.histogram("batch_occupancy").expect("step loops must record occupancy");
    assert!(occupancy.count() > 0);
    assert!(occupancy.max() >= 2.0, "no step batch ever held 2+ requests (max {})", occupancy.max());

    // 8. compliance stays clean under cancellation churn
    assert!(orch.audit.violations(0.9, 0.9).is_empty());
}
