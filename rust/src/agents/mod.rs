//! The four cooperating agents of the IslandRun universe (§IV):
//!
//! - [`mist`]       — Multi-level Intelligent Sensitivity Tracker (privacy)
//! - [`tide`]       — Temporal Island Demand Evaluator (resources)
//! - [`waves`]      — Weighted Agent-based Variance Equilibration System
//!   (multi-objective routing)
//! - [`lighthouse`] — Link and Health Tracking (mesh topology, registry)
//!
//! SHORE and HORIZON are *execution endpoints* (islands), not agents —
//! they live in [`crate::islands`].
//!
//! §IV.C standardized agent interface: every optimization dimension exposes
//! `score(request, island) -> [0,1]` (lower is better). WAVES aggregates
//! registered scorers into Eq. 1 plus any extension terms, which is how new
//! objectives (e.g. carbon intensity) are added without touching the router
//! (tested in `waves::router` and ablated in E6).

pub mod lighthouse;
pub mod mist;
pub mod tide;
pub mod waves;

use crate::types::{Island, Request};

/// §IV.C agent interface: objective-specific score in [0,1], lower better.
pub trait Scorer: Send + Sync {
    fn name(&self) -> &'static str;
    fn score(&self, request: &Request, island: &Island) -> f64;
}

/// Example extension agent (§IV "Extensibility": carbon footprint): scores
/// islands by a static grams-CO2-per-request estimate, normalized.
pub struct CarbonScorer;

impl Scorer for CarbonScorer {
    fn name(&self) -> &'static str {
        "carbon"
    }

    fn score(&self, _request: &Request, island: &Island) -> f64 {
        // Personal devices amortize embodied carbon; cloud burns datacenter
        // power + WAN transit. Numbers are illustrative (the paper leaves
        // carbon to future work; we implement it as the extensibility demo).
        match island.tier {
            crate::types::TrustTier::Personal => 0.1,
            crate::types::TrustTier::PrivateEdge => 0.4,
            crate::types::TrustTier::Cloud => 0.9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    #[test]
    fn carbon_scorer_orders_tiers() {
        let islands = preset_personal_group();
        let r = Request::new(0, "hello");
        let personal = CarbonScorer.score(&r, &islands[0]);
        let edge = CarbonScorer.score(&r, &islands[4]);
        let cloud = CarbonScorer.score(&r, &islands[5]);
        assert!(personal < edge && edge < cloud);
        assert!((0.0..=1.0).contains(&personal) && (0.0..=1.0).contains(&cloud));
    }
}
