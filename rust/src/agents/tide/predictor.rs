//! Exhaustion prediction (§IV: TIDE "predict[s] when local capacity will be
//! exhausted and trigger[s] proactive offloading").
//!
//! EWMA-smoothed capacity + EWMA slope extrapolation: predict capacity at
//! `horizon_ms` ahead; when the prediction falls below the configured buffer
//! threshold, TIDE signals proactive offload *before* the island actually
//! saturates (Attack-4 mitigation also keys off this).

/// EWMA capacity trend predictor.
#[derive(Clone, Debug)]
pub struct Predictor {
    alpha: f64,
    level: Option<f64>,
    slope_per_ms: f64,
    last_t: f64,
}

impl Predictor {
    /// `alpha` is the EWMA smoothing factor in (0,1]; higher = more reactive.
    pub fn new(alpha: f64) -> Predictor {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Predictor { alpha, level: None, slope_per_ms: 0.0, last_t: 0.0 }
    }

    /// Feed a (t_ms, capacity) observation.
    pub fn observe(&mut self, t_ms: f64, capacity: f64) {
        match self.level {
            None => {
                self.level = Some(capacity);
                self.last_t = t_ms;
            }
            Some(level) => {
                let dt = (t_ms - self.last_t).max(1e-9);
                let inst_slope = (capacity - level) / dt;
                self.slope_per_ms = self.alpha * inst_slope + (1.0 - self.alpha) * self.slope_per_ms;
                self.level = Some(self.alpha * capacity + (1.0 - self.alpha) * level);
                self.last_t = t_ms;
            }
        }
    }

    /// Predicted capacity `horizon_ms` after the last observation (clamped).
    pub fn predict(&self, horizon_ms: f64) -> f64 {
        let level = self.level.unwrap_or(1.0);
        (level + self.slope_per_ms * horizon_ms).clamp(0.0, 1.0)
    }

    /// Will capacity fall below `buffer` within the horizon?
    pub fn exhaustion_imminent(&self, horizon_ms: f64, buffer: f64) -> bool {
        self.predict(horizon_ms) < buffer
    }

    /// Current smoothed capacity (1.0 before any observation).
    pub fn level(&self) -> f64 {
        self.level.unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_capacity_predicts_itself() {
        let mut p = Predictor::new(0.5);
        for t in 0..20 {
            p.observe(t as f64 * 100.0, 0.6);
        }
        assert!((p.predict(1000.0) - 0.6).abs() < 0.05);
        assert!(!p.exhaustion_imminent(1000.0, 0.3));
    }

    #[test]
    fn declining_capacity_predicts_exhaustion() {
        let mut p = Predictor::new(0.5);
        // capacity dropping 0.9 -> 0.5 over 2s: slope -0.0002/ms
        for t in 0..21 {
            p.observe(t as f64 * 100.0, 0.9 - 0.02 * t as f64);
        }
        assert!(p.predict(2000.0) < 0.25, "pred={}", p.predict(2000.0));
        assert!(p.exhaustion_imminent(2000.0, 0.3));
    }

    #[test]
    fn rising_capacity_not_imminent() {
        let mut p = Predictor::new(0.5);
        for t in 0..21 {
            p.observe(t as f64 * 100.0, 0.3 + 0.02 * t as f64);
        }
        assert!(!p.exhaustion_imminent(2000.0, 0.3));
    }

    #[test]
    fn prediction_clamped() {
        let mut p = Predictor::new(1.0);
        p.observe(0.0, 0.5);
        p.observe(100.0, 0.1);
        assert_eq!(p.predict(1e9), 0.0);
        let mut q = Predictor::new(1.0);
        q.observe(0.0, 0.5);
        q.observe(100.0, 0.9);
        assert_eq!(q.predict(1e9), 1.0);
    }

    #[test]
    fn unobserved_predictor_assumes_full_capacity() {
        let p = Predictor::new(0.3);
        assert_eq!(p.level(), 1.0);
        assert_eq!(p.predict(500.0), 1.0);
    }
}
