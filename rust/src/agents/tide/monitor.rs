//! TIDE resource sampling: Eq. 3 capacity from CPU/GPU/memory utilization.
//!
//!   R_local(t) = 1 - max(CPU(t)/100, GPU(t)/100, Mem(t)/Total)
//!
//! Two metric sources:
//! - [`MetricsSource::Proc`] reads real `/proc/stat` + `/proc/meminfo`
//!   (keeps the real-system path honest; used by `islandrun serve`),
//! - [`MetricsSource::Synthetic`] replays a deterministic load program
//!   (what every experiment uses — load must be *controllable*).

use std::fs;

/// One utilization sample, each component in [0,1].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    pub cpu: f64,
    pub gpu: f64,
    pub mem: f64,
}

impl Sample {
    /// Eq. 3: available capacity.
    pub fn capacity(&self) -> f64 {
        1.0 - self.cpu.max(self.gpu).max(self.mem).clamp(0.0, 1.0)
    }
}

/// A synthetic load program: piecewise-linear utilization over time.
#[derive(Clone, Debug)]
pub struct LoadProgram {
    /// (t_ms, utilization) knots, sorted by time; linear in between;
    /// clamped at the ends.
    pub knots: Vec<(f64, f64)>,
}

impl LoadProgram {
    pub fn constant(u: f64) -> LoadProgram {
        LoadProgram { knots: vec![(0.0, u)] }
    }

    /// Oscillating load around `mid` with amplitude `amp` and period ms —
    /// drives the E10 hysteresis experiment.
    pub fn oscillating(mid: f64, amp: f64, period_ms: f64, total_ms: f64) -> LoadProgram {
        let mut knots = Vec::new();
        let mut t = 0.0;
        let mut up = true;
        while t <= total_ms {
            knots.push((t, if up { mid + amp } else { mid - amp }));
            up = !up;
            t += period_ms / 2.0;
        }
        LoadProgram { knots }
    }

    /// Ramp from u0 to u1 over the window (exhaustion prediction tests).
    pub fn ramp(u0: f64, u1: f64, total_ms: f64) -> LoadProgram {
        LoadProgram { knots: vec![(0.0, u0), (total_ms, u1)] }
    }

    /// Utilization at time t (ms).
    pub fn at(&self, t_ms: f64) -> f64 {
        match self.knots.len() {
            0 => 0.0,
            1 => self.knots[0].1,
            _ => {
                if t_ms <= self.knots[0].0 {
                    return self.knots[0].1.clamp(0.0, 1.0);
                }
                for w in self.knots.windows(2) {
                    let (t0, u0) = w[0];
                    let (t1, u1) = w[1];
                    if t_ms >= t0 && t_ms <= t1 {
                        let f = if t1 > t0 { (t_ms - t0) / (t1 - t0) } else { 0.0 };
                        return (u0 + f * (u1 - u0)).clamp(0.0, 1.0);
                    }
                }
                self.knots.last().map(|&(_, u)| u).unwrap_or(0.0).clamp(0.0, 1.0)
            }
        }
    }
}

/// Degraded-island detector: folds a stream of Eq. 3 capacity samples for
/// one island into a binary degraded/healthy signal that LIGHTHOUSE carries
/// alongside heartbeat liveness. An island is *degraded* after `limit`
/// consecutive zero-capacity samples — it is reachable (heartbeats still
/// arrive) but has served no capacity for a full detection window, so WAVES
/// deprioritizes it (last pick for the Algorithm-1 failsafe). Unlike an
/// offline island it is never excluded outright: saturation must queue,
/// not reject. One non-zero sample clears the signal (capacity recovered).
#[derive(Clone, Copy, Debug)]
pub struct DegradeDetector {
    limit: u32,
    zeros: u32,
    degraded: bool,
}

impl DegradeDetector {
    pub fn new(limit: u32) -> DegradeDetector {
        DegradeDetector { limit: limit.max(1), zeros: 0, degraded: false }
    }

    /// Feed one capacity sample; returns the current degraded verdict.
    pub fn observe(&mut self, capacity: f64) -> bool {
        if capacity <= 0.0 {
            self.zeros = self.zeros.saturating_add(1);
            if self.zeros >= self.limit {
                self.degraded = true;
            }
        } else {
            self.zeros = 0;
            self.degraded = false;
        }
        self.degraded
    }

    pub fn is_degraded(&self) -> bool {
        self.degraded
    }
}

/// Where samples come from.
pub enum MetricsSource {
    /// Real /proc on linux. CPU utilization is measured between calls
    /// (first call returns 0 load), GPU is assumed 0 (no GPU on this image).
    Proc(ProcState),
    /// Deterministic synthetic program driven by virtual time.
    Synthetic(LoadProgram),
}

/// Book-keeping for /proc/stat deltas.
#[derive(Default)]
pub struct ProcState {
    last_total: u64,
    last_idle: u64,
}

impl MetricsSource {
    pub fn synthetic(p: LoadProgram) -> MetricsSource {
        MetricsSource::Synthetic(p)
    }

    pub fn proc() -> MetricsSource {
        MetricsSource::Proc(ProcState::default())
    }

    /// Sample utilization at virtual time `t_ms` (ignored by Proc).
    pub fn sample(&mut self, t_ms: f64) -> Sample {
        match self {
            MetricsSource::Synthetic(p) => {
                let u = p.at(t_ms);
                Sample { cpu: u, gpu: u * 0.9, mem: u * 0.6 }
            }
            MetricsSource::Proc(state) => sample_proc(state),
        }
    }
}

fn sample_proc(state: &mut ProcState) -> Sample {
    let cpu = (|| -> Option<f64> {
        let stat = fs::read_to_string("/proc/stat").ok()?;
        let line = stat.lines().next()?;
        let fields: Vec<u64> = line.split_whitespace().skip(1).filter_map(|x| x.parse().ok()).collect();
        if fields.len() < 4 {
            return None;
        }
        let idle = fields[3] + fields.get(4).copied().unwrap_or(0);
        let total: u64 = fields.iter().sum();
        let (dt, di) = (total.saturating_sub(state.last_total), idle.saturating_sub(state.last_idle));
        state.last_total = total;
        state.last_idle = idle;
        if dt == 0 {
            return Some(0.0);
        }
        Some(1.0 - di as f64 / dt as f64)
    })()
    .unwrap_or(0.0);

    let mem = (|| -> Option<f64> {
        let info = fs::read_to_string("/proc/meminfo").ok()?;
        let get = |key: &str| -> Option<f64> {
            info.lines().find(|l| l.starts_with(key))?.split_whitespace().nth(1)?.parse().ok()
        };
        let total = get("MemTotal:")?;
        let avail = get("MemAvailable:")?;
        Some(1.0 - avail / total)
    })()
    .unwrap_or(0.0);

    Sample { cpu: cpu.clamp(0.0, 1.0), gpu: 0.0, mem: mem.clamp(0.0, 1.0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_capacity_takes_max_component() {
        let s = Sample { cpu: 0.2, gpu: 0.7, mem: 0.4 };
        assert!((s.capacity() - 0.3).abs() < 1e-12);
        let idle = Sample { cpu: 0.0, gpu: 0.0, mem: 0.0 };
        assert_eq!(idle.capacity(), 1.0);
        let full = Sample { cpu: 1.0, gpu: 0.0, mem: 0.0 };
        assert_eq!(full.capacity(), 0.0);
    }

    #[test]
    fn capacity_clamps_out_of_range() {
        let s = Sample { cpu: 1.5, gpu: 0.0, mem: 0.0 };
        assert_eq!(s.capacity(), 0.0);
    }

    #[test]
    fn constant_program() {
        let p = LoadProgram::constant(0.6);
        assert_eq!(p.at(0.0), 0.6);
        assert_eq!(p.at(1e6), 0.6);
    }

    #[test]
    fn ramp_interpolates() {
        let p = LoadProgram::ramp(0.0, 1.0, 1000.0);
        assert!((p.at(500.0) - 0.5).abs() < 1e-9);
        assert_eq!(p.at(-10.0), 0.0);
        assert_eq!(p.at(2000.0), 1.0);
    }

    #[test]
    fn oscillation_alternates() {
        let p = LoadProgram::oscillating(0.5, 0.3, 200.0, 1000.0);
        assert!((p.at(0.0) - 0.8).abs() < 1e-9);
        assert!((p.at(100.0) - 0.2).abs() < 1e-9);
        assert!((p.at(200.0) - 0.8).abs() < 1e-9);
        // midpoint between knots interpolates through mid
        assert!((p.at(50.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn synthetic_source_couples_components() {
        let mut src = MetricsSource::synthetic(LoadProgram::constant(0.5));
        let s = src.sample(0.0);
        assert_eq!(s.cpu, 0.5);
        assert!(s.gpu < s.cpu && s.mem < s.gpu);
    }

    #[test]
    fn degrade_detector_needs_consecutive_zeros() {
        let mut d = DegradeDetector::new(3);
        assert!(!d.observe(0.0));
        assert!(!d.observe(0.0));
        assert!(d.observe(0.0), "third consecutive zero trips the signal");
        assert!(d.is_degraded());
        // one healthy sample clears it and resets the streak
        assert!(!d.observe(0.4));
        assert!(!d.observe(0.0));
        assert!(!d.observe(0.0));
        assert!(!d.is_degraded());
        assert!(d.observe(0.0));
    }

    #[test]
    fn degrade_detector_interrupted_streak_never_trips() {
        let mut d = DegradeDetector::new(4);
        for _ in 0..10 {
            d.observe(0.0);
            d.observe(0.0);
            d.observe(0.0);
            d.observe(0.5); // recovery one sample before the limit
        }
        assert!(!d.is_degraded());
    }

    #[test]
    fn proc_source_returns_sane_values() {
        let mut src = MetricsSource::proc();
        let _ = src.sample(0.0);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let s = src.sample(0.0);
        assert!((0.0..=1.0).contains(&s.cpu), "{s:?}");
        assert!((0.0..=1.0).contains(&s.mem), "{s:?}");
        assert!(s.capacity() >= 0.0 && s.capacity() <= 1.0);
    }
}
