//! §IX.C hysteresis-based fallback: a two-threshold state machine that
//! prevents route flapping when capacity hovers near the offload threshold.
//!
//!   - Fallback:  R < `low`  (paper: 70%) → prefer cloud
//!   - Recovery:  R > `high` (paper: 80%) → prefer local again
//!
//! The `high - low` dead zone (paper: 10%) absorbs transient spikes; E10
//! measures flap counts with and without it.

/// Current routing preference produced by the state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preference {
    Local,
    Cloud,
}

/// Two-threshold hysteresis state machine.
#[derive(Clone, Debug)]
pub struct Hysteresis {
    low: f64,
    high: f64,
    state: Preference,
    transitions: u64,
}

impl Hysteresis {
    /// Build with paper defaults low=0.70, high=0.80 via `Config`.
    pub fn new(low: f64, high: f64) -> Hysteresis {
        assert!(low <= high, "hysteresis requires low <= high");
        Hysteresis { low, high, state: Preference::Local, transitions: 0 }
    }

    /// Degenerate no-dead-zone variant (ablation: low == high).
    pub fn without_dead_zone(threshold: f64) -> Hysteresis {
        Hysteresis::new(threshold, threshold)
    }

    /// Feed a capacity sample R ∈ [0,1]; returns the (possibly updated)
    /// preference.
    pub fn observe(&mut self, capacity: f64) -> Preference {
        let next = match self.state {
            Preference::Local if capacity < self.low => Preference::Cloud,
            Preference::Cloud if capacity > self.high => Preference::Local,
            s => s,
        };
        if next != self.state {
            self.transitions += 1;
            self.state = next;
        }
        self.state
    }

    pub fn state(&self) -> Preference {
        self.state
    }

    /// Total number of local↔cloud flips observed (E10 metric).
    pub fn transitions(&self) -> u64 {
        self.transitions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_thresholds_behavior() {
        let mut h = Hysteresis::new(0.70, 0.80);
        assert_eq!(h.observe(0.90), Preference::Local);
        assert_eq!(h.observe(0.75), Preference::Local); // inside dead zone
        assert_eq!(h.observe(0.65), Preference::Cloud); // below fallback
        assert_eq!(h.observe(0.75), Preference::Cloud); // dead zone holds cloud
        assert_eq!(h.observe(0.85), Preference::Local); // above recovery
        assert_eq!(h.transitions(), 2);
    }

    #[test]
    fn dead_zone_prevents_flapping() {
        // capacity oscillates inside the dead zone: 0.72 ↔ 0.78
        let mut with = Hysteresis::new(0.70, 0.80);
        let mut without = Hysteresis::without_dead_zone(0.75);
        for i in 0..100 {
            let r = if i % 2 == 0 { 0.72 } else { 0.78 };
            with.observe(r);
            without.observe(r);
        }
        assert_eq!(with.transitions(), 0, "dead zone must absorb oscillation");
        assert!(without.transitions() > 90, "no dead zone should flap: {}", without.transitions());
    }

    #[test]
    fn boundary_values_do_not_transition() {
        let mut h = Hysteresis::new(0.70, 0.80);
        assert_eq!(h.observe(0.70), Preference::Local); // strictly-less required
        h.observe(0.60); // now cloud
        assert_eq!(h.observe(0.80), Preference::Cloud); // strictly-greater required
    }

    #[test]
    #[should_panic(expected = "low <= high")]
    fn inverted_thresholds_rejected() {
        Hysteresis::new(0.9, 0.1);
    }
}
