//! TIDE — Temporal Island Demand Evaluator (§IX).
//!
//! Monitors computational capacity (Eq. 3) on a sampling period, maintains
//! the §IX.C hysteresis preference and the exhaustion [`predictor`], and
//! exposes `capacity()` to WAVES (Algorithm 1 line 2).
//!
//! Fault tolerance (§IV.B): a crashed TIDE reports `R = 0` — resources
//! exhausted, the conservative fallback that pushes work to other islands
//! rather than overloading a blind local device (tested below and ablated
//! in E6 — "No TIDE: request failures, local island OOM, no fallback").

pub mod hysteresis;
pub mod monitor;
pub mod predictor;

use crate::config::Config;
use hysteresis::{Hysteresis, Preference};
use monitor::{MetricsSource, Sample};
use predictor::Predictor;

/// The TIDE agent for one (local) island.
pub struct Tide {
    source: Option<MetricsSource>,
    hysteresis: Hysteresis,
    predictor: Predictor,
    period_ms: f64,
    last_sample: Option<Sample>,
    last_sample_t: f64,
    now_ms: f64,
}

impl Tide {
    pub fn new(config: &Config, source: MetricsSource) -> Tide {
        Tide {
            source: Some(source),
            hysteresis: Hysteresis::new(config.hysteresis_low, config.hysteresis_high),
            predictor: Predictor::new(0.4),
            period_ms: config.tide_period_ms as f64,
            last_sample: None,
            last_sample_t: f64::NEG_INFINITY,
            now_ms: 0.0,
        }
    }

    /// Simulate an agent crash (§IV.B / E6 ablation).
    pub fn kill(&mut self) {
        self.source = None;
    }

    pub fn is_alive(&self) -> bool {
        self.source.is_some()
    }

    /// Advance virtual time and resample if the period has elapsed.
    pub fn tick(&mut self, now_ms: f64) {
        self.now_ms = now_ms;
        if now_ms - self.last_sample_t < self.period_ms {
            return;
        }
        if let Some(src) = self.source.as_mut() {
            let s = src.sample(now_ms);
            self.last_sample = Some(s);
            self.last_sample_t = now_ms;
            self.predictor.observe(now_ms, s.capacity());
            self.hysteresis.observe(s.capacity());
        }
    }

    /// Current available capacity R(t). Dead TIDE → 0.0 (fail conservative).
    pub fn capacity(&self) -> f64 {
        if self.source.is_none() {
            return 0.0;
        }
        self.last_sample.map(|s| s.capacity()).unwrap_or(1.0)
    }

    /// Hysteresis routing preference (E10).
    pub fn preference(&self) -> Preference {
        if self.source.is_none() {
            return Preference::Cloud;
        }
        self.hysteresis.state()
    }

    pub fn flaps(&self) -> u64 {
        self.hysteresis.transitions()
    }

    /// Proactive-offload signal: predicted capacity below `buffer` within
    /// one sampling horizon.
    pub fn exhaustion_imminent(&self, buffer: f64) -> bool {
        if self.source.is_none() {
            return true;
        }
        self.predictor.exhaustion_imminent(self.period_ms, buffer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use monitor::LoadProgram;

    fn tide_with(p: LoadProgram) -> Tide {
        let mut cfg = Config::default();
        cfg.tide_period_ms = 100;
        Tide::new(&cfg, MetricsSource::synthetic(p))
    }

    #[test]
    fn tracks_constant_load() {
        let mut t = tide_with(LoadProgram::constant(0.25));
        for step in 0..10 {
            t.tick(step as f64 * 100.0);
        }
        assert!((t.capacity() - 0.75).abs() < 1e-9);
        // R = 0.75 sits inside the 0.70/0.80 dead zone: stays Local
        assert_eq!(t.preference(), Preference::Local);
        // §IX.C: R = 0.6 < 0.70 flips the preference to Cloud
        let mut t2 = tide_with(LoadProgram::constant(0.4));
        t2.tick(0.0);
        assert_eq!(t2.preference(), Preference::Cloud);
    }

    #[test]
    fn heavy_load_prefers_cloud() {
        let mut t = tide_with(LoadProgram::constant(0.95));
        for step in 0..5 {
            t.tick(step as f64 * 100.0);
        }
        assert!(t.capacity() < 0.1);
        assert_eq!(t.preference(), Preference::Cloud);
    }

    #[test]
    fn killed_tide_fails_conservative() {
        let mut t = tide_with(LoadProgram::constant(0.0));
        t.tick(0.0);
        assert_eq!(t.capacity(), 1.0);
        t.kill();
        assert_eq!(t.capacity(), 0.0);
        assert_eq!(t.preference(), Preference::Cloud);
        assert!(t.exhaustion_imminent(0.2));
        assert!(!t.is_alive());
    }

    #[test]
    fn ramp_triggers_exhaustion_prediction() {
        let mut t = tide_with(LoadProgram::ramp(0.2, 1.0, 1000.0));
        for step in 0..11 {
            t.tick(step as f64 * 100.0);
        }
        assert!(t.exhaustion_imminent(0.3));
    }

    #[test]
    fn respects_sampling_period() {
        let mut t = tide_with(LoadProgram::ramp(0.0, 1.0, 1000.0));
        t.tick(0.0);
        let c0 = t.capacity();
        t.tick(10.0); // before the period elapses: no resample
        assert_eq!(t.capacity(), c0);
        t.tick(150.0);
        assert!(t.capacity() < c0);
    }
}
