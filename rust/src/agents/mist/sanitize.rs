//! Typed-placeholder sanitization with reversible bidirectional mapping —
//! the paper's Def. 4 transformation τ and mapping φ (§VII.B).
//!
//! Forward pass: detected entities whose kind-sensitivity exceeds the target
//! island's privacy score are replaced by typed placeholders
//! (`[PERSON_483]`), preserving semantic structure so the remote LLM can
//! still reason about entity relationships. The same entity value always
//! maps to the same placeholder *within a session* (coherence across turns),
//! while identifier numbers are drawn from a session-seeded RNG
//! (Attack-3 mitigation: mappings are not comparable across sessions).
//!
//! Backward pass: placeholders in the island's response are resolved back to
//! the original values before the user sees them.

use std::collections::HashMap;

use once_cell::sync::Lazy;
use regex::Regex;

use crate::agents::mist::entities::{detect, Entity, EntityKind};
use crate::types::{Role, Turn};
use crate::util::Rng;

/// Default size of the random placeholder-id space per session.
const ID_SPACE: u64 = 1_000_000;
/// Random draws attempted before falling back to sequential ids.
const MAX_ID_RETRIES: u32 = 16;

/// Session-scoped bidirectional placeholder map (φ).
#[derive(Clone, Debug)]
pub struct PlaceholderMap {
    forward: HashMap<String, String>, // entity value -> placeholder
    reverse: HashMap<String, String>, // placeholder -> entity value
    rng: Rng,
    /// Upper bound (exclusive) of the random id range `[1, id_space)`.
    id_space: u64,
    /// Next sequential id for the deterministic fallback; starts at
    /// `id_space` so fallback ids never collide with random ones.
    next_seq: u64,
}

static RE_PLACEHOLDER: Lazy<Regex> = Lazy::new(|| {
    // islandlint: allow(serving-path-panic) -- constant pattern, exercised by every sanitize unit test; compiles once at first use
    Regex::new(r"\[[A-Z][A-Z_]*_\d+\]").unwrap()
});

impl PlaceholderMap {
    /// Create a map for one session. Different sessions must use different
    /// seeds (the session store derives them from the session id).
    pub fn new(session_seed: u64) -> PlaceholderMap {
        PlaceholderMap::with_id_space(session_seed, ID_SPACE)
    }

    /// Like [`PlaceholderMap::new`] with an explicit random-id space
    /// (test/bench hook: a tiny space forces the sequential fallback).
    pub fn with_id_space(session_seed: u64, id_space: u64) -> PlaceholderMap {
        let id_space = id_space.max(2);
        PlaceholderMap {
            forward: HashMap::new(),
            reverse: HashMap::new(),
            rng: Rng::new(session_seed),
            id_space,
            next_seq: id_space,
        }
    }

    /// Number of distinct entities currently mapped.
    pub fn len(&self) -> usize {
        self.forward.len()
    }

    pub fn is_empty(&self) -> bool {
        self.forward.is_empty()
    }

    fn placeholder_for(&mut self, kind: EntityKind, value: &str) -> String {
        // normalize the key so "John Doe" and "john doe" share a placeholder
        let key = value.to_lowercase();
        if let Some(p) = self.forward.get(&key) {
            return p.clone();
        }
        // Random, session-scoped identifier with BOUNDED retries: the old
        // unbounded loop hung a worker once one kind's id space filled up.
        // After the retry budget, fall back to a deterministic sequential
        // counter that starts past the random range (disjoint, so the scan
        // below terminates after at most a few occupied slots).
        for _ in 0..MAX_ID_RETRIES {
            let id = self.rng.range_u64(1, self.id_space);
            let placeholder = format!("[{}_{}]", kind.prefix(), id);
            if !self.reverse.contains_key(&placeholder) {
                self.forward.insert(key, placeholder.clone());
                self.reverse.insert(placeholder.clone(), value.to_string());
                return placeholder;
            }
        }
        loop {
            let id = self.next_seq;
            self.next_seq += 1;
            let placeholder = format!("[{}_{}]", kind.prefix(), id);
            if !self.reverse.contains_key(&placeholder) {
                self.forward.insert(key, placeholder.clone());
                self.reverse.insert(placeholder.clone(), value.to_string());
                return placeholder;
            }
        }
    }

    /// Forward transformation τ: replace entities with sensitivity above
    /// `target_privacy` by typed placeholders.
    pub fn sanitize(&mut self, text: &str, target_privacy: f64) -> String {
        let entities = detect(text);
        self.splice(text, &entities, target_privacy)
    }

    /// Splice precomputed entities into `text`: the cheap half of
    /// [`PlaceholderMap::sanitize`], for callers that ran [`detect`] on an
    /// immutable snapshot *outside* the lock guarding this map. `entities`
    /// must be `detect(text)`'s output (sorted, non-overlapping, in-bounds
    /// char-boundary spans).
    pub fn splice(&mut self, text: &str, entities: &[Entity], target_privacy: f64) -> String {
        let mut out = String::with_capacity(text.len());
        let mut cursor = 0;
        for e in entities {
            if e.kind.sensitivity() <= target_privacy {
                continue; // safe to reveal at this trust level
            }
            out.push_str(&text[cursor..e.start]);
            let p = self.placeholder_for(e.kind, &e.text);
            out.push_str(&p);
            cursor = e.end;
        }
        out.push_str(&text[cursor..]);
        out
    }

    /// Backward pass: restore original values for every known placeholder in
    /// a response. Unknown placeholders are left intact (the island may have
    /// invented one; surfacing it beats hallucinating a value).
    pub fn desanitize(&self, text: &str) -> String {
        RE_PLACEHOLDER
            .replace_all(text, |caps: &regex::Captures<'_>| {
                // capture 0 (the whole match) always exists
                let p = caps.get(0).map(|m| m.as_str()).unwrap_or_default();
                self.reverse.get(p).cloned().unwrap_or_else(|| p.to_string())
            })
            .into_owned()
    }

    /// Verify PII(h') = ∅ for the Def. 4 guarantee: after sanitization at
    /// `target_privacy`, no detectable entity above that level remains.
    pub fn verify_clean(text: &str, target_privacy: f64) -> bool {
        detect(text).iter().all(|e| e.kind.sensitivity() <= target_privacy)
    }
}

/// Sanitize a whole chat history (Algorithm 1 line 15:
/// `h'_r ← MIST.Sanitize(h_r, P_i*)`).
pub fn sanitize_history(history: &[Turn], target_privacy: f64, map: &mut PlaceholderMap) -> Vec<Turn> {
    history
        .iter()
        .map(|t| Turn { role: t.role, text: map.sanitize(&t.text, target_privacy) })
        .collect()
}

/// Convenience constructor for history turns in tests/examples.
pub fn turn(role: Role, text: &str) -> Turn {
    Turn { role, text: text.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_examples_round_trip() {
        // §VII.B: "Patient John Doe" → "Patient [PERSON_x]",
        //         "Chicago hospital" → "[LOCATION_y] hospital"
        let mut map = PlaceholderMap::new(42);
        let s = map.sanitize("Patient John Doe was admitted to the Chicago hospital", 0.4);
        assert!(!s.contains("John"), "{s}");
        assert!(!s.contains("Chicago"), "{s}");
        assert!(s.contains("[PERSON_"), "{s}");
        assert!(s.contains("[LOCATION_"), "{s}");
        // backward pass restores the original values
        let restored = map.desanitize(&s);
        assert!(restored.contains("John Doe"));
        assert!(restored.contains("Chicago"));
    }

    #[test]
    fn response_with_placeholder_is_resolved() {
        // §VII.B backward pass: cloud answers "[PERSON_1] should consult..."
        let mut map = PlaceholderMap::new(1);
        let s = map.sanitize("john doe has diabetes", 0.4);
        let person_ph = s.split_whitespace().find(|w| w.starts_with("[PERSON_")).unwrap();
        let response = format!("{person_ph} should consult a specialist");
        assert_eq!(map.desanitize(&response), "john doe should consult a specialist");
    }

    #[test]
    fn same_entity_same_placeholder_within_session() {
        let mut map = PlaceholderMap::new(7);
        let a = map.sanitize("john doe called", 0.4);
        let b = map.sanitize("call John Doe back", 0.4);
        let pa = a.split_whitespace().next().unwrap();
        assert!(b.contains(pa), "a={a} b={b}");
        assert_eq!(map.len(), 1);
    }

    #[test]
    fn different_sessions_different_identifiers() {
        // Attack-3 mitigation: per-session random ids
        let mut m1 = PlaceholderMap::new(100);
        let mut m2 = PlaceholderMap::new(200);
        let mut diff = 0;
        for text in ["john doe", "jane smith", "arun patel", "maria garcia", "wei chen"] {
            let a = m1.sanitize(text, 0.4);
            let b = m2.sanitize(text, 0.4);
            if a != b {
                diff += 1;
            }
        }
        assert!(diff >= 3, "sessions should disagree on most ids, diff={diff}");
    }

    #[test]
    fn sensitivity_threshold_gates_replacement() {
        let mut map = PlaceholderMap::new(3);
        let text = "meet in chicago on 2024-01-05";
        // Location sens = 0.6, Temporal = 0.5.
        // At P=0.8 (private edge): nothing replaced.
        assert_eq!(map.sanitize(text, 0.8), text);
        // At P=0.55: location replaced, temporal kept.
        let mid = map.sanitize(text, 0.55);
        assert!(mid.contains("[LOCATION_") && mid.contains("2024-01-05"), "{mid}");
        // At P=0.4 (cloud): both replaced.
        let low = map.sanitize(text, 0.4);
        assert!(low.contains("[LOCATION_") && low.contains("[TEMPORAL_REFERENCE_"), "{low}");
    }

    #[test]
    fn sanitized_text_verifies_clean() {
        let mut map = PlaceholderMap::new(11);
        let dirty = "patient john doe ssn 123-45-6789 prescribed metformin in chicago";
        let clean = map.sanitize(dirty, 0.4);
        assert!(PlaceholderMap::verify_clean(&clean, 0.4), "{clean}");
        assert!(!PlaceholderMap::verify_clean(dirty, 0.4));
    }

    #[test]
    fn unknown_placeholders_left_intact() {
        let map = PlaceholderMap::new(5);
        assert_eq!(map.desanitize("ask [PERSON_999] about it"), "ask [PERSON_999] about it");
    }

    #[test]
    fn history_sanitization_applies_per_turn() {
        let mut map = PlaceholderMap::new(13);
        let history = vec![
            turn(Role::User, "patient john doe has diabetes"),
            turn(Role::Assistant, "john doe should monitor glucose"),
            turn(Role::User, "what are general complications"),
        ];
        let clean = sanitize_history(&history, 0.4, &mut map);
        assert_eq!(clean.len(), 3);
        assert!(!clean[0].text.contains("john"));
        assert!(!clean[1].text.contains("john"));
        // same placeholder across turns (coherence)
        let p0 = clean[0].text.split_whitespace().find(|w| w.starts_with("[PERSON_")).unwrap().to_string();
        assert!(clean[1].text.contains(&p0));
        assert_eq!(clean[2].text, "what are general complications");
    }

    #[test]
    fn idempotent_on_clean_text() {
        let mut map = PlaceholderMap::new(17);
        let text = "explain how rust ownership works";
        assert_eq!(map.sanitize(text, 0.4), text);
        assert!(map.is_empty());
    }

    #[test]
    fn two_thousand_distinct_entities_of_one_kind_terminate_with_unique_ids() {
        // regression: the old 999-id space + unbounded retry loop hung a
        // worker once a session accumulated >999 distinct PERSONs
        let mut map = PlaceholderMap::new(31);
        let mut placeholders = std::collections::HashSet::new();
        for i in 0..2_000 {
            // synthetic distinct values of one kind, inserted directly
            // through the id allocator
            let p = map.placeholder_for(EntityKind::Person, &format!("person-{i}"));
            assert!(p.starts_with("[PERSON_") && p.ends_with(']'), "{p}");
            assert!(placeholders.insert(p.clone()), "duplicate placeholder {p}");
            // the reverse map resolves every placeholder back
            assert_eq!(map.desanitize(&p), format!("person-{i}"));
        }
        assert_eq!(map.len(), 2_000);
    }

    #[test]
    fn exhausted_random_space_falls_back_to_sequential_ids() {
        // a 4-slot random space exhausts immediately: the deterministic
        // fallback must keep allocating unique ids without spinning
        let mut map = PlaceholderMap::with_id_space(7, 4);
        let mut placeholders = std::collections::HashSet::new();
        for i in 0..100 {
            let p = map.placeholder_for(EntityKind::Person, &format!("p{i}"));
            assert!(placeholders.insert(p), "duplicate at {i}");
        }
        assert_eq!(map.len(), 100);
        // sequential ids start past the random range
        assert!(placeholders.iter().any(|p| p.contains("[PERSON_4")), "{placeholders:?}");
    }

    #[test]
    fn splice_matches_sanitize_for_precomputed_entities() {
        let text = "patient john doe ssn 123-45-6789 in chicago";
        let entities = crate::agents::mist::entities::detect(text);
        let mut a = PlaceholderMap::new(99);
        let mut b = PlaceholderMap::new(99);
        assert_eq!(a.sanitize(text, 0.4), b.splice(text, &entities, 0.4));
    }

    #[test]
    fn desanitize_is_inverse_even_with_multiple_entities() {
        let mut map = PlaceholderMap::new(23);
        let orig = "jane smith met arun patel in berlin";
        let s = map.sanitize(orig, 0.4);
        // all three entities replaced
        assert_eq!(s.matches('[').count(), 3, "{s}");
        assert_eq!(map.desanitize(&s), orig);
    }
}
