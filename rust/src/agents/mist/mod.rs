//! MIST — Multi-level Intelligent Sensitivity Tracker (§VII).
//!
//! Two-stage sensitivity pipeline:
//!   Stage 1: regex pattern matching ([`patterns`]) establishing regulatory
//!            floors (PII ≥ 0.8, HIPAA/financial ≥ 0.9).
//!   Stage 2: contextual classification into public/internal/confidential/
//!            restricted (0.2/0.5/0.8/1.0). The paper uses a local small
//!            language model; ours is the AOT-compiled n-gram MLP served via
//!            PJRT ([`Stage2::Classifier`]), with a keyword heuristic
//!            ([`Stage2::Heuristic`]) for pure-simulation experiments.
//!
//! Final score: `s_r = max(stage1_floor, stage2_score)`.
//!
//! Fault tolerance (§IV.B): if Stage 2 fails (engine down), MIST assumes
//! `s_r = 1.0` — all data sensitive, the conservative fallback.
//!
//! Sanitization (τ/φ of Def. 4) lives in [`sanitize`]; entity detection in
//! [`entities`].

pub mod entities;
pub mod patterns;
pub mod sanitize;

use crate::runtime::EngineHandle;
use crate::types::Request;

/// Stage-2 classifier backend.
pub enum Stage2 {
    /// AOT classifier artifact via the PJRT engine (production path).
    Classifier(EngineHandle),
    /// Keyword heuristic (fast path for large simulations).
    Heuristic,
    /// Simulated failure: every Stage-2 call errors (for the §IV.B
    /// fail-conservative tests and the E6 ablation).
    Broken,
}

/// Sensitivity classes (Stage-2 output), §VII.A Stage 2.
pub const CLASS_SENSITIVITY: [f64; 4] = [0.2, 0.5, 0.8, 1.0];

/// Full analysis result.
#[derive(Clone, Debug, PartialEq)]
pub struct SensitivityReport {
    /// Final s_r for the *current prompt* — the routing constraint.
    pub score: f64,
    /// Stage-1 regulatory floor (0.0 when no pattern matched).
    pub stage1_floor: f64,
    /// Stage-2 class index (0..4) if the classifier ran.
    pub stage2_class: Option<usize>,
    /// Max sensitivity found in the chat history. NOT folded into `score`:
    /// per §I.A / §VII.B, a general follow-up after a sensitive topic may
    /// still route to lower-trust islands — the history is protected by the
    /// τ sanitization on the trust-boundary crossing, not by routing.
    pub history_score: f64,
    /// True when the conservative fallback (s_r = 1) was applied.
    pub failed_closed: bool,
}

/// The MIST agent.
pub struct Mist {
    stage2: Stage2,
}

impl Mist {
    pub fn new(stage2: Stage2) -> Mist {
        Mist { stage2 }
    }

    /// Heuristic-only MIST for simulations.
    pub fn heuristic() -> Mist {
        Mist::new(Stage2::Heuristic)
    }

    /// Analyze a text's sensitivity (both stages).
    pub fn analyze_text(&self, text: &str) -> SensitivityReport {
        let floor = patterns::stage1_floor(text);
        match self.stage2_score(text) {
            Ok((class, s2)) => SensitivityReport {
                score: floor.max(s2),
                stage1_floor: floor,
                stage2_class: Some(class),
                history_score: 0.0,
                failed_closed: false,
            },
            Err(_) => SensitivityReport {
                // §IV.B: MIST crash → assume all data sensitive.
                score: 1.0,
                stage1_floor: floor,
                stage2_class: None,
                history_score: 0.0,
                failed_closed: true,
            },
        }
    }

    /// Analyze a request. The routing score (`score`) comes from the
    /// current prompt; history sensitivity is reported separately
    /// (`history_score`) and protected by sanitization on trust-boundary
    /// crossings rather than by the routing constraint (§I.A, §VII.B).
    pub fn analyze(&self, request: &Request) -> SensitivityReport {
        let mut report = self.analyze_text(&request.prompt);
        for turn in &request.history {
            let r = self.analyze_text(&turn.text);
            report.history_score = report.history_score.max(r.score);
            report.failed_closed |= r.failed_closed;
        }
        if report.failed_closed {
            report.score = 1.0;
        }
        report
    }

    fn stage2_score(&self, text: &str) -> anyhow::Result<(usize, f64)> {
        match &self.stage2 {
            Stage2::Classifier(engine) => {
                let probs = engine.classify(vec![text.to_string()])?;
                let row = probs.first().ok_or_else(|| anyhow::anyhow!("empty classifier output"))?;
                let class = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(3);
                Ok((class, CLASS_SENSITIVITY[class.min(3)]))
            }
            Stage2::Heuristic => {
                let class = heuristic_class(text);
                Ok((class, CLASS_SENSITIVITY[class]))
            }
            Stage2::Broken => Err(anyhow::anyhow!("stage2 classifier unavailable")),
        }
    }
}

/// Keyword heuristic mirroring the classifier's training distribution
/// (substrate::trace templates): restricted > confidential > internal >
/// public.
fn heuristic_class(text: &str) -> usize {
    let t = text.to_lowercase();
    let restricted = ["patient", "ssn", "mrn", "hba1c", "wire transfer", "card 4", "routing"];
    let confidential = ["@", "salary", "offer letter", "my name is", "candidate", "invoice", "ip 10."];
    let internal = [
        "standup", "sync", "sprint", "migration", "agenda", "onboarding", "refactor", "team", "literature",
        "guidelines", "estimate effort",
    ];
    if restricted.iter().any(|k| t.contains(k)) {
        3
    } else if confidential.iter().any(|k| t.contains(k)) {
        2
    } else if internal.iter().any(|k| t.contains(k)) {
        1
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Role, Turn};

    #[test]
    fn phi_text_scores_restricted() {
        let mist = Mist::heuristic();
        let r = mist.analyze_text("patient john doe ssn 123-45-6789 diagnosed with diabetes");
        assert!(r.score >= 0.9, "{r:?}");
        assert_eq!(r.stage2_class, Some(3));
        assert!(!r.failed_closed);
    }

    #[test]
    fn public_text_scores_low() {
        let mist = Mist::heuristic();
        let r = mist.analyze_text("what is the capital of france");
        assert_eq!(r.score, 0.2);
        assert_eq!(r.stage1_floor, 0.0);
    }

    #[test]
    fn internal_text_scores_half() {
        let mist = Mist::heuristic();
        let r = mist.analyze_text("draft the agenda for the platform team standup");
        assert_eq!(r.score, 0.5);
    }

    #[test]
    fn stage1_floor_dominates_lenient_stage2() {
        let mist = Mist::heuristic();
        // no restricted keywords but contains an email: floor 0.8 wins
        let r = mist.analyze_text("send the doc to a@b.co when ready");
        assert!(r.score >= 0.8, "{r:?}");
        assert_eq!(r.stage1_floor, 0.8);
    }

    #[test]
    fn broken_stage2_fails_closed() {
        let mist = Mist::new(Stage2::Broken);
        let r = mist.analyze_text("what is the capital of france");
        assert_eq!(r.score, 1.0);
        assert!(r.failed_closed);
    }

    #[test]
    fn history_reported_separately_from_routing_score() {
        // §VII.B challenge: a general follow-up after a sensitive topic may
        // still route broadly — the history is protected by sanitization.
        let mist = Mist::heuristic();
        let req = Request::new(1, "what are the usual next steps").with_history(vec![Turn {
            role: Role::User,
            text: "patient john doe ssn 123-45-6789 has elevated hba1c".to_string(),
        }]);
        let r = mist.analyze(&req);
        assert!(r.score <= 0.3, "prompt itself is benign: {r:?}");
        assert!(r.history_score >= 0.9, "history sensitivity must be surfaced: {r:?}");
    }

    #[test]
    fn motivating_example_scores() {
        // §I.A: sensitive query s_r = 0.9 (high), general query s_r ≈ 0.3
        let mist = Mist::heuristic();
        let sensitive = mist.analyze_text("Analyze treatment options for 45-year-old diabetic patient with elevated HbA1c");
        assert!(sensitive.score >= 0.9, "{sensitive:?}");
        let general = mist.analyze_text("What are common complications of long term conditions?");
        assert!(general.score <= 0.3, "{general:?}");
    }
}
