//! MIST Stage-1: regex pattern matching (§VII.A).
//!
//! ~50 compiled patterns across three regulated categories, each imposing a
//! sensitivity *floor* on the request:
//!
//! - PII (email, phone, SSN, IP, passport, plates, …)    → s_r ≥ 0.8
//! - HIPAA (diagnoses, medications, MRN, ICD codes, …)   → s_r ≥ 0.9
//! - Financial (cards, IBAN, routing numbers, crypto, …) → s_r ≥ 0.9
//!
//! The set size (m ≈ 50) matches the paper's §VI.B complexity analysis
//! (`O(|q|·m)`; <10 ms routing at n<10, m≈50 — benchmarked in E7).

use once_cell::sync::Lazy;
use regex::Regex;

/// Pattern category with its sensitivity floor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    Pii,
    Hipaa,
    Financial,
}

impl Category {
    /// §VII.A sensitivity floors.
    pub fn floor(self) -> f64 {
        match self {
            Category::Pii => 0.8,
            Category::Hipaa => 0.9,
            Category::Financial => 0.9,
        }
    }
}

/// One compiled Stage-1 pattern.
pub struct Pattern {
    pub name: &'static str,
    pub category: Category,
    pub regex: Regex,
    /// A literal (lowercase) that MUST occur, case-insensitively, in any
    /// text this regex can match. Scanning checks the literal with a cheap
    /// ASCII-folded substring search and skips the regex when absent, so
    /// clean text pays one memmem per keyword pattern instead of a full
    /// regex pass. `None` for purely structural patterns (digit shapes).
    pub gate: Option<&'static str>,
}

/// A Stage-1 match found in a request.
#[derive(Clone, Debug, PartialEq)]
pub struct Match {
    pub pattern: &'static str,
    pub category: Category,
    pub start: usize,
    pub end: usize,
}

macro_rules! patterns {
    ($(($name:literal, $cat:expr, $re:literal, $gate:expr)),+ $(,)?) => {
        // islandlint: allow(serving-path-panic) -- the Stage-1 pattern table is a compile-time constant exercised by unit tests; first-use compile is boot-time, not per request
        vec![$(Pattern { name: $name, category: $cat, regex: Regex::new($re).expect($name), gate: $gate }),+]
    };
}

/// The full Stage-1 pattern set (m ≈ 50). Gate literals are chosen
/// conservatively: only a literal the regex requires in EVERY match (up to
/// ASCII case) may gate it; structural digit-shape patterns stay ungated.
pub static PATTERNS: Lazy<Vec<Pattern>> = Lazy::new(|| {
    use Category::*;
    patterns![
        // ---------------- PII ----------------
        ("email", Pii, r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b", Some("@")),
        ("phone-us", Pii, r"\b\d{3}[-. ]\d{3}[-. ]\d{4}\b", None),
        ("phone-intl", Pii, r"\+\d{1,3}[ -]?\d{2,4}[ -]?\d{3,4}[ -]?\d{3,4}\b", Some("+")),
        ("ssn", Pii, r"\b\d{3}-\d{2}-\d{4}\b", None),
        ("ipv4", Pii, r"\b(?:\d{1,3}\.){3}\d{1,3}\b", Some(".")),
        ("ipv6", Pii, r"(?i)\b(?:[0-9a-f]{1,4}:){3,7}[0-9a-f]{1,4}\b", Some(":")),
        ("mac-addr", Pii, r"(?i)\b(?:[0-9a-f]{2}:){5}[0-9a-f]{2}\b", Some(":")),
        ("passport", Pii, r"(?i)\bpassport\s*(?:no\.?|number)?\s*[:#]?\s*[a-z]?\d{7,9}\b", Some("passport")),
        ("drivers-license", Pii, r"(?i)\b(?:driver'?s?\s+licen[sc]e|dl)\s*[:#]?\s*[a-z]?\d{6,9}\b", None),
        ("plate", Pii, r"(?i)\blicense\s+plate\s*[:#]?\s*[a-z0-9-]{5,8}\b", Some("plate")),
        ("dob", Pii, r"(?i)\b(?:dob|date\s+of\s+birth)\s*[:#]?\s*\d{1,4}[-/]\d{1,2}[-/]\d{1,4}\b", None),
        ("street-address", Pii, r"(?i)\b\d{1,5}\s+[a-z]+\s+(?:st|street|ave|avenue|rd|road|blvd|lane|ln|dr|drive)\b", None),
        ("zip+4", Pii, r"\b\d{5}-\d{4}\b", None),
        ("geo-coord", Pii, r"-?\d{1,3}\.\d{4,},\s*-?\d{1,3}\.\d{4,}", Some(",")),
        ("aadhaar", Pii, r"\b\d{4}\s\d{4}\s\d{4}\b", None),
        ("national-id", Pii, r"(?i)\bnational\s+id\s*[:#]?\s*\d{6,12}\b", Some("national")),
        ("username-handle", Pii, r"(?i)\bmy\s+(?:name|username)\s+is\s+[a-z][a-z .'-]{2,40}\b", Some("my")),
        ("api-key", Pii, r"\b(?:sk|pk|api)[-_](?:live|test)?[-_]?[A-Za-z0-9]{16,}\b", None),
        ("password-assign", Pii, r"(?i)\bpassword\s*[:=]\s*\S{6,}", Some("password")),
        ("ssh-key", Pii, r"ssh-(?:rsa|ed25519)\s+[A-Za-z0-9+/=]{40,}", Some("ssh-")),
        // ---------------- HIPAA / PHI ----------------
        ("patient-kw", Hipaa, r"(?i)\bpatient\b", Some("patient")),
        ("mrn", Hipaa, r"(?i)\bmrn\s*[:#]?\s*\d{4,10}\b", Some("mrn")),
        ("icd10", Hipaa, r"(?i)\b[a-tv-z]\d{2}(?:\.\d{1,4})?\b\s*(?:code|diagnos)", None),
        ("diagnosis-kw", Hipaa, r"(?i)\bdiagnos(?:is|ed|tic)\b", Some("diagnos")),
        ("prescription", Hipaa, r"(?i)\bprescri(?:bed?|ption)\b", Some("prescri")),
        ("dosage", Hipaa, r"(?i)\b\d+\s*(?:mg|mcg|ml|units?)\s+(?:daily|twice|bid|tid|qid|per\s+day)\b", None),
        ("med-metformin", Hipaa, r"(?i)\bmetformin\b", Some("metformin")),
        ("med-insulin", Hipaa, r"(?i)\binsulin\b", Some("insulin")),
        ("med-lisinopril", Hipaa, r"(?i)\blisinopril\b", Some("lisinopril")),
        ("med-atorvastatin", Hipaa, r"(?i)\batorvastatin\b", Some("atorvastatin")),
        ("hba1c", Hipaa, r"(?i)\bhba1c\b", Some("hba1c")),
        ("blood-pressure", Hipaa, r"\b\d{2,3}/\d{2,3}\s*(?:mmhg|bp)\b", Some("/")),
        ("lab-result", Hipaa, r"(?i)\b(?:glucose|cholesterol|a1c|creatinine)\s+(?:level|result)s?\b", None),
        ("condition-diabetes", Hipaa, r"(?i)\bdiabet(?:es|ic)\b", Some("diabet")),
        ("condition-hypertension", Hipaa, r"(?i)\bhypertension\b", Some("hypertension")),
        ("condition-cancer", Hipaa, r"(?i)\b(?:cancer|oncolog|chemotherapy)\b", None),
        ("condition-hiv", Hipaa, r"(?i)\bhiv(?:\s+positive)?\b", Some("hiv")),
        ("condition-mental", Hipaa, r"(?i)\b(?:depression|anxiety\s+disorder|schizophrenia|bipolar)\b", None),
        ("symptom-report", Hipaa, r"(?i)\bsymptoms?\s+(?:of|include|analysis)\b", Some("symptom")),
        ("treatment-plan", Hipaa, r"(?i)\btreatment\s+(?:options?|plan)\b", Some("treatment")),
        ("health-insurance-id", Hipaa, r"(?i)\b(?:member|policy)\s+id\s*[:#]?\s*[a-z0-9]{6,14}\b", Some("id")),
        // ---------------- Financial ----------------
        ("card-visa", Financial, r"\b4\d{3}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b", None),
        ("card-mc", Financial, r"\b5[1-5]\d{2}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b", None),
        ("card-amex", Financial, r"\b3[47]\d{2}[- ]?\d{6}[- ]?\d{5}\b", None),
        ("cvv", Financial, r"(?i)\bcvv2?\s*[:#]?\s*\d{3,4}\b", Some("cvv")),
        ("iban", Financial, r"\b[A-Z]{2}\d{2}[A-Z0-9]{11,30}\b", None),
        ("swift", Financial, r"(?i)\bswift\s*(?:code)?\s*[:#]?\s*[a-z]{6}[a-z0-9]{2,5}\b", Some("swift")),
        ("routing-number", Financial, r"(?i)\brouting\s*(?:no\.?|number)?\s*[:#]?\s*\d{9}\b", Some("routing")),
        ("account-number", Financial, r"(?i)\baccount\s*(?:no\.?|number)?\s*[:#]?\s*\d{8,12}\b", Some("account")),
        ("wire-transfer", Financial, r"(?i)\bwire\s+transfer\b", Some("wire")),
        ("salary", Financial, r"(?i)\bsalary\s+(?:review|of|is)\b", Some("salary")),
        ("crypto-btc", Financial, r"\b(?:bc1|[13])[a-km-zA-HJ-NP-Z1-9]{25,42}\b", None),
        ("tax-ein", Financial, r"\b\d{2}-\d{7}\b", None),
    ]
});

/// Scan text, returning every Stage-1 match. Each pattern's regex runs at
/// most once; keyword-anchored patterns are skipped entirely when their
/// required literal is absent (see [`Pattern::gate`]). The text is
/// ASCII-folded once up front — `to_ascii_lowercase` is byte-preserving,
/// so the folded copy is valid UTF-8 and gate checks are plain (optimized)
/// substring searches against already-lowercase literals.
pub fn scan(text: &str) -> Vec<Match> {
    let folded = text.to_ascii_lowercase();
    let mut out = Vec::new();
    for p in PATTERNS.iter() {
        if let Some(lit) = p.gate {
            if !folded.contains(lit) {
                continue;
            }
        }
        for m in p.regex.find_iter(text) {
            out.push(Match { pattern: p.name, category: p.category, start: m.start(), end: m.end() });
        }
    }
    out
}

/// Is this HIPAA pattern mere *content* (a condition/medication mention)
/// rather than patient *context* (identifiers, prescriptions, diagnoses)?
/// Content alone — e.g. a literature search naming a disease — floors at
/// 0.5 (private-edge tolerable, §III.D Scenario B); any context match
/// raises the floor to the full 0.9.
fn is_hipaa_content_only(name: &str) -> bool {
    name.starts_with("condition-") || name.starts_with("med-") || name == "hba1c" || name == "lab-result"
}

/// Stage-1 sensitivity floor for the text: max category floor over matches,
/// 0.0 when clean. HIPAA condition/medication mentions without patient
/// context floor at 0.5 instead of 0.9 (see [`is_hipaa_content_only`]).
pub fn stage1_floor(text: &str) -> f64 {
    let matches = scan(text);
    let mut floor: f64 = 0.0;
    for m in &matches {
        let f = if m.category == Category::Hipaa && is_hipaa_content_only(m.pattern) { 0.5 } else { m.category.floor() };
        floor = floor.max(f);
    }
    floor
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_count_near_paper_m() {
        // §VI.B assumes m ≈ 50
        let m = PATTERNS.len();
        assert!((45..=60).contains(&m), "m={m}");
    }

    #[test]
    fn pii_floors() {
        assert_eq!(stage1_floor("contact me at jane@example.com"), 0.8);
        assert_eq!(stage1_floor("call 555-123-4567 tomorrow"), 0.8);
        assert_eq!(stage1_floor("my ip is 10.0.0.12"), 0.8);
    }

    #[test]
    fn hipaa_floors_dominate() {
        assert_eq!(stage1_floor("patient diagnosed with diabetes"), 0.9);
        assert_eq!(stage1_floor("prescribed metformin 500 mg daily"), 0.9);
        assert_eq!(stage1_floor("ssn 123-45-6789 of a patient"), 0.9); // max(0.8, 0.9)
    }

    #[test]
    fn condition_mention_without_patient_context_floors_at_half() {
        // §III.D Scenario B: literature searches are moderate sensitivity
        assert_eq!(stage1_floor("search medical literature for diabetes guidelines"), 0.5);
        assert_eq!(stage1_floor("how does insulin regulate glucose"), 0.5);
        // adding patient context restores the full floor
        assert_eq!(stage1_floor("patient needs insulin"), 0.9);
    }

    #[test]
    fn financial_floors() {
        assert_eq!(stage1_floor("charge card 4111-1111-1111-1234"), 0.9);
        assert_eq!(stage1_floor("wire transfer from account 1234567890"), 0.9);
        assert_eq!(stage1_floor("routing number 021000021"), 0.9);
    }

    #[test]
    fn clean_text_scores_zero() {
        for text in [
            "what is the capital of france",
            "explain how rust ownership works",
            "write a haiku about islands",
        ] {
            assert_eq!(stage1_floor(text), 0.0, "{text}");
        }
    }

    #[test]
    fn match_positions_are_correct() {
        let text = "email: a@b.co end";
        let ms = scan(text);
        let email = ms.iter().find(|m| m.pattern == "email").unwrap();
        assert_eq!(&text[email.start..email.end], "a@b.co");
    }

    #[test]
    fn multiple_matches_reported() {
        let ms = scan("patient jane, ssn 123-45-6789, card 4111 1111 1111 1111");
        let cats: std::collections::HashSet<_> = ms.iter().map(|m| m.category).collect();
        assert!(cats.contains(&Category::Pii));
        assert!(cats.contains(&Category::Hipaa));
        assert!(cats.contains(&Category::Financial));
    }

    #[test]
    fn case_insensitive_where_expected() {
        assert_eq!(stage1_floor("PATIENT WITH HYPERTENSION"), 0.9);
        assert_eq!(stage1_floor("Email ME at X@Y.ORG"), 0.8);
    }

    /// `scan` with literal gates must find exactly what an ungated pass
    /// finds: a gate may only skip work, never change results.
    #[test]
    fn gated_scan_equals_ungated_scan() {
        fn scan_ungated(text: &str) -> Vec<Match> {
            let mut out = Vec::new();
            for p in PATTERNS.iter() {
                for m in p.regex.find_iter(text) {
                    out.push(Match { pattern: p.name, category: p.category, start: m.start(), end: m.end() });
                }
            }
            out
        }
        for text in [
            "contact me at jane@example.com",
            "call 555-123-4567 tomorrow",
            "my ip is 10.0.0.12",
            "patient diagnosed with diabetes",
            "prescribed metformin 500 mg daily",
            "ssn 123-45-6789 of a patient",
            "search medical literature for diabetes guidelines",
            "how does insulin regulate glucose",
            "charge card 4111-1111-1111-1234",
            "wire transfer from account 1234567890",
            "routing number 021000021",
            "what is the capital of france",
            "explain how rust ownership works",
            "PATIENT WITH HYPERTENSION",
            "Email ME at X@Y.ORG",
            "passport no: X1234567 and license plate AB-123C",
            "my name is jane doe, dob 1990/01/02, bp 120/80 bp",
            "İstanbul'da MRN: 48291 ve hba1c sonuçları",
            "salary review for 日本 staff, cvv: 123, swift code ABCDEF12",
        ] {
            assert_eq!(scan(text), scan_ungated(text), "gate changed results for {text:?}");
        }
    }

    #[test]
    fn gates_fold_ascii_case_only() {
        // uppercase ASCII keywords pass their gate…
        assert_eq!(stage1_floor("WIRE TRANSFER incoming"), 0.9);
        // …and multi-byte chars never false-match an ASCII literal: "ü"
        // does not fold to "u", so this stays clean
        assert_eq!(stage1_floor("ünrelated text"), 0.0);
    }
}
