//! Entity detection for context sanitization (§VII.B "Detect sensitive
//! entities in chat history using NER").
//!
//! The paper assumes an NER model; we substitute a gazetteer + regex
//! detector (DESIGN.md §2) — the sanitization guarantee is *structural*
//! (typed placeholders + bidirectional map), not a function of NER recall.
//! Types are deliberately coarse (PERSON, LOCATION, ID, …) per the Attack-3
//! mitigation: "Placeholder types are coarse-grained … reducing uniqueness."

use once_cell::sync::Lazy;
use regex::Regex;

/// Coarse entity types → placeholder prefixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EntityKind {
    Person,
    Location,
    Id,
    Contact,
    MedicalCondition,
    Medication,
    Temporal,
    Financial,
    Org,
}

impl EntityKind {
    /// Placeholder prefix, e.g. PERSON in `[PERSON_7]`.
    pub fn prefix(self) -> &'static str {
        match self {
            EntityKind::Person => "PERSON",
            EntityKind::Location => "LOCATION",
            EntityKind::Id => "ID",
            EntityKind::Contact => "CONTACT",
            EntityKind::MedicalCondition => "MEDICAL_CONDITION",
            EntityKind::Medication => "MEDICATION",
            EntityKind::Temporal => "TEMPORAL_REFERENCE",
            EntityKind::Financial => "FINANCIAL",
            EntityKind::Org => "ORG",
        }
    }

    /// Sensitivity of revealing this entity kind (drives the Def. 4 rule
    /// "entities with sensitivity > P_target are replaced").
    pub fn sensitivity(self) -> f64 {
        match self {
            EntityKind::Id | EntityKind::Financial => 1.0,
            EntityKind::MedicalCondition | EntityKind::Medication => 0.9,
            EntityKind::Person | EntityKind::Contact => 0.8,
            EntityKind::Location | EntityKind::Org => 0.6,
            EntityKind::Temporal => 0.5,
        }
    }
}

/// A detected entity span.
#[derive(Clone, Debug, PartialEq)]
pub struct Entity {
    pub kind: EntityKind,
    pub start: usize,
    pub end: usize,
    pub text: String,
}

// Gazetteers (mirrors substrate::trace word banks so traces exercise them).
const FIRST_NAMES: &[&str] = &["john", "jane", "arun", "maria", "wei", "fatima", "alice", "bob", "carol", "david"];
const LAST_NAMES: &[&str] = &["doe", "smith", "patel", "garcia", "chen", "khan", "jones", "müller"];
const CITIES: &[&str] =
    &["chicago", "mumbai", "berlin", "osaka", "lagos", "austin", "london", "paris", "delhi", "tokyo"];
const CONDITIONS: &[&str] = &[
    "diabetes", "hypertension", "asthma", "migraine", "anemia", "depression", "cancer", "neuropathy", "retinopathy",
];
const MEDICATIONS: &[&str] = &["metformin", "lisinopril", "insulin", "atorvastatin", "ibuprofen", "amoxicillin"];
const ORGS: &[&str] = &["acme corp", "general hospital", "city clinic", "the firm"];

static RE_ID: Lazy<Regex> =
    Lazy::new(|| Regex::new(r"\b\d{3}-\d{2}-\d{4}\b|\b(?i:mrn)\s*[:#]?\s*\d{4,10}\b").unwrap());
static RE_CONTACT: Lazy<Regex> = Lazy::new(|| {
    Regex::new(r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b|\b\d{3}[-. ]\d{3}[-. ]\d{4}\b").unwrap()
});
static RE_FINANCIAL: Lazy<Regex> = Lazy::new(|| {
    Regex::new(r"\b\d{4}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b|(?i)\baccount\s*[:#]?\s*\d{8,12}\b").unwrap()
});
static RE_TEMPORAL: Lazy<Regex> = Lazy::new(|| {
    Regex::new(r"(?i)\b\d{1,4}[-/]\d{1,2}[-/]\d{1,4}\b|\b(?:yesterday|tomorrow|last\s+\w+day|next\s+\w+day|on\s+(?:mon|tues|wednes|thurs|fri|satur|sun)day)\b").unwrap()
})
;
static RE_AGE: Lazy<Regex> = Lazy::new(|| Regex::new(r"(?i)\b\d{1,3}[- ]?year[- ]?old\b").unwrap());

fn find_gazetteer(text_lower: &str, terms: &[&str], kind: EntityKind, out: &mut Vec<Entity>, orig: &str) {
    for term in terms {
        let mut from = 0;
        while let Some(pos) = text_lower[from..].find(term) {
            let start = from + pos;
            let end = start + term.len();
            // word-boundary check
            let before_ok = start == 0 || !text_lower.as_bytes()[start - 1].is_ascii_alphanumeric();
            let after_ok = end >= text_lower.len() || !text_lower.as_bytes()[end].is_ascii_alphanumeric();
            if before_ok && after_ok {
                out.push(Entity { kind, start, end, text: orig[start..end].to_string() });
            }
            from = end;
        }
    }
}

/// Detect all entities in `text`. Overlapping detections are resolved by
/// (earliest start, longest span, highest sensitivity).
pub fn detect(text: &str) -> Vec<Entity> {
    let lower = text.to_lowercase();
    let mut out = Vec::new();

    // Person: first name optionally followed by a known last name; merge.
    for first in FIRST_NAMES {
        let mut from = 0;
        while let Some(pos) = lower[from..].find(first) {
            let start = from + pos;
            let mut end = start + first.len();
            let before_ok = start == 0 || !lower.as_bytes()[start - 1].is_ascii_alphanumeric();
            let mut after_ok = end >= lower.len() || !lower.as_bytes()[end].is_ascii_alphanumeric();
            if before_ok && after_ok {
                // try to extend over "first last"
                if end < lower.len() {
                    let rest = &lower[end..];
                    for last in LAST_NAMES {
                        if rest.starts_with(' ') && rest[1..].starts_with(last) {
                            let e2 = end + 1 + last.len();
                            if e2 >= lower.len() || !lower.as_bytes()[e2].is_ascii_alphanumeric() {
                                end = e2;
                                break;
                            }
                        }
                    }
                }
                after_ok = end >= lower.len() || !lower.as_bytes()[end].is_ascii_alphanumeric();
                if after_ok {
                    out.push(Entity { kind: EntityKind::Person, start, end, text: text[start..end].to_string() });
                }
            }
            from = end.max(start + 1);
        }
    }
    find_gazetteer(&lower, CITIES, EntityKind::Location, &mut out, text);
    find_gazetteer(&lower, CONDITIONS, EntityKind::MedicalCondition, &mut out, text);
    find_gazetteer(&lower, MEDICATIONS, EntityKind::Medication, &mut out, text);
    find_gazetteer(&lower, ORGS, EntityKind::Org, &mut out, text);
    for (re, kind) in [
        (&*RE_ID, EntityKind::Id),
        (&*RE_CONTACT, EntityKind::Contact),
        (&*RE_FINANCIAL, EntityKind::Financial),
        (&*RE_TEMPORAL, EntityKind::Temporal),
        (&*RE_AGE, EntityKind::Id),
    ] {
        for m in re.find_iter(text) {
            out.push(Entity { kind, start: m.start(), end: m.end(), text: m.as_str().to_string() });
        }
    }

    // Resolve overlaps: sort by (start, -len, -sensitivity) and drop spans
    // overlapping an accepted one.
    out.sort_by(|a, b| {
        a.start
            .cmp(&b.start)
            .then((b.end - b.start).cmp(&(a.end - a.start)))
            .then(b.kind.sensitivity().partial_cmp(&a.kind.sensitivity()).unwrap())
    });
    let mut accepted: Vec<Entity> = Vec::new();
    for e in out {
        if accepted.iter().all(|a| e.start >= a.end || e.end <= a.start) {
            accepted.push(e);
        }
    }
    accepted
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(text: &str) -> Vec<EntityKind> {
        detect(text).into_iter().map(|e| e.kind).collect()
    }

    #[test]
    fn detects_full_names() {
        let es = detect("Patient John Doe visited yesterday");
        let person = es.iter().find(|e| e.kind == EntityKind::Person).unwrap();
        assert_eq!(person.text, "John Doe");
        assert!(es.iter().any(|e| e.kind == EntityKind::Temporal));
    }

    #[test]
    fn detects_paper_motivating_example_entities() {
        // §I.A: "45-year-old diabetic patient with elevated HbA1c"
        let es = detect("Analyze treatment options for 45-year-old diabetic patient with elevated HbA1c");
        assert!(es.iter().any(|e| e.kind == EntityKind::Id && e.text.contains("45")), "{es:?}"); // age
        // "diabetic" is not in the gazetteer, but "diabetes" variants are
        // covered by Stage-1; MedicalCondition here catches base forms.
    }

    #[test]
    fn detects_ids_contacts_financial() {
        assert!(kinds("ssn 123-45-6789").contains(&EntityKind::Id));
        assert!(kinds("mail a@b.co now").contains(&EntityKind::Contact));
        assert!(kinds("card 4111 1111 1111 1111").contains(&EntityKind::Financial));
    }

    #[test]
    fn detects_medical() {
        let ks = kinds("diagnosed with diabetes, prescribed metformin");
        assert!(ks.contains(&EntityKind::MedicalCondition));
        assert!(ks.contains(&EntityKind::Medication));
    }

    #[test]
    fn locations_and_orgs() {
        let ks = kinds("the chicago office of acme corp");
        assert!(ks.contains(&EntityKind::Location));
        assert!(ks.contains(&EntityKind::Org));
    }

    #[test]
    fn no_overlapping_spans() {
        let es = detect("patient john doe ssn 123-45-6789 in chicago on 2024-01-05");
        for (i, a) in es.iter().enumerate() {
            for b in es.iter().skip(i + 1) {
                assert!(a.end <= b.start || b.end <= a.start, "{a:?} overlaps {b:?}");
            }
        }
    }

    #[test]
    fn word_boundaries_respected() {
        // "weird" contains "wei" (first name); must not match mid-word
        assert!(kinds("that is weird indeed").is_empty());
        // "journey" must not trip "jo..." names
        assert!(!kinds("our journey begins").contains(&EntityKind::Person));
    }

    #[test]
    fn clean_text_yields_nothing() {
        assert!(detect("explain how rust ownership works").is_empty());
    }

    #[test]
    fn sensitivity_ordering() {
        assert!(EntityKind::Id.sensitivity() > EntityKind::Person.sensitivity());
        assert!(EntityKind::Person.sensitivity() > EntityKind::Temporal.sensitivity());
    }
}
