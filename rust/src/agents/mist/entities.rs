//! Entity detection for context sanitization (§VII.B "Detect sensitive
//! entities in chat history using NER").
//!
//! The paper assumes an NER model; we substitute a gazetteer + regex
//! detector (DESIGN.md §2) — the sanitization guarantee is *structural*
//! (typed placeholders + bidirectional map), not a function of NER recall.
//! Types are deliberately coarse (PERSON, LOCATION, ID, …) per the Attack-3
//! mitigation: "Placeholder types are coarse-grained … reducing uniqueness."
//!
//! # Scanner design and the Unicode-safety contract
//!
//! Gazetteer matching is a single pass of an Aho–Corasick-style automaton
//! built over every gazetteer term at once, walking the **original** string
//! and folding only ASCII letters (`A-Z` → `a-z`) for comparison. Because
//! the input is never rewritten, every reported span is a byte range of the
//! original text, always on `char` boundaries — the previous implementation
//! computed offsets on `text.to_lowercase()`, whose byte length can differ
//! from the original (`İ` → `i̇` grows, `ẞ` → `ß` shrinks), so non-ASCII
//! prompts could panic on a char boundary or emit garbage spans.
//!
//! The contract:
//! - [`detect`] never panics on any valid `&str`, including combining
//!   marks, emoji and mixed-width scripts;
//! - every [`Entity`] span satisfies `text.is_char_boundary(start)` and
//!   `text.is_char_boundary(end)`, and `&text[start..end] == entity.text`;
//! - scan-time case folding is ASCII-only; non-ASCII case is covered at
//!   build time by inserting uppercase variants of each non-ASCII pattern
//!   char (`"MÜLLER"` matches the gazetteer entry `"müller"` via the
//!   `"mÜller"` variant). Chars whose uppercase expands to multiple chars
//!   have no variant — a bounded recall trade-off, never a safety one;
//! - word boundaries are computed on `char`s: a term followed by a
//!   combining mark (U+0300..U+036F) or another alphanumeric char is
//!   mid-word and not reported.

use once_cell::sync::Lazy;
use regex::Regex;

/// Coarse entity types → placeholder prefixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EntityKind {
    Person,
    Location,
    Id,
    Contact,
    MedicalCondition,
    Medication,
    Temporal,
    Financial,
    Org,
}

impl EntityKind {
    /// Placeholder prefix, e.g. PERSON in `[PERSON_7]`.
    pub fn prefix(self) -> &'static str {
        match self {
            EntityKind::Person => "PERSON",
            EntityKind::Location => "LOCATION",
            EntityKind::Id => "ID",
            EntityKind::Contact => "CONTACT",
            EntityKind::MedicalCondition => "MEDICAL_CONDITION",
            EntityKind::Medication => "MEDICATION",
            EntityKind::Temporal => "TEMPORAL_REFERENCE",
            EntityKind::Financial => "FINANCIAL",
            EntityKind::Org => "ORG",
        }
    }

    /// Sensitivity of revealing this entity kind (drives the Def. 4 rule
    /// "entities with sensitivity > P_target are replaced").
    pub fn sensitivity(self) -> f64 {
        match self {
            EntityKind::Id | EntityKind::Financial => 1.0,
            EntityKind::MedicalCondition | EntityKind::Medication => 0.9,
            EntityKind::Person | EntityKind::Contact => 0.8,
            EntityKind::Location | EntityKind::Org => 0.6,
            EntityKind::Temporal => 0.5,
        }
    }
}

/// A detected entity span. `start`/`end` are byte offsets into the string
/// passed to [`detect`], guaranteed to lie on `char` boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct Entity {
    pub kind: EntityKind,
    pub start: usize,
    pub end: usize,
    pub text: String,
}

// Gazetteers (mirrors substrate::trace word banks so traces exercise them).
const FIRST_NAMES: &[&str] = &["john", "jane", "arun", "maria", "wei", "fatima", "alice", "bob", "carol", "david"];
const LAST_NAMES: &[&str] = &["doe", "smith", "patel", "garcia", "chen", "khan", "jones", "müller"];
const CITIES: &[&str] =
    &["chicago", "mumbai", "berlin", "osaka", "lagos", "austin", "london", "paris", "delhi", "tokyo"];
const CONDITIONS: &[&str] = &[
    "diabetes", "hypertension", "asthma", "migraine", "anemia", "depression", "cancer", "neuropathy", "retinopathy",
];
const MEDICATIONS: &[&str] = &["metformin", "lisinopril", "insulin", "atorvastatin", "ibuprofen", "amoxicillin"];
const ORGS: &[&str] = &["acme corp", "general hospital", "city clinic", "the firm"];

/// Compile one of this module's constant patterns. A malformed constant is
/// a programming error this module's unit tests catch in CI, never a
/// function of user input, so the first-use compile may panic at boot.
fn compiled(re: &str) -> Regex {
    // islandlint: allow(serving-path-panic) -- const pattern table, exercised by unit tests; compile happens once at first use, not per request
    Regex::new(re).unwrap()
}

static RE_ID: Lazy<Regex> = Lazy::new(|| compiled(r"\b\d{3}-\d{2}-\d{4}\b|\b(?i:mrn)\s*[:#]?\s*\d{4,10}\b"));
static RE_CONTACT: Lazy<Regex> =
    Lazy::new(|| compiled(r"(?i)\b[a-z0-9._%+-]+@[a-z0-9.-]+\.[a-z]{2,}\b|\b\d{3}[-. ]\d{3}[-. ]\d{4}\b"));
static RE_FINANCIAL: Lazy<Regex> =
    Lazy::new(|| compiled(r"\b\d{4}[- ]?\d{4}[- ]?\d{4}[- ]?\d{4}\b|(?i)\baccount\s*[:#]?\s*\d{8,12}\b"));
static RE_TEMPORAL: Lazy<Regex> = Lazy::new(|| {
    compiled(r"(?i)\b\d{1,4}[-/]\d{1,2}[-/]\d{1,4}\b|\b(?:yesterday|tomorrow|last\s+\w+day|next\s+\w+day|on\s+(?:mon|tues|wednes|thurs|fri|satur|sun)day)\b")
});
static RE_AGE: Lazy<Regex> = Lazy::new(|| compiled(r"(?i)\b\d{1,3}[- ]?year[- ]?old\b"));

/// What a trie term means when it matches. Last names are not entities on
/// their own — they only extend a preceding first name into a full PERSON.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TermTag {
    Kind(EntityKind),
    PersonFirst,
    PersonLast,
}

/// Aho–Corasick automaton over every gazetteer term. Matching folds ASCII
/// case per input byte; pattern bytes are stored verbatim (gazetteer terms
/// are already lowercase, including their non-ASCII bytes), so reported
/// spans are byte ranges of the unmodified input.
struct Scanner {
    /// Sorted-by-byte edge lists; node 0 is the root.
    children: Vec<Vec<(u8, u32)>>,
    fail: Vec<u32>,
    /// Terms ending at this node (own + those reachable via failure links),
    /// as (tag, byte length of the term).
    out: Vec<Vec<(TermTag, u32)>>,
}

impl Scanner {
    fn build(terms: &[(String, TermTag)]) -> Scanner {
        let mut children: Vec<Vec<(u8, u32)>> = vec![Vec::new()];
        let mut out: Vec<Vec<(TermTag, u32)>> = vec![Vec::new()];
        for (term, tag) in terms {
            let mut node = 0usize;
            for &b in term.as_bytes() {
                match children[node].iter().find(|(eb, _)| *eb == b) {
                    Some(&(_, next)) => node = next as usize,
                    None => {
                        let next = children.len() as u32;
                        children[node].push((b, next));
                        children.push(Vec::new());
                        out.push(Vec::new());
                        node = next as usize;
                    }
                }
            }
            out[node].push((*tag, term.len() as u32));
        }
        // BFS failure links; outputs of the failure target propagate so one
        // state visit reports every term ending at this position.
        let mut fail = vec![0u32; children.len()];
        let mut queue: std::collections::VecDeque<u32> = children[0].iter().map(|&(_, n)| n).collect();
        while let Some(u) = queue.pop_front() {
            let edges = children[u as usize].clone();
            for (b, v) in edges {
                // follow failure links until a node with a `b`-edge (the
                // chain visits strictly shallower nodes than v's parent, so
                // the found target is never v itself)
                let mut f = fail[u as usize];
                loop {
                    if let Some(&(_, next)) = children[f as usize].iter().find(|(eb, _)| *eb == b) {
                        f = next;
                        break;
                    }
                    if f == 0 {
                        break;
                    }
                    f = fail[f as usize];
                }
                fail[v as usize] = f;
                let inherited = out[f as usize].clone();
                out[v as usize].extend(inherited);
                queue.push_back(v);
            }
        }
        Scanner { children, fail, out }
    }

    fn step(&self, mut state: u32, byte: u8) -> u32 {
        let b = byte.to_ascii_lowercase();
        loop {
            if let Some(&(_, next)) = self.children[state as usize].iter().find(|(eb, _)| *eb == b) {
                return next;
            }
            if state == 0 {
                return 0;
            }
            state = self.fail[state as usize];
        }
    }

    /// One pass over `text`: every word-bounded gazetteer hit, as
    /// `(tag, start, end)` byte offsets into the original string.
    fn scan(&self, text: &str) -> Vec<(TermTag, usize, usize)> {
        let mut hits = Vec::new();
        let mut state = 0u32;
        for (i, &b) in text.as_bytes().iter().enumerate() {
            state = self.step(state, b);
            for &(tag, len) in &self.out[state as usize] {
                let end = i + 1;
                let start = end - len as usize;
                if word_bounded(text, start, end) {
                    hits.push((tag, start, end));
                }
            }
        }
        hits
    }
}

/// Spelling variants of a gazetteer term covering non-ASCII case: the
/// scan-time fold handles ASCII letters, so for every non-ASCII char we
/// also insert the variant with its single-char uppercase form (`ü` → also
/// `Ü`), keeping `"MÜLLER"`-style all-caps entities detectable. Variants
/// are full byte patterns of their own, so spans remain exact byte ranges
/// of the input.
fn case_variants(term: &str) -> Vec<String> {
    let mut variants: Vec<String> = vec![String::with_capacity(term.len())];
    for c in term.chars() {
        let mut alts: Vec<char> = vec![c];
        if !c.is_ascii() {
            let mut up = c.to_uppercase();
            if let (Some(u), None) = (up.next(), up.next()) {
                if u != c {
                    alts.push(u);
                }
            }
        }
        let mut next = Vec::with_capacity(variants.len() * alts.len());
        for v in &variants {
            for &a in &alts {
                let mut s = v.clone();
                s.push(a);
                next.push(s);
            }
        }
        variants = next;
    }
    variants
}

static SCANNER: Lazy<Scanner> = Lazy::new(|| {
    let mut terms: Vec<(String, TermTag)> = Vec::new();
    for (list, tag) in [
        (FIRST_NAMES, TermTag::PersonFirst),
        (LAST_NAMES, TermTag::PersonLast),
        (CITIES, TermTag::Kind(EntityKind::Location)),
        (CONDITIONS, TermTag::Kind(EntityKind::MedicalCondition)),
        (MEDICATIONS, TermTag::Kind(EntityKind::Medication)),
        (ORGS, TermTag::Kind(EntityKind::Org)),
    ] {
        for t in list {
            for v in case_variants(t) {
                terms.push((v, tag));
            }
        }
    }
    Scanner::build(&terms)
});

/// A char that continues a word: alphanumerics, plus combining diacritics
/// (a term trailed by a combining mark renders as a *different* word — it
/// must not match).
fn is_word_char(c: char) -> bool {
    c.is_alphanumeric() || ('\u{0300}'..='\u{036F}').contains(&c)
}

/// True when `[start, end)` is a valid char-boundary span of `text` whose
/// neighbours are not word chars.
fn word_bounded(text: &str, start: usize, end: usize) -> bool {
    // Trie spans are byte-aligned to valid UTF-8 pattern text, so these
    // hold structurally; the guard keeps slicing panic-free regardless.
    if !text.is_char_boundary(start) || !text.is_char_boundary(end) {
        return false;
    }
    let before_ok = !text[..start].chars().next_back().is_some_and(is_word_char);
    let after_ok = !text[end..].chars().next().is_some_and(is_word_char);
    before_ok && after_ok
}

/// One scan pass: every entity candidate found in `hay`, whose byte
/// offsets also index `original` (the caller guarantees `hay` is either
/// `original` itself or a length-preserving masked copy). Entity text is
/// sliced from `original`.
fn collect_candidates(hay: &str, original: &str) -> Vec<Entity> {
    let hits = SCANNER.scan(hay);
    let mut out = Vec::new();

    // O(1) "last name starting at byte offset" lookup for the first→full
    // name extension (a linear scan here would make adversarial inputs
    // with many name hits quadratic).
    let last_by_start: std::collections::HashMap<usize, usize> = hits
        .iter()
        .filter(|(t, _, _)| *t == TermTag::PersonLast)
        .map(|&(_, s, e)| (s, e))
        .collect();

    for &(tag, start, end) in &hits {
        match tag {
            TermTag::Kind(kind) => {
                out.push(Entity { kind, start, end, text: original[start..end].to_string() })
            }
            TermTag::PersonFirst => {
                // extend over "first last" when a word-bounded last name
                // starts one space after the first name ends
                let mut span_end = end;
                if hay.as_bytes().get(end) == Some(&b' ') {
                    if let Some(&le) = last_by_start.get(&(end + 1)) {
                        span_end = le;
                    }
                }
                out.push(Entity {
                    kind: EntityKind::Person,
                    start,
                    end: span_end,
                    text: original[start..span_end].to_string(),
                });
            }
            // lone last names are too weak a signal to be entities
            TermTag::PersonLast => {}
        }
    }

    for (re, kind) in [
        (&*RE_ID, EntityKind::Id),
        (&*RE_CONTACT, EntityKind::Contact),
        (&*RE_FINANCIAL, EntityKind::Financial),
        (&*RE_TEMPORAL, EntityKind::Temporal),
        (&*RE_AGE, EntityKind::Id),
    ] {
        for m in re.find_iter(hay) {
            out.push(Entity { kind, start: m.start(), end: m.end(), text: original[m.start()..m.end()].to_string() });
        }
    }
    out
}

fn overlaps(a: &Entity, start: usize, end: usize) -> bool {
    start < a.end && a.start < end
}

/// Detect all entities in `text`. Overlapping detections are resolved by
/// (earliest start, longest span, highest sensitivity). See the module docs
/// for the Unicode-safety contract on the returned spans.
///
/// Resolution alone is not enough: `find_iter` resumes AFTER each match, so
/// a dropped straddling match can eclipse a real entity behind it — e.g. in
/// `"ssn 123-45-6789 4111 1111 1111 1111"` the Financial class's leftmost
/// match is `"6789 4111 1111 1111"`, which loses overlap resolution to the
/// SSN and would leave the card number undetected (and hence transmitted in
/// cleartext by τ). Whenever a dropped candidate is not fully covered by an
/// accepted span, the accepted spans are masked out (length-preserving, so
/// offsets stay valid) and the classes re-scanned; the common no-straddle
/// case pays nothing beyond one boolean check.
pub fn detect(text: &str) -> Vec<Entity> {
    let mut accepted: Vec<Entity> = Vec::new();
    let mut masked: Option<String> = None;
    // each extra round accepts at least one span; 8 bounds adversarial input
    for _round in 0..8 {
        let hay: &str = masked.as_deref().unwrap_or(text);
        let mut candidates = collect_candidates(hay, text);
        if !accepted.is_empty() {
            // masked spans can still be straddled by \s-bridged matches;
            // anything touching an accepted span is not a new entity
            candidates.retain(|e| !accepted.iter().any(|a| overlaps(a, e.start, e.end)));
        }
        candidates.sort_by(|a, b| {
            a.start
                .cmp(&b.start)
                .then((b.end - b.start).cmp(&(a.end - a.start)))
                .then(b.kind.sensitivity().total_cmp(&a.kind.sensitivity()))
        });
        let mut fresh: Vec<Entity> = Vec::new();
        let mut uncovered_drop = false;
        for e in candidates {
            if fresh.iter().all(|a| !overlaps(a, e.start, e.end)) {
                fresh.push(e);
            } else if !fresh.iter().any(|a| a.start <= e.start && a.end >= e.end) {
                // the dropped span sticks out of every accepted span: its
                // find_iter pass may have skipped a real match behind it
                uncovered_drop = true;
            }
        }
        let done = !uncovered_drop;
        if uncovered_drop || masked.is_some() {
            let m = masked.get_or_insert_with(|| text.to_string());
            for e in &fresh {
                m.replace_range(e.start..e.end, &" ".repeat(e.end - e.start));
            }
        }
        let stuck = fresh.is_empty();
        accepted.extend(fresh);
        if done || stuck {
            break;
        }
    }
    accepted.sort_by_key(|e| e.start);
    accepted
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(text: &str) -> Vec<EntityKind> {
        detect(text).into_iter().map(|e| e.kind).collect()
    }

    /// Every span the detector reports must slice the original text cleanly
    /// and reproduce the entity text verbatim.
    fn assert_spans_sound(text: &str) {
        for e in detect(text) {
            assert!(text.is_char_boundary(e.start) && text.is_char_boundary(e.end), "{e:?} in {text:?}");
            assert_eq!(&text[e.start..e.end], e.text, "span/text mismatch in {text:?}");
        }
    }

    #[test]
    fn detects_full_names() {
        let es = detect("Patient John Doe visited yesterday");
        let person = es.iter().find(|e| e.kind == EntityKind::Person).unwrap();
        assert_eq!(person.text, "John Doe");
        assert!(es.iter().any(|e| e.kind == EntityKind::Temporal));
    }

    #[test]
    fn detects_paper_motivating_example_entities() {
        // §I.A: "45-year-old diabetic patient with elevated HbA1c"
        let es = detect("Analyze treatment options for 45-year-old diabetic patient with elevated HbA1c");
        assert!(es.iter().any(|e| e.kind == EntityKind::Id && e.text.contains("45")), "{es:?}"); // age
        // "diabetic" is not in the gazetteer, but "diabetes" variants are
        // covered by Stage-1; MedicalCondition here catches base forms.
    }

    #[test]
    fn detects_ids_contacts_financial() {
        assert!(kinds("ssn 123-45-6789").contains(&EntityKind::Id));
        assert!(kinds("mail a@b.co now").contains(&EntityKind::Contact));
        assert!(kinds("card 4111 1111 1111 1111").contains(&EntityKind::Financial));
    }

    #[test]
    fn detects_medical() {
        let ks = kinds("diagnosed with diabetes, prescribed metformin");
        assert!(ks.contains(&EntityKind::MedicalCondition));
        assert!(ks.contains(&EntityKind::Medication));
    }

    #[test]
    fn locations_and_orgs() {
        let ks = kinds("the chicago office of acme corp");
        assert!(ks.contains(&EntityKind::Location));
        assert!(ks.contains(&EntityKind::Org));
    }

    #[test]
    fn no_overlapping_spans() {
        let es = detect("patient john doe ssn 123-45-6789 in chicago on 2024-01-05");
        for (i, a) in es.iter().enumerate() {
            for b in es.iter().skip(i + 1) {
                assert!(a.end <= b.start || b.end <= a.start, "{a:?} overlaps {b:?}");
            }
        }
    }

    #[test]
    fn word_boundaries_respected() {
        // "weird" contains "wei" (first name); must not match mid-word
        assert!(kinds("that is weird indeed").is_empty());
        // "journey" must not trip "jo..." names
        assert!(!kinds("our journey begins").contains(&EntityKind::Person));
    }

    #[test]
    fn clean_text_yields_nothing() {
        assert!(detect("explain how rust ownership works").is_empty());
    }

    #[test]
    fn sensitivity_ordering() {
        assert!(EntityKind::Id.sensitivity() > EntityKind::Person.sensitivity());
        assert!(EntityKind::Person.sensitivity() > EntityKind::Temporal.sensitivity());
    }

    // ------------- non-ASCII regression tests (the offset bugfix) -------------

    #[test]
    fn non_ascii_last_name_matches_with_original_offsets() {
        // "müller" is in the gazetteer with its multi-byte ü; offsets must
        // index the original string
        let text = "call jane müller in berlin";
        let es = detect(text);
        let person = es.iter().find(|e| e.kind == EntityKind::Person).expect("person");
        assert_eq!(person.text, "jane müller");
        assert_eq!(&text[person.start..person.end], "jane müller");
        let city = es.iter().find(|e| e.kind == EntityKind::Location).expect("location");
        assert_eq!(city.text, "berlin");
        assert_spans_sound(text);
    }

    #[test]
    fn dotted_capital_i_before_entity_does_not_shift_offsets() {
        // "İ" (U+0130) lowercases to a LONGER byte sequence ("i" + U+0307):
        // the old to_lowercase()-offset scheme sliced the original string
        // with shifted indices here. Entities AFTER the İ must come out with
        // exact spans.
        let text = "İstanbul trip notes: jane smith met john doe in berlin";
        let es = detect(text);
        let persons: Vec<&Entity> = es.iter().filter(|e| e.kind == EntityKind::Person).collect();
        assert_eq!(persons.len(), 2, "{es:?}");
        assert_eq!(persons[0].text, "jane smith");
        assert_eq!(persons[1].text, "john doe");
        for p in &persons {
            assert_eq!(&text[p.start..p.end], p.text);
        }
        assert!(es.iter().any(|e| e.kind == EntityKind::Location && e.text == "berlin"));
        assert_spans_sound(text);
    }

    #[test]
    fn sharp_s_and_mixed_width_text_never_panic() {
        for text in [
            "weiß is not wei",                       // ß directly after a first name fragment
            "straße 12, tokyo",                      // multi-byte mid-word
            "日本語テキスト john doe 日本語",          // CJK around an entity
            "ẞ İ ß ﬀ ﬁ ligatures and john",          // chars whose case maps change length
        ] {
            assert_spans_sound(text);
        }
        let es = detect("日本語テキスト john doe 日本語");
        assert!(es.iter().any(|e| e.kind == EntityKind::Person && e.text == "john doe"));
    }

    #[test]
    fn combining_marks_block_word_boundary() {
        // "jane" + U+0301 renders as "jané…": mid-word, must not match
        let text = "jane\u{0301}ish spoke to maria";
        let es = detect(text);
        assert!(!es.iter().any(|e| e.text.starts_with("jane")), "{es:?}");
        assert!(es.iter().any(|e| e.kind == EntityKind::Person && e.text == "maria"));
        assert_spans_sound(text);
    }

    #[test]
    fn emoji_around_entities_keep_exact_spans() {
        let text = "🏝️ patient john doe 🏥 in chicago 🌆 ssn 123-45-6789";
        let es = detect(text);
        assert!(es.iter().any(|e| e.kind == EntityKind::Person && e.text == "john doe"));
        assert!(es.iter().any(|e| e.kind == EntityKind::Location && e.text == "chicago"));
        assert!(es.iter().any(|e| e.kind == EntityKind::Id && e.text == "123-45-6789"));
        assert_spans_sound(text);
    }

    #[test]
    fn uppercase_non_ascii_gazetteer_chars_still_match() {
        // "MÜLLER" must keep matching "müller" (the old full-lowercase path
        // caught it; the build-time Ü-variant preserves that recall)
        let text = "call JANE MÜLLER in berlin";
        let es = detect(text);
        let person = es.iter().find(|e| e.kind == EntityKind::Person).expect("person");
        assert_eq!(person.text, "JANE MÜLLER");
        assert_eq!(&text[person.start..person.end], "JANE MÜLLER");
        // mixed case too
        let es = detect("ask Müller's colleague jane Müller");
        assert!(es.iter().any(|e| e.kind == EntityKind::Person && e.text == "jane Müller"), "{es:?}");
    }

    #[test]
    fn case_variants_expand_only_non_ascii_chars() {
        assert_eq!(case_variants("john"), vec!["john".to_string()]);
        let mut v = case_variants("müller");
        v.sort();
        assert_eq!(v, vec!["mÜller".to_string(), "müller".to_string()]);
    }

    #[test]
    fn ascii_case_folding_still_matches_uppercase_ascii() {
        let es = detect("PATIENT JOHN DOE WITH DIABETES IN CHICAGO");
        assert!(es.iter().any(|e| e.kind == EntityKind::Person && e.text == "JOHN DOE"), "{es:?}");
        assert!(es.iter().any(|e| e.kind == EntityKind::MedicalCondition && e.text == "DIABETES"));
        assert!(es.iter().any(|e| e.kind == EntityKind::Location && e.text == "CHICAGO"));
    }

    #[test]
    fn multiword_org_terms_match_through_the_trie() {
        let es = detect("admitted to general hospital by the firm");
        let orgs: Vec<&Entity> = es.iter().filter(|e| e.kind == EntityKind::Org).collect();
        assert_eq!(orgs.len(), 2, "{es:?}");
        assert_eq!(orgs[0].text, "general hospital");
        assert_eq!(orgs[1].text, "the firm");
    }

    #[test]
    fn straddling_match_does_not_eclipse_the_entity_behind_it() {
        // RE_FINANCIAL's leftmost match here is "6789 4111 1111 1111",
        // which straddles the SSN span and loses overlap resolution; the
        // masked rescan must still surface the card number itself.
        let text = "ssn 123-45-6789 4111 1111 1111 1111";
        let es = detect(text);
        assert!(es.iter().any(|e| e.kind == EntityKind::Id && e.text == "123-45-6789"), "{es:?}");
        let fin = es.iter().find(|e| e.kind == EntityKind::Financial).expect("card must be detected");
        assert_eq!(fin.text, "4111 1111 1111 1111");
        // and the Def. 4 pipeline stays clean end to end
        let mut map = crate::agents::mist::sanitize::PlaceholderMap::new(77);
        let clean = map.sanitize(text, 0.4);
        assert!(crate::agents::mist::sanitize::PlaceholderMap::verify_clean(&clean, 0.4), "{clean}");
        assert!(!clean.contains("4111"), "{clean}");
    }

    #[test]
    fn repeated_entities_all_reported() {
        let es = detect("john called, then john called again from chicago, not chicago heights");
        let persons = es.iter().filter(|e| e.kind == EntityKind::Person).count();
        assert_eq!(persons, 2, "{es:?}");
        let cities = es.iter().filter(|e| e.kind == EntityKind::Location).count();
        assert_eq!(cities, 2, "{es:?}");
    }
}
