//! LIGHTHOUSE island registry: registration, attestation, trust composition.
//!
//! §III.B "Island Registration": each island declares privacy `P_j`, trust
//! components (base/cert/jurisdiction → Eq. 2) and a cost model. §VIII.C
//! Attack-2 mitigation: registration requires cryptographic attestation —
//! personal islands use device-bound certificates, edge islands mutual TLS.
//! We substitute a keyed-MAC token scheme (DESIGN.md §2): the mesh owner
//! holds a secret; a registration is accepted only when its token equals
//! `MAC(secret, island_name || declared_privacy || declared_tier)`, i.e.
//! only islands provisioned by the owner can join, and a malicious island
//! cannot inflate its declared trust without invalidating its token.

use std::collections::BTreeMap;

use crate::types::{Island, IslandId};

/// Attestation token (keyed MAC over the registration claims).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Token(pub u64);

/// Compute the registration MAC. FNV-based keyed hash — NOT cryptographic,
/// standing in for TPM/Secure-Enclave device certificates (DESIGN.md §2);
/// the *protocol logic* (claims bound to token, tamper → reject) is what the
/// Attack-2 experiment exercises.
pub fn attest(secret: u64, island: &Island) -> Token {
    let mut h = 0xCBF2_9CE4_8422_2325u64 ^ secret.rotate_left(17);
    let mut mix = |data: &[u8]| {
        for &b in data {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01B3);
        }
    };
    mix(island.name.as_bytes());
    mix(&island.privacy.to_bits().to_le_bytes());
    mix(&[island.tier.base_trust().to_bits() as u8]);
    mix(&island.certification.score().to_bits().to_le_bytes());
    mix(&island.jurisdiction.score().to_bits().to_le_bytes());
    Token(h)
}

/// Registration outcome.
#[derive(Debug, PartialEq)]
pub enum RegisterResult {
    Accepted(IslandId),
    /// Attestation token did not match the claims (Attack 2).
    RejectedBadAttestation,
    /// An island with this id is already registered.
    RejectedDuplicate,
}

/// The island registry (the LIGHTHOUSE allowlist).
pub struct Registry {
    secret: u64,
    islands: BTreeMap<IslandId, Island>,
}

impl Registry {
    pub fn new(secret: u64) -> Registry {
        Registry { secret, islands: BTreeMap::new() }
    }

    /// Register an island; the owner must present a valid token over the
    /// island's *declared* claims.
    pub fn register(&mut self, island: Island, token: Token) -> RegisterResult {
        if self.islands.contains_key(&island.id) {
            return RegisterResult::RejectedDuplicate;
        }
        if attest(self.secret, &island) != token {
            return RegisterResult::RejectedBadAttestation;
        }
        let id = island.id;
        self.islands.insert(id, island);
        RegisterResult::Accepted(id)
    }

    /// Provision + register in one step (owner-side convenience).
    pub fn register_owned(&mut self, island: Island) -> RegisterResult {
        let token = attest(self.secret, &island);
        self.register(island, token)
    }

    pub fn deregister(&mut self, id: IslandId) -> Option<Island> {
        self.islands.remove(&id)
    }

    pub fn get(&self, id: IslandId) -> Option<&Island> {
        self.islands.get(&id)
    }

    pub fn islands(&self) -> impl Iterator<Item = &Island> {
        self.islands.values()
    }

    pub fn len(&self) -> usize {
        self.islands.len()
    }

    pub fn is_empty(&self) -> bool {
        self.islands.is_empty()
    }

    /// Snapshot of the current island list (the "cached island list" used
    /// when LIGHTHOUSE is down, §IV.B).
    pub fn snapshot(&self) -> Vec<Island> {
        self.islands.values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    #[test]
    fn owner_registration_accepted() {
        let mut reg = Registry::new(0x5EC2E7);
        for island in preset_personal_group() {
            assert!(matches!(reg.register_owned(island), RegisterResult::Accepted(_)));
        }
        assert_eq!(reg.len(), 7);
    }

    #[test]
    fn forged_token_rejected() {
        let mut reg = Registry::new(1234);
        let island = preset_personal_group().remove(0);
        assert_eq!(reg.register(island, Token(0xDEAD_BEEF)), RegisterResult::RejectedBadAttestation);
        assert!(reg.is_empty());
    }

    #[test]
    fn attack2_trust_inflation_invalidates_token() {
        // Attacker gets a valid token for a low-trust island, then inflates
        // the declared privacy before registering: token must not verify.
        let mut reg = Registry::new(99);
        let mut island = preset_personal_group().remove(5); // cloud island
        let token = attest(99, &island);
        island.privacy = 1.0; // forged claim: "I am as private as a laptop"
        assert_eq!(reg.register(island, token), RegisterResult::RejectedBadAttestation);
    }

    #[test]
    fn wrong_secret_cannot_mint_tokens() {
        let mut reg = Registry::new(42);
        let island = preset_personal_group().remove(0);
        let forged = attest(43, &island); // attacker guesses wrong secret
        assert_eq!(reg.register(island, forged), RegisterResult::RejectedBadAttestation);
    }

    #[test]
    fn duplicate_ids_rejected() {
        let mut reg = Registry::new(7);
        let island = preset_personal_group().remove(0);
        assert!(matches!(reg.register_owned(island.clone()), RegisterResult::Accepted(_)));
        assert_eq!(reg.register_owned(island), RegisterResult::RejectedDuplicate);
    }

    #[test]
    fn deregister_and_snapshot() {
        let mut reg = Registry::new(7);
        for island in preset_personal_group() {
            reg.register_owned(island);
        }
        let snap = reg.snapshot();
        assert_eq!(snap.len(), reg.len());
        let id = snap[0].id;
        assert!(reg.deregister(id).is_some());
        assert!(reg.get(id).is_none());
        assert_eq!(reg.len(), snap.len() - 1);
    }
}
