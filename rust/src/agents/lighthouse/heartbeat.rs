//! LIGHTHOUSE liveness tracking: periodic heartbeats + miss-count policy.
//!
//! §X: "LIGHTHOUSE maintains mesh connectivity via periodic heartbeats and
//! enables dynamic island discovery. Personal devices announce availability
//! when coming online (laptop waking from sleep, car starting)." Runs in
//! virtual time like everything else in the simulator.

use std::collections::BTreeMap;

use crate::types::IslandId;

/// Liveness record for one island.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Liveness {
    pub last_heartbeat_ms: f64,
    pub missed: u32,
    pub online: bool,
}

/// Heartbeat tracker.
#[derive(Clone, Debug)]
pub struct HeartbeatTracker {
    period_ms: f64,
    miss_limit: u32,
    records: BTreeMap<IslandId, Liveness>,
}

impl HeartbeatTracker {
    pub fn new(period_ms: f64, miss_limit: u32) -> HeartbeatTracker {
        HeartbeatTracker { period_ms, miss_limit, records: BTreeMap::new() }
    }

    /// An island announces itself (discovery / wake-from-sleep). An explicit
    /// announcement always brings the island online, but never moves its
    /// heartbeat timestamp backwards.
    pub fn announce(&mut self, id: IslandId, now_ms: f64) {
        let rec = self.records.entry(id).or_insert(Liveness { last_heartbeat_ms: now_ms, missed: 0, online: true });
        rec.last_heartbeat_ms = rec.last_heartbeat_ms.max(now_ms);
        rec.missed = 0;
        rec.online = true;
    }

    /// Record a heartbeat from an island. Heartbeats can arrive out of
    /// order (network reordering, clock skew between islands): a beat older
    /// than the freshest one we have seen is stale evidence and is dropped —
    /// it must neither move `last_heartbeat_ms` backwards nor resurrect an
    /// island that timed out after the stale beat was sent.
    pub fn beat(&mut self, id: IslandId, now_ms: f64) {
        let rec = self.records.entry(id).or_insert(Liveness { last_heartbeat_ms: now_ms, missed: 0, online: true });
        if now_ms < rec.last_heartbeat_ms {
            return;
        }
        rec.last_heartbeat_ms = now_ms;
        rec.missed = 0;
        rec.online = true;
    }

    /// Advance time: count missed periods, mark islands offline past the
    /// miss limit. `now_ms` is not required to be monotonic (callers race on
    /// a shared clock): negative elapsed time is clamped to zero rather than
    /// flowing through the f64 → u32 cast, and a backwards tick never
    /// resurrects an offline island (only a fresh beat/announce does).
    pub fn tick(&mut self, now_ms: f64) {
        for rec in self.records.values_mut() {
            let elapsed = (now_ms - rec.last_heartbeat_ms).max(0.0);
            let missed_f = (elapsed / self.period_ms).floor();
            rec.missed = if missed_f >= u32::MAX as f64 { u32::MAX } else { missed_f as u32 };
            if rec.missed >= self.miss_limit {
                rec.online = false;
            }
        }
    }

    /// Force an island offline immediately (failed execution observed by
    /// the orchestrator, or an announced clean shutdown). The island comes
    /// back only through a fresh `beat`/`announce`.
    pub fn force_offline(&mut self, id: IslandId) {
        if let Some(rec) = self.records.get_mut(&id) {
            rec.online = false;
        }
    }

    /// Drop an island's liveness record entirely (deregistration).
    pub fn forget(&mut self, id: IslandId) {
        self.records.remove(&id);
    }

    pub fn is_online(&self, id: IslandId) -> bool {
        self.records.get(&id).map(|r| r.online).unwrap_or(false)
    }

    pub fn online_ids(&self) -> Vec<IslandId> {
        self.records.iter().filter(|(_, r)| r.online).map(|(id, _)| *id).collect()
    }

    pub fn liveness(&self, id: IslandId) -> Option<Liveness> {
        self.records.get(&id).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: IslandId = IslandId(1);
    const B: IslandId = IslandId(2);

    #[test]
    fn announced_islands_are_online() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        assert!(hb.is_online(A));
        assert!(!hb.is_online(B));
    }

    #[test]
    fn missed_beats_take_island_offline() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.tick(1400.0); // 2 missed periods: still online
        assert!(hb.is_online(A));
        assert_eq!(hb.liveness(A).unwrap().missed, 2);
        hb.tick(1600.0); // 3 missed: offline
        assert!(!hb.is_online(A));
    }

    #[test]
    fn heartbeat_recovers_island() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.tick(2000.0);
        assert!(!hb.is_online(A));
        hb.beat(A, 2100.0); // island wakes up
        hb.tick(2200.0);
        assert!(hb.is_online(A));
        assert_eq!(hb.liveness(A).unwrap().missed, 0);
    }

    #[test]
    fn online_ids_filters() {
        let mut hb = HeartbeatTracker::new(500.0, 2);
        hb.announce(A, 0.0);
        hb.announce(B, 0.0);
        hb.beat(B, 900.0);
        hb.tick(1100.0); // A missed 2 → offline; B missed 0
        assert_eq!(hb.online_ids(), vec![B]);
    }

    #[test]
    fn stale_beat_never_moves_heartbeat_backwards() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.beat(A, 1000.0);
        // a reordered packet from t=400 arrives late: must be dropped
        hb.beat(A, 400.0);
        assert_eq!(hb.liveness(A).unwrap().last_heartbeat_ms, 1000.0);
        hb.tick(2600.0); // 3 periods past t=1000 → offline
        assert!(!hb.is_online(A));
    }

    #[test]
    fn stale_beat_cannot_resurrect_timed_out_island() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.beat(A, 5000.0);
        hb.tick(99_000.0);
        assert!(!hb.is_online(A));
        hb.beat(A, 4000.0); // pre-timeout packet finally delivered
        assert!(!hb.is_online(A), "stale beat must not bring the island back");
        hb.beat(A, 99_500.0); // a genuinely fresh beat does
        assert!(hb.is_online(A));
    }

    #[test]
    fn backwards_tick_clamps_negative_elapsed() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 10_000.0);
        // clock observed out of order: tick with now < last_heartbeat
        hb.tick(3_000.0);
        let rec = hb.liveness(A).unwrap();
        assert_eq!(rec.missed, 0, "negative elapsed must clamp to 0 missed");
        assert!(rec.online);
    }

    #[test]
    fn backwards_tick_never_resurrects() {
        let mut hb = HeartbeatTracker::new(500.0, 2);
        hb.announce(A, 0.0);
        hb.tick(2_000.0);
        assert!(!hb.is_online(A));
        // an earlier tick arrives out of order: missed shrinks, but the
        // island stays offline until a fresh beat
        hb.tick(100.0);
        assert!(!hb.is_online(A));
    }

    #[test]
    fn announce_is_explicit_revival_but_keeps_freshest_timestamp() {
        let mut hb = HeartbeatTracker::new(500.0, 2);
        hb.announce(A, 0.0);
        hb.beat(A, 3000.0);
        hb.tick(99_000.0);
        assert!(!hb.is_online(A));
        // a re-announcement (wake from sleep) with an older local clock:
        // online again, but the freshest heartbeat timestamp is kept
        hb.announce(A, 2000.0);
        assert!(hb.is_online(A));
        assert_eq!(hb.liveness(A).unwrap().last_heartbeat_ms, 3000.0);
    }

    #[test]
    fn force_offline_until_fresh_beat() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.force_offline(A);
        assert!(!hb.is_online(A));
        hb.tick(10.0); // ticking alone never revives
        assert!(!hb.is_online(A));
        hb.beat(A, 20.0);
        assert!(hb.is_online(A));
        hb.forget(A);
        assert!(hb.liveness(A).is_none());
    }

    #[test]
    fn huge_elapsed_saturates_missed_count() {
        let mut hb = HeartbeatTracker::new(0.001, 3);
        hb.announce(A, 0.0);
        hb.tick(1e18); // would overflow u32 without saturation
        assert_eq!(hb.liveness(A).unwrap().missed, u32::MAX);
        assert!(!hb.is_online(A));
    }

    #[test]
    fn steady_beats_stay_online() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        for i in 1..20 {
            hb.beat(A, i as f64 * 400.0);
            hb.tick(i as f64 * 400.0 + 10.0);
            assert!(hb.is_online(A), "iteration {i}");
        }
    }
}
