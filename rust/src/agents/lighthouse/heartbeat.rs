//! LIGHTHOUSE liveness tracking: periodic heartbeats + miss-count policy.
//!
//! §X: "LIGHTHOUSE maintains mesh connectivity via periodic heartbeats and
//! enables dynamic island discovery. Personal devices announce availability
//! when coming online (laptop waking from sleep, car starting)." Runs in
//! virtual time like everything else in the simulator.

use std::collections::BTreeMap;

use crate::types::IslandId;

/// Liveness record for one island.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Liveness {
    pub last_heartbeat_ms: f64,
    pub missed: u32,
    pub online: bool,
}

/// Heartbeat tracker.
#[derive(Clone, Debug)]
pub struct HeartbeatTracker {
    period_ms: f64,
    miss_limit: u32,
    records: BTreeMap<IslandId, Liveness>,
}

impl HeartbeatTracker {
    pub fn new(period_ms: f64, miss_limit: u32) -> HeartbeatTracker {
        HeartbeatTracker { period_ms, miss_limit, records: BTreeMap::new() }
    }

    /// An island announces itself (discovery / wake-from-sleep).
    pub fn announce(&mut self, id: IslandId, now_ms: f64) {
        self.records.insert(id, Liveness { last_heartbeat_ms: now_ms, missed: 0, online: true });
    }

    /// Record a heartbeat from an island.
    pub fn beat(&mut self, id: IslandId, now_ms: f64) {
        let rec = self.records.entry(id).or_insert(Liveness { last_heartbeat_ms: now_ms, missed: 0, online: true });
        rec.last_heartbeat_ms = now_ms;
        rec.missed = 0;
        rec.online = true;
    }

    /// Advance time: count missed periods, mark islands offline past the
    /// miss limit.
    pub fn tick(&mut self, now_ms: f64) {
        for rec in self.records.values_mut() {
            let missed = ((now_ms - rec.last_heartbeat_ms) / self.period_ms).floor() as u32;
            rec.missed = missed;
            if missed >= self.miss_limit {
                rec.online = false;
            }
        }
    }

    pub fn is_online(&self, id: IslandId) -> bool {
        self.records.get(&id).map(|r| r.online).unwrap_or(false)
    }

    pub fn online_ids(&self) -> Vec<IslandId> {
        self.records.iter().filter(|(_, r)| r.online).map(|(id, _)| *id).collect()
    }

    pub fn liveness(&self, id: IslandId) -> Option<Liveness> {
        self.records.get(&id).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: IslandId = IslandId(1);
    const B: IslandId = IslandId(2);

    #[test]
    fn announced_islands_are_online() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        assert!(hb.is_online(A));
        assert!(!hb.is_online(B));
    }

    #[test]
    fn missed_beats_take_island_offline() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.tick(1400.0); // 2 missed periods: still online
        assert!(hb.is_online(A));
        assert_eq!(hb.liveness(A).unwrap().missed, 2);
        hb.tick(1600.0); // 3 missed: offline
        assert!(!hb.is_online(A));
    }

    #[test]
    fn heartbeat_recovers_island() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        hb.tick(2000.0);
        assert!(!hb.is_online(A));
        hb.beat(A, 2100.0); // island wakes up
        hb.tick(2200.0);
        assert!(hb.is_online(A));
        assert_eq!(hb.liveness(A).unwrap().missed, 0);
    }

    #[test]
    fn online_ids_filters() {
        let mut hb = HeartbeatTracker::new(500.0, 2);
        hb.announce(A, 0.0);
        hb.announce(B, 0.0);
        hb.beat(B, 900.0);
        hb.tick(1100.0); // A missed 2 → offline; B missed 0
        assert_eq!(hb.online_ids(), vec![B]);
    }

    #[test]
    fn steady_beats_stay_online() {
        let mut hb = HeartbeatTracker::new(500.0, 3);
        hb.announce(A, 0.0);
        for i in 1..20 {
            hb.beat(A, i as f64 * 400.0);
            hb.tick(i as f64 * 400.0 + 10.0);
            assert!(hb.is_online(A), "iteration {i}");
        }
    }
}
