//! LIGHTHOUSE — Link and Health Tracking for Heterogeneous Operations Using
//! Synchronized Endpoints (§IV, §X).
//!
//! Maintains the mesh: island [`registry`] (registration + attestation +
//! Eq. 2 trust), [`heartbeat`] liveness, and dynamic discovery. Fault
//! tolerance (§IV.B): when LIGHTHOUSE crashes, WAVES keeps routing against
//! the last cached island list ("correct but slower" — E6 ablation measures
//! the re-discovery cost).

pub mod heartbeat;
pub mod registry;

use crate::types::{Island, IslandId};
use heartbeat::HeartbeatTracker;
use registry::{RegisterResult, Registry, Token};

/// The LIGHTHOUSE agent: registry + liveness + cached-list fallback.
pub struct Lighthouse {
    registry: Registry,
    heartbeats: HeartbeatTracker,
    alive: bool,
    /// Last island list served before a crash (the §IV.B fallback).
    cache: Vec<Island>,
    /// Count of registry rebuilds while down (E6 "re-discovers islands per
    /// request" cost proxy).
    pub cache_serves: u64,
}

impl Lighthouse {
    pub fn new(secret: u64, heartbeat_period_ms: f64, miss_limit: u32) -> Lighthouse {
        Lighthouse {
            registry: Registry::new(secret),
            heartbeats: HeartbeatTracker::new(heartbeat_period_ms, miss_limit),
            alive: true,
            cache: Vec::new(),
            cache_serves: 0,
        }
    }

    /// Register an island with an attestation token; announces it online.
    pub fn register(&mut self, island: Island, token: Token, now_ms: f64) -> RegisterResult {
        let id = island.id;
        let result = self.registry.register(island, token);
        if matches!(result, RegisterResult::Accepted(_)) {
            self.heartbeats.announce(id, now_ms);
        }
        result
    }

    /// Owner-side registration (token minted with the mesh secret).
    pub fn register_owned(&mut self, island: Island, now_ms: f64) -> RegisterResult {
        let id = island.id;
        let result = self.registry.register_owned(island);
        if matches!(result, RegisterResult::Accepted(_)) {
            self.heartbeats.announce(id, now_ms);
        }
        result
    }

    pub fn beat(&mut self, id: IslandId, now_ms: f64) {
        self.heartbeats.beat(id, now_ms);
    }

    pub fn tick(&mut self, now_ms: f64) {
        self.heartbeats.tick(now_ms);
    }

    /// Algorithm 1 line 4: the island list WAVES iterates. Only online
    /// islands are returned; when LIGHTHOUSE is down the cached snapshot is
    /// served instead (§IV.B).
    pub fn islands(&mut self) -> Vec<Island> {
        if !self.alive {
            self.cache_serves += 1;
            return self.cache.clone();
        }
        let list: Vec<Island> =
            self.registry.islands().filter(|i| self.heartbeats.is_online(i.id)).cloned().collect();
        self.cache = list.clone();
        list
    }

    pub fn get(&self, id: IslandId) -> Option<&Island> {
        self.registry.get(id)
    }

    pub fn is_online(&self, id: IslandId) -> bool {
        self.heartbeats.is_online(id)
    }

    /// Simulate a LIGHTHOUSE crash / recovery (E6 ablation).
    pub fn kill(&mut self) {
        self.alive = false;
    }

    pub fn revive(&mut self) {
        self.alive = true;
    }

    pub fn is_alive(&self) -> bool {
        self.alive
    }

    pub fn mint_token(&self, island: &Island, secret: u64) -> Token {
        registry::attest(secret, island)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn mesh() -> Lighthouse {
        let mut lh = Lighthouse::new(42, 500.0, 3);
        for island in preset_personal_group() {
            assert!(matches!(lh.register_owned(island, 0.0), RegisterResult::Accepted(_)));
        }
        lh
    }

    #[test]
    fn islands_returns_online_only() {
        let mut lh = mesh();
        assert_eq!(lh.islands().len(), 7);
        // laptop (id 0) goes silent
        for id in 1..7 {
            lh.beat(IslandId(id), 2000.0);
        }
        lh.tick(2000.0);
        let list = lh.islands();
        assert_eq!(list.len(), 6);
        assert!(!list.iter().any(|i| i.id == IslandId(0)));
    }

    #[test]
    fn crash_serves_cached_list() {
        let mut lh = mesh();
        let before = lh.islands();
        lh.kill();
        // registry churn while down is invisible
        lh.beat(IslandId(0), 9999.0);
        let during = lh.islands();
        assert_eq!(before.len(), during.len());
        assert_eq!(lh.cache_serves, 1);
        lh.revive();
        assert!(lh.is_alive());
    }

    #[test]
    fn rejected_islands_are_not_announced() {
        let mut lh = Lighthouse::new(1, 500.0, 3);
        let island = preset_personal_group().remove(0);
        let id = island.id;
        assert_eq!(lh.register(island, Token(123), 0.0), RegisterResult::RejectedBadAttestation);
        assert!(!lh.is_online(id));
        assert!(lh.islands().is_empty());
    }

    #[test]
    fn dynamic_discovery_announces_new_island() {
        let mut lh = mesh();
        lh.tick(100.0);
        let mut extra = preset_personal_group().remove(1);
        extra.id = IslandId(77);
        extra.name = "car-infotainment".to_string();
        assert!(matches!(lh.register_owned(extra, 100.0), RegisterResult::Accepted(_)));
        assert!(lh.islands().iter().any(|i| i.id == IslandId(77)));
    }
}
