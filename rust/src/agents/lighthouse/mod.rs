//! LIGHTHOUSE — Link and Health Tracking for Heterogeneous Operations Using
//! Synchronized Endpoints (§IV, §X).
//!
//! Maintains the mesh: island [`registry`] (registration + attestation +
//! Eq. 2 trust), [`heartbeat`] liveness, and dynamic discovery. Fault
//! tolerance (§IV.B): when LIGHTHOUSE crashes, WAVES keeps routing against
//! the last cached island list ("correct but slower" — E6 ablation measures
//! the re-discovery cost).
//!
//! Concurrency: LIGHTHOUSE is embedded in the orchestrator and consulted on
//! every `submit` from every serving thread, so the whole API takes `&self`
//! (matching the `Arc<Orchestrator>` design). The hot-path read —
//! [`Lighthouse::is_online`] — is a read-locked map lookup plus two atomic
//! loads; the heartbeat tracker sits behind its own mutex touched only on
//! beats/ticks, and the registry behind an `RwLock` touched only on
//! (de)registration.
//!
//! Two signals per island: *online* (heartbeat liveness — a dead island is
//! no routing candidate at all) and *degraded*, fed by TIDE's monitor
//! ([`crate::agents::tide::monitor::DegradeDetector`]): the island is
//! reachable but has served zero capacity for a full detection window.
//! WAVES deprioritizes degraded islands (last pick for the failsafe) but
//! never treats them as dead — saturation must queue, not reject.

pub mod heartbeat;
pub mod registry;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::types::{Island, IslandId};
use heartbeat::{HeartbeatTracker, Liveness};
use registry::{RegisterResult, Registry, Token};

use crate::util::sync::{LockExt, RwLockExt};

/// Lock-free health flags for one island (hot-path view).
#[derive(Debug, Default)]
struct IslandHealth {
    /// Heartbeat-derived liveness (mirrors the tracker's `online` bit).
    online: AtomicBool,
    /// TIDE-derived capacity-degradation signal.
    degraded: AtomicBool,
}

/// The LIGHTHOUSE agent: registry + liveness + cached-list fallback.
pub struct Lighthouse {
    registry: RwLock<Registry>,
    heartbeats: Mutex<HeartbeatTracker>,
    /// Atomic per-island flags mirrored from the tracker + degrade signals,
    /// so `is_online` on the routing hot path never touches a mutex.
    health: RwLock<BTreeMap<IslandId, Arc<IslandHealth>>>,
    alive: AtomicBool,
    /// Last island list served before a crash (the §IV.B fallback).
    cache: Mutex<Vec<Island>>,
    /// Count of cached-list serves while down (E6 "re-discovers islands per
    /// request" cost proxy).
    cache_serves: AtomicU64,
}

impl Lighthouse {
    pub fn new(secret: u64, heartbeat_period_ms: f64, miss_limit: u32) -> Lighthouse {
        Lighthouse {
            registry: RwLock::new(Registry::new(secret)),
            heartbeats: Mutex::new(HeartbeatTracker::new(heartbeat_period_ms, miss_limit)),
            health: RwLock::new(BTreeMap::new()),
            alive: AtomicBool::new(true),
            cache: Mutex::new(Vec::new()),
            cache_serves: AtomicU64::new(0),
        }
    }

    fn health_cell(&self, id: IslandId) -> Arc<IslandHealth> {
        if let Some(h) = self.health.read_clean().get(&id) {
            return Arc::clone(h);
        }
        let mut w = self.health.write_clean();
        Arc::clone(w.entry(id).or_default())
    }

    fn announce_online(&self, id: IslandId, now_ms: f64) {
        self.heartbeats.lock_clean().announce(id, now_ms);
        let cell = self.health_cell(id);
        cell.online.store(true, Ordering::SeqCst);
        cell.degraded.store(false, Ordering::SeqCst);
    }

    /// Register an island with an attestation token; announces it online.
    pub fn register(&self, island: Island, token: Token, now_ms: f64) -> RegisterResult {
        let id = island.id;
        let result = self.registry.write_clean().register(island, token);
        if matches!(result, RegisterResult::Accepted(_)) {
            self.announce_online(id, now_ms);
        }
        result
    }

    /// Owner-side registration (token minted with the mesh secret).
    pub fn register_owned(&self, island: Island, now_ms: f64) -> RegisterResult {
        let id = island.id;
        let result = self.registry.write_clean().register_owned(island);
        if matches!(result, RegisterResult::Accepted(_)) {
            self.announce_online(id, now_ms);
        }
        result
    }

    /// Remove an island from the mesh (clean leave). Its liveness record and
    /// health flags are dropped with it.
    pub fn deregister(&self, id: IslandId) -> Option<Island> {
        let island = self.registry.write_clean().deregister(id);
        if island.is_some() {
            self.heartbeats.lock_clean().forget(id);
            self.health.write_clean().remove(&id);
        }
        island
    }

    pub fn beat(&self, id: IslandId, now_ms: f64) {
        if !self.is_alive() {
            return;
        }
        let mut hb = self.heartbeats.lock_clean();
        hb.beat(id, now_ms);
        let online = hb.is_online(id);
        drop(hb);
        self.health_cell(id).online.store(online, Ordering::SeqCst);
    }

    /// Record heartbeats for a batch of islands under one tracker lock
    /// (the orchestrator relays sim-fleet liveness at heartbeat cadence).
    pub fn beat_many<I: IntoIterator<Item = IslandId>>(&self, ids: I, now_ms: f64) {
        if !self.is_alive() {
            return;
        }
        let mut hb = self.heartbeats.lock_clean();
        for id in ids {
            hb.beat(id, now_ms);
        }
        drop(hb);
        self.sync_flags();
    }

    /// Advance liveness time: islands past the miss limit go offline.
    pub fn tick(&self, now_ms: f64) {
        if !self.is_alive() {
            return;
        }
        self.heartbeats.lock_clean().tick(now_ms);
        self.sync_flags();
    }

    /// Mirror the tracker's online bits into the atomic hot-path flags.
    fn sync_flags(&self) {
        let hb = self.heartbeats.lock_clean();
        let health = self.health.read_clean();
        for (id, cell) in health.iter() {
            cell.online.store(hb.is_online(*id), Ordering::SeqCst);
        }
    }

    /// Force an island offline immediately — the orchestrator observed a
    /// failed execution (island died between routing and execute). The
    /// island returns only through a fresh beat / announce / revive.
    pub fn mark_offline(&self, id: IslandId) {
        self.heartbeats.lock_clean().force_offline(id);
        self.health_cell(id).online.store(false, Ordering::SeqCst);
    }

    /// Set/clear the TIDE-fed capacity-degradation signal for an island.
    pub fn set_degraded(&self, id: IslandId, degraded: bool) {
        self.health_cell(id).degraded.store(degraded, Ordering::SeqCst);
    }

    pub fn is_degraded(&self, id: IslandId) -> bool {
        self.health.read_clean().get(&id).map(|h| h.degraded.load(Ordering::SeqCst)).unwrap_or(false)
    }

    /// Algorithm 1 line 4: the island list WAVES iterates. Only
    /// heartbeat-online islands are returned; when LIGHTHOUSE is down the
    /// cached snapshot is served instead (§IV.B).
    pub fn islands(&self) -> Vec<Island> {
        if !self.is_alive() {
            self.cache_serves.fetch_add(1, Ordering::SeqCst);
            return self.cache.lock_clean().clone();
        }
        let list: Vec<Island> =
            self.registry.read_clean().islands().filter(|i| self.is_online(i.id)).cloned().collect();
        *self.cache.lock_clean() = list.clone();
        list
    }

    pub fn get(&self, id: IslandId) -> Option<Island> {
        self.registry.read_clean().get(id).cloned()
    }

    /// Hot-path heartbeat-liveness check. Capacity degradation is a
    /// separate signal ([`Lighthouse::is_degraded`]): degraded islands are
    /// deprioritized by WAVES, offline ones are excluded outright.
    pub fn is_online(&self, id: IslandId) -> bool {
        self.health.read_clean().get(&id).map(|h| h.online.load(Ordering::SeqCst)).unwrap_or(false)
    }

    pub fn liveness(&self, id: IslandId) -> Option<Liveness> {
        self.heartbeats.lock_clean().liveness(id)
    }

    /// Simulate a LIGHTHOUSE crash / recovery (E6 ablation).
    pub fn kill(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }

    pub fn revive(&self) {
        self.alive.store(true, Ordering::SeqCst);
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    pub fn cache_serves(&self) -> u64 {
        self.cache_serves.load(Ordering::SeqCst)
    }

    pub fn mint_token(&self, island: &Island, secret: u64) -> Token {
        registry::attest(secret, island)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn mesh() -> Lighthouse {
        let lh = Lighthouse::new(42, 500.0, 3);
        for island in preset_personal_group() {
            assert!(matches!(lh.register_owned(island, 0.0), RegisterResult::Accepted(_)));
        }
        lh
    }

    #[test]
    fn islands_returns_online_only() {
        let lh = mesh();
        assert_eq!(lh.islands().len(), 7);
        // laptop (id 0) goes silent
        for id in 1..7 {
            lh.beat(IslandId(id), 2000.0);
        }
        lh.tick(2000.0);
        let list = lh.islands();
        assert_eq!(list.len(), 6);
        assert!(!list.iter().any(|i| i.id == IslandId(0)));
    }

    #[test]
    fn crash_serves_cached_list() {
        let lh = mesh();
        let before = lh.islands();
        lh.kill();
        // registry churn while down is invisible
        lh.beat(IslandId(0), 9999.0);
        let during = lh.islands();
        assert_eq!(before.len(), during.len());
        assert_eq!(lh.cache_serves(), 1);
        lh.revive();
        assert!(lh.is_alive());
    }

    #[test]
    fn rejected_islands_are_not_announced() {
        let lh = Lighthouse::new(1, 500.0, 3);
        let island = preset_personal_group().remove(0);
        let id = island.id;
        assert_eq!(lh.register(island, Token(123), 0.0), RegisterResult::RejectedBadAttestation);
        assert!(!lh.is_online(id));
        assert!(lh.islands().is_empty());
    }

    #[test]
    fn dynamic_discovery_announces_new_island() {
        let lh = mesh();
        lh.tick(100.0);
        let mut extra = preset_personal_group().remove(1);
        extra.id = IslandId(77);
        extra.name = "car-infotainment".to_string();
        assert!(matches!(lh.register_owned(extra, 100.0), RegisterResult::Accepted(_)));
        assert!(lh.islands().iter().any(|i| i.id == IslandId(77)));
    }

    #[test]
    fn deregistered_island_leaves_the_mesh() {
        let lh = mesh();
        assert!(lh.deregister(IslandId(0)).is_some());
        assert!(!lh.is_online(IslandId(0)));
        assert!(!lh.islands().iter().any(|i| i.id == IslandId(0)));
        assert!(lh.liveness(IslandId(0)).is_none());
        // rejoin: registration works again and announces online
        let island = preset_personal_group().remove(0);
        assert!(matches!(lh.register_owned(island, 50.0), RegisterResult::Accepted(_)));
        assert!(lh.is_online(IslandId(0)));
    }

    #[test]
    fn mark_offline_is_immediate_and_sticky() {
        let lh = mesh();
        lh.mark_offline(IslandId(2));
        assert!(!lh.is_online(IslandId(2)));
        lh.tick(1.0); // ticking never resurrects
        assert!(!lh.is_online(IslandId(2)));
        lh.beat(IslandId(2), 10.0); // a fresh heartbeat does
        assert!(lh.is_online(IslandId(2)));
    }

    #[test]
    fn degraded_is_a_separate_signal_from_liveness() {
        let lh = mesh();
        assert!(lh.is_online(IslandId(1)));
        lh.set_degraded(IslandId(1), true);
        // degraded != dead: the island stays heartbeat-online (WAVES
        // deprioritizes it but may still queue on it under saturation)
        assert!(lh.is_online(IslandId(1)));
        assert!(lh.is_degraded(IslandId(1)));
        lh.set_degraded(IslandId(1), false);
        assert!(!lh.is_degraded(IslandId(1)));
        // while heartbeat loss takes it out of the mesh entirely
        lh.mark_offline(IslandId(1));
        assert!(!lh.is_online(IslandId(1)));
    }

    #[test]
    fn concurrent_beats_and_liveness_reads() {
        use std::sync::Arc;
        let lh = Arc::new(mesh());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let lh = Arc::clone(&lh);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let id = IslandId((t % 7) as u32);
                        lh.beat(id, i as f64 * 10.0);
                        let _ = lh.is_online(id);
                        if i % 100 == 0 {
                            lh.tick(i as f64 * 10.0);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // every island beaten recently is online
        for id in 0..7u32 {
            assert!(lh.is_online(IslandId(id)), "island {id}");
        }
    }
}
