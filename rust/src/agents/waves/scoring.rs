//! Eq. 1 composite scoring: `S(r, i_j) = w1·C_j + w2·L_j + w3·(1 − P_j)`.
//!
//! Cost and latency are normalized to [0,1] before weighting so the
//! user-preference weights are dimensionless (the paper writes Eq. 1 over
//! raw quantities; without normalization w2 would be dominated by latency's
//! magnitude — we document this as an implementation refinement).
//!
//! Extension scorers registered via [`super::router::Waves::add_scorer`]
//! contribute additional weighted terms (§IV "Extensibility").

use crate::config::Weights;
use crate::types::Island;

/// Latency normalization ceiling (ms): the paper's worst expected island
/// latency (§XI.B cloud upper bound).
pub const LATENCY_CEIL_MS: f64 = 2000.0;
/// Cost normalization ceiling ($/request): the priciest §X cloud API call.
pub const COST_CEIL: f64 = 0.05;

/// Normalized per-dimension components of Eq. 1 (useful for Pareto and for
/// experiment reporting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScoreParts {
    pub cost: f64,
    pub latency: f64,
    pub privacy_penalty: f64,
}

impl ScoreParts {
    pub fn compute(island: &Island, tokens: usize) -> ScoreParts {
        ScoreParts {
            cost: (island.request_cost(tokens) / COST_CEIL).clamp(0.0, 1.0),
            latency: (island.latency_ms / LATENCY_CEIL_MS).clamp(0.0, 1.0),
            privacy_penalty: (1.0 - island.privacy).clamp(0.0, 1.0),
        }
    }

    /// Eq. 1 weighted sum.
    pub fn weighted(&self, w: &Weights) -> f64 {
        w.cost * self.cost + w.latency * self.latency + w.privacy * self.privacy_penalty
    }
}

/// Convenience: Eq. 1 score for an island.
pub fn eq1_score(island: &Island, tokens: usize, w: &Weights) -> f64 {
    ScoreParts::compute(island, tokens).weighted(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    #[test]
    fn free_local_island_scores_near_zero() {
        let islands = preset_personal_group();
        let w = Weights::default();
        let laptop = eq1_score(&islands[0], 64, &w);
        assert!(laptop < 0.01, "laptop={laptop}");
    }

    #[test]
    fn cloud_scores_worse_than_personal_on_balanced_weights() {
        let islands = preset_personal_group();
        let w = Weights::default();
        let laptop = eq1_score(&islands[0], 64, &w);
        let cloud = eq1_score(&islands[5], 64, &w);
        assert!(cloud > laptop + 0.1, "cloud={cloud} laptop={laptop}");
    }

    #[test]
    fn latency_only_weights_flip_preference_to_fastest() {
        let islands = preset_personal_group();
        let w = Weights { cost: 0.0, latency: 1.0, privacy: 0.0 };
        // mobile (20ms LAN) must beat cloud (180ms WAN)
        assert!(eq1_score(&islands[1], 64, &w) < eq1_score(&islands[5], 64, &w));
    }

    #[test]
    fn privacy_weight_penalizes_low_trust() {
        let islands = preset_personal_group();
        let w = Weights { cost: 0.0, latency: 0.0, privacy: 1.0 };
        assert_eq!(eq1_score(&islands[0], 64, &w), 0.0); // P=1.0
        assert!((eq1_score(&islands[5], 64, &w) - 0.6).abs() < 1e-9); // P=0.4
    }

    #[test]
    fn score_bounded_in_unit_interval_for_normalized_weights() {
        let islands = preset_personal_group();
        let w = Weights { cost: 0.33, latency: 0.33, privacy: 0.34 };
        for i in &islands {
            let s = eq1_score(i, 100_000, &w); // huge token count saturates cost
            assert!((0.0..=1.0).contains(&s), "{}: {s}", i.name);
        }
    }

    #[test]
    fn parts_clamp_extremes() {
        let mut island = preset_personal_group().remove(5);
        island.latency_ms = 99_999.0;
        let p = ScoreParts::compute(&island, 1_000_000);
        assert_eq!(p.latency, 1.0);
        assert_eq!(p.cost, 1.0);
    }
}
