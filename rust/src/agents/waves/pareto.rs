//! Pareto-front utilities (§II.C / §VI.C).
//!
//! The greedy Eq. 1 scalarization picks one point; these helpers compute the
//! actual non-dominated set over (cost, latency, 1−privacy) so tests and the
//! eval harness can verify the §VI.C property: *for strictly positive
//! weights, the scalarized argmin is Pareto-optimal*.

use crate::agents::waves::scoring::ScoreParts;
use crate::types::{Island, IslandId};

/// One candidate point in objective space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub id: IslandId,
    pub cost: f64,
    pub latency: f64,
    pub privacy_penalty: f64,
}

impl Point {
    pub fn of(island: &Island, tokens: usize) -> Point {
        let p = ScoreParts::compute(island, tokens);
        Point { id: island.id, cost: p.cost, latency: p.latency, privacy_penalty: p.privacy_penalty }
    }

    /// Does `self` dominate `other` (≤ in all objectives, < in at least one)?
    pub fn dominates(&self, other: &Point) -> bool {
        let le = self.cost <= other.cost && self.latency <= other.latency && self.privacy_penalty <= other.privacy_penalty;
        let lt = self.cost < other.cost || self.latency < other.latency || self.privacy_penalty < other.privacy_penalty;
        le && lt
    }
}

/// Non-dominated subset (the Pareto front). O(n²) — fine for n ≤ dozens of
/// islands (§VI.B assumes n < 10).
pub fn pareto_front(points: &[Point]) -> Vec<Point> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .copied()
        .collect()
}

/// Is `id` on the front?
pub fn on_front(points: &[Point], id: IslandId) -> bool {
    pareto_front(points).iter().any(|p| p.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(id: u32, c: f64, l: f64, p: f64) -> Point {
        Point { id: IslandId(id), cost: c, latency: l, privacy_penalty: p }
    }

    #[test]
    fn dominance_definition() {
        let a = pt(0, 0.1, 0.1, 0.1);
        let b = pt(1, 0.2, 0.2, 0.2);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        // equal points do not dominate each other
        let c = pt(2, 0.1, 0.1, 0.1);
        assert!(!a.dominates(&c) && !c.dominates(&a));
    }

    #[test]
    fn front_excludes_dominated() {
        let pts =
            vec![pt(0, 0.0, 0.5, 0.5), pt(1, 0.5, 0.0, 0.5), pt(2, 0.5, 0.5, 0.0), pt(3, 0.6, 0.6, 0.6)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 3);
        assert!(!on_front(&pts, IslandId(3)));
    }

    #[test]
    fn incomparable_points_all_on_front() {
        let pts = vec![pt(0, 0.1, 0.9, 0.5), pt(1, 0.9, 0.1, 0.5), pt(2, 0.5, 0.5, 0.1)];
        assert_eq!(pareto_front(&pts).len(), 3);
    }

    #[test]
    fn scalarized_argmin_is_on_front_for_positive_weights() {
        // §VI.C property, checked exhaustively over a random cloud of points
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let pts: Vec<Point> =
                (0..8).map(|i| pt(i, rng.f64(), rng.f64(), rng.f64())).collect();
            let (w1, w2, w3) = (0.2 + rng.f64(), 0.2 + rng.f64(), 0.2 + rng.f64());
            let best = pts
                .iter()
                .min_by(|a, b| {
                    let sa = w1 * a.cost + w2 * a.latency + w3 * a.privacy_penalty;
                    let sb = w1 * b.cost + w2 * b.latency + w3 * b.privacy_penalty;
                    sa.partial_cmp(&sb).unwrap()
                })
                .unwrap();
            assert!(on_front(&pts, best.id), "argmin must be Pareto-optimal");
        }
    }

    #[test]
    fn empty_input() {
        assert!(pareto_front(&[]).is_empty());
    }
}
