//! §IX.B tiered prompt routing: primary / secondary / burstable admission.
//!
//! During resource contention WAVES routes:
//!   Primary   → always local (may queue)
//!   Secondary → local if R > 50%, else cloud
//!   Burstable → local if R > 80%, else cloud immediately
//!
//! "Local" means the user's personal island group (Tier 1); "cloud" means
//! any island outside it. This module decides, per request, which island
//! *classes* are admissible given current local capacity — the router then
//! scores within the admissible set.

use crate::config::Config;
use crate::types::{Island, PriorityTier, TrustTier};

/// Where a priority tier may execute given local capacity R.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Only personal (Tier-1) islands; queue if saturated.
    LocalOnly,
    /// Personal preferred, non-personal allowed.
    PreferLocal,
    /// Non-personal preferred (offload immediately), local allowed if idle.
    PreferOffload,
}

/// §IX.B decision table.
pub fn admission(priority: PriorityTier, local_capacity: f64, config: &Config) -> Admission {
    match priority {
        PriorityTier::Primary => Admission::LocalOnly,
        PriorityTier::Secondary => {
            if local_capacity > config.secondary_local_threshold {
                Admission::PreferLocal
            } else {
                Admission::PreferOffload
            }
        }
        PriorityTier::Burstable => {
            if local_capacity > config.burstable_local_threshold {
                Admission::PreferLocal
            } else {
                Admission::PreferOffload
            }
        }
    }
}

/// Does an island fall on the "local" side of the admission split?
pub fn is_local(island: &Island) -> bool {
    island.tier == TrustTier::Personal
}

/// Filter candidate islands by the admission decision. Returns (primary
/// choice set, fallback set) — the router tries the first, then the second.
pub fn admissible<'a>(islands: &'a [Island], adm: Admission) -> (Vec<&'a Island>, Vec<&'a Island>) {
    let (local, remote): (Vec<&Island>, Vec<&Island>) = islands.iter().partition(|i| is_local(i));
    match adm {
        Admission::LocalOnly => (local, Vec::new()),
        Admission::PreferLocal => (local, remote),
        Admission::PreferOffload => (remote, local),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    #[test]
    fn primary_always_local() {
        let cfg = Config::default();
        for r in [0.0, 0.3, 0.6, 1.0] {
            assert_eq!(admission(PriorityTier::Primary, r, &cfg), Admission::LocalOnly);
        }
    }

    #[test]
    fn secondary_threshold_at_50() {
        let cfg = Config::default();
        assert_eq!(admission(PriorityTier::Secondary, 0.6, &cfg), Admission::PreferLocal);
        assert_eq!(admission(PriorityTier::Secondary, 0.5, &cfg), Admission::PreferOffload);
        assert_eq!(admission(PriorityTier::Secondary, 0.2, &cfg), Admission::PreferOffload);
    }

    #[test]
    fn burstable_threshold_at_80() {
        let cfg = Config::default();
        assert_eq!(admission(PriorityTier::Burstable, 0.9, &cfg), Admission::PreferLocal);
        assert_eq!(admission(PriorityTier::Burstable, 0.7, &cfg), Admission::PreferOffload);
    }

    #[test]
    fn admissible_partitions_by_tier() {
        let islands = preset_personal_group();
        let (first, second) = admissible(&islands, Admission::LocalOnly);
        assert_eq!(first.len(), 4); // 4 personal devices
        assert!(second.is_empty());
        let (first, second) = admissible(&islands, Admission::PreferOffload);
        assert_eq!(first.len(), 3); // edge + 2 cloud
        assert_eq!(second.len(), 4);
        assert!(first.iter().all(|i| !is_local(i)));
    }

    #[test]
    fn thresholds_configurable() {
        let mut cfg = Config::default();
        cfg.secondary_local_threshold = 0.9;
        assert_eq!(admission(PriorityTier::Secondary, 0.8, &cfg), Admission::PreferOffload);
    }
}
