//! WAVES — Weighted Agent-based Variance Equilibration System (§VI).
//!
//! The multi-objective router: Eq. 1 scalarization ([`scoring`]), the
//! §VI.C constraint-based alternative, Pareto-front verification
//! ([`pareto`]), §IX.B priority-tier admission ([`tiers`]) and Algorithm 1
//! itself ([`router`]).

pub mod pareto;
pub mod router;
pub mod scoring;
pub mod tiers;

pub use router::{Decision, IslandState, Routed, Waves};
