//! WAVES multi-objective router — Algorithm 1.
//!
//! Pipeline per request (Fig. 2 route-then-sanitize):
//!  1. privacy filter `P_j ≥ s_r` (Def. 3, fail-closed on empty set),
//!  2. data-locality filter (Guarantee 3: requests needing dataset D run
//!     only where D lives),
//!  3. §IX.B priority-tier admission given local capacity,
//!  4. capacity / battery / budget / hysteresis feasibility,
//!  5. Eq. 1 argmin (or §VI.C constraint-mode latency argmin) plus any
//!     registered extension scorers,
//!  6. sanitize decision for trust-boundary crossings (Alg. 1 lines 14–17).
//!
//! Fail-closed (§III.C): when no island satisfies the privacy constraint the
//! request is *rejected*, never silently degraded. When privacy-eligible
//! islands exist but none has capacity, Algorithm 1 line 11's failsafe
//! applies: queue on the best local island.

use crate::agents::waves::scoring::{self, ScoreParts};
use crate::agents::waves::tiers::{self, Admission};
use crate::agents::Scorer;
use crate::agents::tide::hysteresis::Preference;
use crate::config::{Config, RouterMode};
use crate::types::{Island, IslandId, LinkKind, Request};

/// Dynamic view of one island at routing time.
#[derive(Clone, Debug)]
pub struct IslandState {
    pub island: Island,
    /// Available capacity R_j(t) in [0,1]; unbounded islands report 1.0.
    pub capacity: f64,
    /// LIGHTHOUSE heartbeat liveness. Offline islands are dropped before
    /// any other constraint is evaluated — a dead island is never a routing
    /// candidate, however Pareto-optimal its static profile looks.
    pub online: bool,
    /// TIDE capacity-degradation signal: the island is reachable but has
    /// served zero capacity for a full detection window. Degraded islands
    /// are already infeasible for the scored sets (capacity ≈ 0); the flag
    /// additionally deprioritizes them as the failsafe pick — but never
    /// converts saturation into a rejection (a degraded island still beats
    /// rejecting when it is the only privacy-eligible one left).
    pub degraded: bool,
}

/// Why a request was routed where it was (experiment reporting / audit log).
#[derive(Clone, Debug, PartialEq)]
pub struct Routed {
    pub target: IslandId,
    pub score: f64,
    /// Must the chat context be sanitized before transmission?
    pub sanitize: bool,
    /// Privacy score of the selected island (drives sanitization level).
    pub target_privacy: f64,
    pub admission: Admission,
}

/// Routing outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    Route(Routed),
    /// Algorithm 1 line 11: no capacity anywhere eligible — queue locally.
    FailsafeLocal(Routed),
    /// Fail-closed rejection (privacy or data-locality unsatisfiable).
    Reject { reason: String },
}

impl Decision {
    pub fn target(&self) -> Option<IslandId> {
        match self {
            Decision::Route(r) | Decision::FailsafeLocal(r) => Some(r.target),
            Decision::Reject { .. } => None,
        }
    }

    pub fn routed(&self) -> Option<&Routed> {
        match self {
            Decision::Route(r) | Decision::FailsafeLocal(r) => Some(r),
            Decision::Reject { .. } => None,
        }
    }
}

/// The WAVES router.
pub struct Waves {
    pub config: Config,
    /// Extension scorers: (agent, weight) — §IV extensibility hook.
    scorers: Vec<(Box<dyn Scorer>, f64)>,
}

/// Battery floor below which battery-powered islands are avoided when any
/// alternative exists (Scenario 2: hiking friends).
const BATTERY_FLOOR: f64 = 0.25;

impl Waves {
    pub fn new(config: Config) -> Waves {
        Waves { config, scorers: Vec::new() }
    }

    /// Register an extension objective (e.g. [`crate::agents::CarbonScorer`])
    /// with a weight; no router changes required (§IV).
    pub fn add_scorer(&mut self, scorer: Box<dyn Scorer>, weight: f64) {
        self.scorers.push((scorer, weight));
    }

    fn total_score(&self, request: &Request, island: &Island) -> f64 {
        let tokens = request.token_estimate();
        let base = match self.config.mode {
            RouterMode::Scalarized => scoring::eq1_score(island, tokens, &self.config.weights),
            // §VI.C constraint-based: among feasible, minimize latency only.
            RouterMode::ConstraintBased => ScoreParts::compute(island, tokens).latency,
        };
        let ext: f64 = self.scorers.iter().map(|(s, w)| w * s.score(request, island)).sum();
        base + ext
    }

    /// Algorithm 1. `s_r` comes from MIST (caller owns the MIST instance so
    /// a dead MIST's conservative fallback is visible upstream);
    /// `local_capacity` and `pref` come from TIDE; `states` from LIGHTHOUSE.
    /// `budget_left` is the user's remaining spend (cost agent).
    pub fn route(
        &self,
        request: &Request,
        s_r: f64,
        states: &[IslandState],
        local_capacity: f64,
        pref: Preference,
        budget_left: f64,
    ) -> Decision {
        // -- 0. liveness filter (LIGHTHOUSE view): heartbeat-offline
        // islands are not candidates for anything — not even the failsafe.
        // (Degraded islands stay in: they are deprioritized in step 6, not
        // excluded — saturation must queue, never reject.)
        let online: Vec<&IslandState> = states.iter().filter(|s| s.online).collect();
        if online.is_empty() {
            return Decision::Reject { reason: "no online island (fleet unreachable, fail-closed)".to_string() };
        }

        // -- 1. privacy constraint (Def. 3): fail-closed on violation
        let eligible: Vec<&IslandState> = online.into_iter().filter(|s| s.island.privacy >= s_r).collect();
        if eligible.is_empty() {
            return Decision::Reject {
                reason: format!("no online island satisfies privacy constraint P_j >= {s_r:.2} (fail-closed)"),
            };
        }

        // -- 2. data locality (Guarantee 3)
        let eligible: Vec<&IslandState> = match &request.required_dataset {
            Some(ds) => {
                let with: Vec<&IslandState> = eligible.iter().filter(|s| s.island.has_dataset(ds)).copied().collect();
                if with.is_empty() {
                    return Decision::Reject {
                        reason: format!("dataset '{ds}' not present on any privacy-eligible island"),
                    };
                }
                with
            }
            None => eligible,
        };

        // -- 2b. §XIV heterogeneous model support: restrict to islands that
        // advertise the required model family (fail-closed like datasets —
        // there is no point routing to an island that cannot serve it).
        let eligible: Vec<&IslandState> = match &request.required_model {
            Some(model) => {
                let with: Vec<&IslandState> =
                    eligible.iter().filter(|s| s.island.serves_model(model)).copied().collect();
                if with.is_empty() {
                    return Decision::Reject { reason: format!("model '{model}' not served by any eligible island") };
                }
                with
            }
            None => eligible,
        };

        // -- 2c. §XIV regulatory compliance: jurisdiction floor. Like the
        // privacy constraint this is inviolable (GDPR-class workloads must
        // not land on low-jurisdiction islands even under pressure).
        let eligible: Vec<&IslandState> = match request.min_jurisdiction {
            Some(floor) => {
                let with: Vec<&IslandState> =
                    eligible.iter().filter(|s| s.island.jurisdiction.score() >= floor).copied().collect();
                if with.is_empty() {
                    return Decision::Reject {
                        reason: format!("no eligible island meets jurisdiction floor {floor:.2}"),
                    };
                }
                with
            }
            None => eligible,
        };

        // -- 3. priority-tier admission (index partition; no island clones
        // on the hot path — §Perf iteration 3)
        let adm = tiers::admission(request.priority, local_capacity, &self.config);
        let (local_set, remote_set): (Vec<&IslandState>, Vec<&IslandState>) =
            eligible.iter().partition(|s| tiers::is_local(&s.island));
        let (primary_set, fallback_set): (Vec<&IslandState>, Vec<&IslandState>) = match adm {
            Admission::LocalOnly => (local_set, Vec::new()),
            Admission::PreferLocal => (local_set, remote_set),
            Admission::PreferOffload => (remote_set, local_set),
        };

        // -- 4/5. feasibility + scoring within the admission sets
        let tokens = request.token_estimate();
        for (set_idx, set) in [&primary_set, &fallback_set].into_iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            let mut feasible: Vec<&&IslandState> = set
                .iter()
                .filter(|s| {
                    let cap_ok = s.island.unbounded() || s.capacity > self.config.buffer.buffer();
                    let battery_ok = s.island.battery.map(|b| b > BATTERY_FLOOR).unwrap_or(true);
                    let budget_ok = s.island.request_cost(tokens) <= budget_left;
                    // deadline feasibility (Def. 2 d_r): an island whose
                    // declared base RTT already exceeds the latency budget
                    // cannot possibly meet it, whatever its score. Soft
                    // overall — if no island anywhere satisfies it, the
                    // Alg. 1 failsafe still queues (served late beats lost).
                    let deadline_ok = s.island.latency_ms <= request.deadline_ms;
                    cap_ok && battery_ok && budget_ok && deadline_ok
                })
                .collect();
            // battery relaxation: if the floor filtered everything, allow
            // low-battery islands rather than failing (privacy first). The
            // deadline stays enforced here so a too-slow primary set falls
            // through to the fallback set (which may hold faster islands).
            if feasible.is_empty() {
                feasible = set
                    .iter()
                    .filter(|s| {
                        (s.island.unbounded() || s.capacity > self.config.buffer.buffer())
                            && s.island.request_cost(tokens) <= budget_left
                            && s.island.latency_ms <= request.deadline_ms
                    })
                    .collect();
            }
            // hysteresis: under cloud preference, avoid the loopback SHORE
            // for offloadable tiers when any remote candidate exists.
            if pref == Preference::Cloud && adm != Admission::LocalOnly && set_idx == 0 {
                let non_loopback: Vec<&&IslandState> =
                    feasible.iter().filter(|s| s.island.link != LinkKind::Loopback).copied().collect();
                if !non_loopback.is_empty() {
                    feasible = non_loopback;
                }
            }
            let best = feasible.iter().min_by(|a, b| {
                self.total_score(request, &a.island).total_cmp(&self.total_score(request, &b.island))
            });
            if let Some(best) = best {
                return Decision::Route(self.routed(request, &best.island, adm));
            }
        }

        // -- 6. failsafe (Alg. 1 line 11): privacy-eligible islands exist
        // but none has capacity — queue on the highest-privacy one,
        // preferring islands TIDE has not flagged as degraded.
        let failsafe = eligible.iter().max_by(|a, b| {
            (!a.degraded)
                .cmp(&!b.degraded)
                .then(a.island.privacy.total_cmp(&b.island.privacy))
                .then(a.capacity.total_cmp(&b.capacity))
        });
        match failsafe {
            Some(failsafe) => Decision::FailsafeLocal(self.routed(request, &failsafe.island, adm)),
            // unreachable in practice: step 1 rejects when no island is
            // privacy-eligible, so `eligible` is non-empty here. Shed
            // fail-closed rather than panic if that invariant ever breaks.
            None => Decision::Reject { reason: "no privacy-eligible island for failsafe queueing".to_string() },
        }
    }

    fn routed(&self, request: &Request, island: &Island, adm: Admission) -> Routed {
        // Alg. 1 lines 14-17: sanitize when crossing to lower trust with
        // chat context; intra-personal (P = 1.0) bypasses MIST entirely.
        let prev = request.prev_island_privacy.unwrap_or(1.0);
        let sanitize = !request.history.is_empty() && prev > island.privacy && island.privacy < 1.0;
        Routed {
            target: island.id,
            score: self.total_score(request, island),
            sanitize,
            target_privacy: island.privacy,
            admission: adm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::CarbonScorer;
    use crate::config::{preset_personal_group, BufferProfile};
    use crate::types::{PriorityTier, Role, Turn};

    fn states(capacity: f64) -> Vec<IslandState> {
        preset_personal_group()
            .into_iter()
            .map(|island| {
                let cap = if island.unbounded() { 1.0 } else { capacity };
                IslandState { island, capacity: cap, online: true, degraded: false }
            })
            .collect()
    }

    fn waves() -> Waves {
        Waves::new(Config::default())
    }

    fn route_simple(w: &Waves, s_r: f64, priority: PriorityTier, cap: f64) -> Decision {
        let r = Request::new(1, "test prompt").with_priority(priority);
        w.route(&r, s_r, &states(cap), cap, Preference::Local, f64::INFINITY)
    }

    #[test]
    fn high_sensitivity_routes_to_personal_island() {
        let d = route_simple(&waves(), 0.9, PriorityTier::Primary, 0.9);
        let routed = d.routed().expect("routed");
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == routed.target).unwrap();
        assert_eq!(target.tier, crate::types::TrustTier::Personal);
        assert!(target.privacy >= 0.9);
    }

    #[test]
    fn low_sensitivity_burstable_under_load_goes_to_cloud() {
        // burstable with local capacity 0.3 (< 0.8 threshold) → offload
        let d = route_simple(&waves(), 0.3, PriorityTier::Burstable, 0.3);
        let routed = d.routed().expect("routed");
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == routed.target).unwrap();
        assert_ne!(target.tier, crate::types::TrustTier::Personal, "target={}", target.name);
    }

    #[test]
    fn fail_closed_when_privacy_unsatisfiable() {
        let w = waves();
        // only cloud islands online; sensitive request must be rejected
        let cloud_only: Vec<IslandState> =
            states(1.0).into_iter().filter(|s| s.island.privacy < 0.9).collect();
        let r = Request::new(1, "patient data").with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &cloud_only, 1.0, Preference::Local, f64::INFINITY);
        assert!(matches!(d, Decision::Reject { .. }), "{d:?}");
    }

    #[test]
    fn failsafe_queues_locally_when_no_capacity() {
        // all bounded islands saturated; primary request cannot offload
        let d = route_simple(&waves(), 0.9, PriorityTier::Primary, 0.0);
        match d {
            Decision::FailsafeLocal(r) => {
                assert_eq!(r.target_privacy, 1.0);
            }
            other => panic!("expected failsafe, got {other:?}"),
        }
    }

    #[test]
    fn attack1_false_exhaustion_cannot_leak_privacy() {
        // §VIII.C Attack 1: TIDE reports local exhaustion; privacy constraint
        // must still hold — the request queues locally rather than going to
        // cloud.
        let w = waves();
        let r = Request::new(1, "patient record").with_priority(PriorityTier::Primary);
        let mut st = states(0.0); // compromised TIDE: everything "exhausted"
        for s in st.iter_mut() {
            if s.island.unbounded() {
                s.capacity = 1.0;
            }
        }
        let d = w.route(&r, 0.9, &st, 0.0, Preference::Cloud, f64::INFINITY);
        let target = d.target().expect("not rejected");
        let islands = preset_personal_group();
        let island = islands.iter().find(|i| i.id == target).unwrap();
        assert!(island.privacy >= 0.9, "leaked to {}", island.name);
    }

    #[test]
    fn dataset_constraint_routes_to_data() {
        let w = waves();
        let mut st = states(0.9);
        st[4].island.datasets.push("case_law".to_string()); // private-edge
        let r = Request::new(1, "find precedent").with_dataset("case_law");
        let d = w.route(&r, 0.5, &st, 0.9, Preference::Local, f64::INFINITY);
        assert_eq!(d.target(), Some(st[4].island.id));
        // dataset nowhere → reject
        let r2 = Request::new(2, "q").with_dataset("missing_ds");
        let d2 = w.route(&r2, 0.2, &st, 0.9, Preference::Local, f64::INFINITY);
        assert!(matches!(d2, Decision::Reject { .. }));
    }

    #[test]
    fn sanitize_required_when_crossing_down() {
        let w = waves();
        let r = Request::new(1, "general question")
            .with_priority(PriorityTier::Burstable)
            .with_history(vec![Turn { role: Role::User, text: "earlier sensitive turn".into() }]);
        // low local capacity pushes burstable to cloud
        let d = w.route(&r, 0.3, &states(0.2), 0.2, Preference::Local, f64::INFINITY);
        let routed = d.routed().unwrap();
        assert!(routed.target_privacy < 1.0);
        assert!(routed.sanitize, "crossing 1.0 -> {} must sanitize", routed.target_privacy);
    }

    #[test]
    fn no_sanitize_within_personal_group() {
        let w = waves();
        let r = Request::new(1, "continue the chat")
            .with_priority(PriorityTier::Primary)
            .with_history(vec![Turn { role: Role::User, text: "ctx".into() }]);
        let d = w.route(&r, 0.9, &states(0.9), 0.9, Preference::Local, f64::INFINITY);
        let routed = d.routed().unwrap();
        assert_eq!(routed.target_privacy, 1.0);
        assert!(!routed.sanitize, "intra-personal routing bypasses MIST");
    }

    #[test]
    fn budget_excludes_paid_islands() {
        let w = waves();
        let r = Request::new(1, "cheap question").with_priority(PriorityTier::Burstable);
        // local capacity low → would prefer cloud, but budget_left = 0
        let d = w.route(&r, 0.2, &states(0.5), 0.5, Preference::Local, 0.0);
        let target = d.target().unwrap();
        let islands = preset_personal_group();
        let island = islands.iter().find(|i| i.id == target).unwrap();
        assert_eq!(island.request_cost(100), 0.0, "must pick a free island");
    }

    #[test]
    fn low_battery_island_avoided_when_alternative_exists() {
        let w = waves();
        let mut st = states(0.9);
        st[0].island.battery = Some(0.1); // laptop nearly dead
        let r = Request::new(1, "x").with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &st, 0.9, Preference::Local, f64::INFINITY);
        assert_ne!(d.target(), Some(st[0].island.id), "low-battery island should be avoided");
    }

    #[test]
    fn hysteresis_cloud_pref_avoids_loopback() {
        let w = waves();
        let r = Request::new(1, "q").with_priority(PriorityTier::Secondary);
        // capacity above secondary threshold so admission = PreferLocal,
        // but hysteresis preference is Cloud → loopback skipped
        let d = w.route(&r, 0.2, &states(0.6), 0.6, Preference::Cloud, f64::INFINITY);
        let target = d.target().unwrap();
        let islands = preset_personal_group();
        let island = islands.iter().find(|i| i.id == target).unwrap();
        assert_ne!(island.link, LinkKind::Loopback);
    }

    #[test]
    fn extension_scorer_changes_choice_without_router_edits() {
        // §IV extensibility: with a huge carbon weight, the router should
        // strictly prefer personal islands even for burstable-offload cases.
        let mut w = waves();
        w.add_scorer(Box::new(CarbonScorer), 10.0);
        let r = Request::new(1, "q").with_priority(PriorityTier::Secondary);
        let d = w.route(&r, 0.2, &states(0.6), 0.6, Preference::Local, f64::INFINITY);
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == d.target().unwrap()).unwrap();
        assert_eq!(target.tier, crate::types::TrustTier::Personal);
    }

    #[test]
    fn constraint_mode_minimizes_latency_among_feasible() {
        let mut cfg = Config::default();
        cfg.mode = RouterMode::ConstraintBased;
        cfg.buffer = BufferProfile::Aggressive;
        let w = Waves::new(cfg);
        let r = Request::new(1, "q").with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &states(0.9), 0.9, Preference::Local, f64::INFINITY);
        // fastest personal island is the laptop (5ms loopback)
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == d.target().unwrap()).unwrap();
        assert_eq!(target.name, "laptop");
    }

    #[test]
    fn model_capability_matching() {
        // §XIV heterogeneous model support
        let w = waves();
        let mut st = states(0.9);
        st[4].island.models = vec!["tinylm".into(), "llama-13b".into()]; // edge serves both
        let r = Request::new(1, "q").with_model("llama-13b");
        let d = w.route(&r, 0.5, &st, 0.9, Preference::Local, f64::INFINITY);
        assert_eq!(d.target(), Some(st[4].island.id));
        // unknown model fails closed
        let r2 = Request::new(2, "q").with_model("gpt-97");
        assert!(matches!(w.route(&r2, 0.2, &st, 0.9, Preference::Local, f64::INFINITY), Decision::Reject { .. }));
    }

    #[test]
    fn jurisdiction_floor_is_inviolable() {
        // §XIV regulatory compliance: GDPR workloads (floor 0.9) can never
        // land on Foreign-jurisdiction islands, even when those are the
        // only ones with capacity.
        let w = waves();
        let mut st = states(0.0); // all bounded islands exhausted
        for s in st.iter_mut() {
            if s.island.unbounded() {
                s.capacity = 1.0;
            }
        }
        let r = Request::new(1, "eu customer record")
            .with_priority(PriorityTier::Secondary)
            .with_min_jurisdiction(0.9);
        let d = w.route(&r, 0.5, &st, 0.0, Preference::Cloud, f64::INFINITY);
        match d.target() {
            Some(id) => {
                let island = &st.iter().find(|s| s.island.id == id).unwrap().island;
                assert!(island.jurisdiction.score() >= 0.9, "landed on {}", island.name);
            }
            None => {} // fail-closed acceptable
        }
        // and with an impossible floor, reject
        let r2 = Request::new(2, "q").with_min_jurisdiction(1.1);
        assert!(matches!(w.route(&r2, 0.2, &st, 1.0, Preference::Local, f64::INFINITY), Decision::Reject { .. }));
    }

    #[test]
    fn offline_island_never_selected_even_when_pareto_optimal() {
        let w = waves();
        let r = Request::new(1, "sensitive patient record").with_priority(PriorityTier::Primary);
        // find where the router sends this when everything is online …
        let healthy = w.route(&r, 0.9, &states(0.9), 0.9, Preference::Local, f64::INFINITY);
        let best = healthy.target().expect("routes when healthy");
        // … then take exactly that island offline: it must never be chosen
        // again, even though it is still the Pareto-optimal candidate.
        let mut st = states(0.9);
        st.iter_mut().find(|s| s.island.id == best).unwrap().online = false;
        let d = w.route(&r, 0.9, &st, 0.9, Preference::Local, f64::INFINITY);
        let target = d.target().expect("fails over to another eligible island");
        assert_ne!(target, best, "offline island selected");
        let island = &st.iter().find(|s| s.island.id == target).unwrap().island;
        assert!(island.privacy >= 0.9, "failover must keep the privacy constraint");
    }

    #[test]
    fn all_offline_rejects_with_liveness_reason() {
        let w = waves();
        let mut st = states(1.0);
        for s in st.iter_mut() {
            s.online = false;
        }
        let r = Request::new(1, "q").with_priority(PriorityTier::Secondary);
        match w.route(&r, 0.2, &st, 1.0, Preference::Local, f64::INFINITY) {
            Decision::Reject { reason } => {
                assert!(reason.contains("no online island"), "reason: {reason}");
            }
            other => panic!("expected liveness reject, got {other:?}"),
        }
    }

    #[test]
    fn offline_local_tier_falls_through_to_remote_tier() {
        let w = waves();
        let mut st = states(0.9);
        // the whole personal tier dies; a low-sensitivity secondary request
        // must fall through to the remote admission set instead of failing
        for s in st.iter_mut() {
            if tiers::is_local(&s.island) {
                s.online = false;
            }
        }
        let r = Request::new(1, "what is rust").with_priority(PriorityTier::Secondary);
        let d = w.route(&r, 0.2, &st, 0.9, Preference::Local, f64::INFINITY);
        let target = d.target().expect("remote tier must pick it up");
        let island = &st.iter().find(|s| s.island.id == target).unwrap().island;
        assert!(!tiers::is_local(island), "picked dead-local tier island {}", island.name);
    }

    #[test]
    fn offline_islands_excluded_from_failsafe() {
        let w = waves();
        // zero capacity everywhere → failsafe path; the highest-privacy
        // island is offline, so the failsafe must queue on the best *online*
        // privacy-eligible island instead.
        let mut st = states(0.0);
        for s in st.iter_mut() {
            if s.island.unbounded() {
                s.capacity = 0.0; // force failsafe even past unbounded islands
            }
        }
        let best_privacy = st
            .iter()
            .filter(|s| s.island.privacy >= 0.9)
            .max_by(|a, b| a.island.privacy.partial_cmp(&b.island.privacy).unwrap())
            .unwrap()
            .island
            .id;
        st.iter_mut().find(|s| s.island.id == best_privacy).unwrap().online = false;
        let r = Request::new(1, "patient ssn data").with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &st, 0.0, Preference::Local, f64::INFINITY);
        match d {
            Decision::FailsafeLocal(routed) => assert_ne!(routed.target, best_privacy),
            Decision::Route(routed) => assert_ne!(routed.target, best_privacy),
            Decision::Reject { .. } => {} // acceptable only if no online island was eligible
        }
    }

    #[test]
    fn failsafe_prefers_non_degraded_but_never_rejects_for_saturation() {
        let w = waves();
        // every privacy-eligible island saturated (failsafe territory); the
        // ones TIDE flagged degraded must lose the failsafe pick...
        let mut st = states(0.0);
        let eligible_ids: Vec<_> =
            st.iter().filter(|s| s.island.privacy >= 0.9).map(|s| s.island.id).collect();
        let survivor = eligible_ids[0];
        for s in st.iter_mut() {
            if s.island.privacy >= 0.9 && s.island.id != survivor {
                s.degraded = true;
            }
        }
        let r = Request::new(1, "patient ssn record").with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &st, 0.0, Preference::Local, f64::INFINITY);
        assert_eq!(d.target(), Some(survivor), "{d:?}");
        // ...but when every eligible island is degraded, saturation still
        // queues (FailsafeLocal) instead of rejecting
        for s in st.iter_mut() {
            if s.island.privacy >= 0.9 {
                s.degraded = true;
            }
        }
        let d2 = w.route(&r, 0.9, &st, 0.0, Preference::Local, f64::INFINITY);
        assert!(d2.target().is_some(), "all-degraded must queue, not reject: {d2:?}");
    }

    #[test]
    fn deadline_excludes_high_rtt_islands_softly() {
        let w = waves();
        // burstable under pressure with the private edge saturated normally
        // offloads to cloud (180/220 ms base RTT); a 150 ms latency budget
        // must keep it off those islands
        let mut st = states(0.3);
        st[4].capacity = 0.0; // private edge saturated → infeasible
        let r = Request::new(1, "quick question").with_priority(PriorityTier::Burstable).with_deadline(150.0);
        let d = w.route(&r, 0.2, &st, 0.3, Preference::Local, f64::INFINITY);
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| Some(i.id) == d.target()).unwrap();
        assert!(target.latency_ms <= 150.0, "picked {} at {} ms", target.name, target.latency_ms);
        // the same request without the deadline goes remote past 150 ms
        let r2 = Request::new(2, "quick question").with_priority(PriorityTier::Burstable);
        let d2 = w.route(&r2, 0.2, &st, 0.3, Preference::Local, f64::INFINITY);
        let t2 = islands.iter().find(|i| Some(i.id) == d2.target()).unwrap();
        assert!(t2.latency_ms > 150.0, "without a deadline the cheap cloud wins ({})", t2.name);
        // an impossible deadline is soft: the failsafe still queues the
        // request (late beats lost), it is never rejected for slowness
        let r3 = Request::new(3, "q").with_priority(PriorityTier::Secondary).with_deadline(1.0);
        let d3 = w.route(&r3, 0.2, &states(0.9), 0.9, Preference::Local, f64::INFINITY);
        assert!(d3.target().is_some(), "deadline must never fail-closed: {d3:?}");
    }

    #[test]
    fn motivating_example_flow() {
        // §I.A: laptop busy, edge P=0.8 < s_r=0.9 fails constraint, cloud
        // ruled out; home NAS (P=1.0, capacity) wins.
        let w = waves();
        let mut st = states(0.9);
        st[0].capacity = 0.05; // laptop at high utilization
        let r = Request::new(1, "analyze treatment options for patient")
            .with_priority(PriorityTier::Primary);
        let d = w.route(&r, 0.9, &st, 0.9, Preference::Local, f64::INFINITY);
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == d.target().unwrap()).unwrap();
        assert!(target.privacy >= 0.9);
        assert_ne!(target.name, "laptop");
        // follow-up general query (s_r=0.3) may use cloud when local is busy
        let r2 = Request::new(2, "what are common diabetes complications")
            .with_priority(PriorityTier::Burstable);
        let mut st2 = states(0.1);
        st2[0].capacity = 0.05;
        let d2 = w.route(&r2, 0.3, &st2, 0.1, Preference::Cloud, f64::INFINITY);
        let t2 = islands.iter().find(|i| i.id == d2.target().unwrap()).unwrap();
        assert_eq!(t2.tier, crate::types::TrustTier::Cloud);
    }
}
