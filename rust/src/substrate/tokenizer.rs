//! Byte-level tokenizer matching the python side (VOCAB=256, SEQ_LEN=64).
//!
//! The TinyLM artifacts operate on raw UTF-8 bytes, so "tokenization" is
//! byte mapping plus fixed-window padding/truncation to the AOT sequence
//! length. Kept as its own substrate so the runtime and examples share the
//! exact framing rules (left-truncate, right-pad with PAD).

/// Pad byte. 0 is a fine pad for the byte-level LM: the corpus never
/// contains NUL and the model learns to treat it as filler.
pub const PAD: u8 = 0;

/// Fixed context window of the AOT artifacts (mirrors meta.json seq_len).
pub const SEQ_LEN: usize = 64;

/// Encode text to exactly `seq_len` token ids: UTF-8 bytes, LEFT-truncated
/// (keep the most recent context, like a chat window), right-padded.
pub fn encode_fixed(text: &str, seq_len: usize) -> Vec<i32> {
    let bytes = text.as_bytes();
    let start = bytes.len().saturating_sub(seq_len);
    let mut ids: Vec<i32> = bytes[start..].iter().map(|&b| b as i32).collect();
    ids.resize(seq_len, PAD as i32);
    ids
}

/// Number of real (non-pad) tokens `encode_fixed` would produce.
pub fn real_len(text: &str, seq_len: usize) -> usize {
    text.as_bytes().len().min(seq_len)
}

/// Decode token ids back to text, stopping at the first PAD; invalid UTF-8
/// is replaced (the tiny byte LM can emit partial sequences).
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids.iter().take_while(|&&i| i != PAD as i32).map(|&i| (i & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Sliding decode-window append: drop the first token, push `next` at the
/// end of the real prefix (greedy decode loop helper).
pub fn push_token(ids: &mut Vec<i32>, real: &mut usize, next: i32) {
    if *real < ids.len() {
        ids[*real] = next;
        *real += 1;
    } else {
        ids.remove(0);
        ids.push(next);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_pads_to_length() {
        let ids = encode_fixed("abc", 8);
        assert_eq!(ids, vec![97, 98, 99, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn encode_left_truncates() {
        let text = "0123456789";
        let ids = encode_fixed(text, 4);
        assert_eq!(ids, vec![b'6' as i32, b'7' as i32, b'8' as i32, b'9' as i32]);
    }

    #[test]
    fn decode_round_trip() {
        let ids = encode_fixed("hello islands", 64);
        assert_eq!(decode(&ids), "hello islands");
    }

    #[test]
    fn decode_stops_at_pad() {
        assert_eq!(decode(&[104, 105, 0, 120]), "hi");
    }

    #[test]
    fn real_len_caps_at_window() {
        assert_eq!(real_len("abc", 64), 3);
        assert_eq!(real_len(&"x".repeat(100), 64), 64);
    }

    #[test]
    fn push_token_fills_then_slides() {
        let mut ids = vec![97, 98, 0, 0];
        let mut real = 2;
        push_token(&mut ids, &mut real, 99);
        assert_eq!(ids, vec![97, 98, 99, 0]);
        assert_eq!(real, 3);
        push_token(&mut ids, &mut real, 100);
        push_token(&mut ids, &mut real, 101);
        // window full: slides left
        assert_eq!(ids, vec![98, 99, 100, 101]);
        assert_eq!(real, 4);
    }

    #[test]
    fn non_ascii_lossy_decode() {
        let ids = encode_fixed("héllo", 16);
        assert_eq!(decode(&ids), "héllo");
    }
}
