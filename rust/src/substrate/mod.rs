//! Substrates the paper's system depends on, built from scratch (offline
//! image has no tokio/serde/etc. — see DESIGN.md §2):
//!
//! - [`executor`] — thread-pool + channel event loop (async runtime stand-in)
//! - [`netsim`]   — network link models (latency/jitter/bandwidth) for the
//!   simulated archipelago
//! - [`tokenizer`] — byte-level tokenizer matching the python side
//! - [`vectorstore`] — cosine-similarity vector index (RAG / data locality)
//! - [`trace`]    — workload generators for every experiment

pub mod executor;
pub mod netsim;
pub mod tokenizer;
pub mod trace;
pub mod vectorstore;
