//! Vector store substrate: the RAG index that makes data-locality routing
//! (§III.F) meaningful.
//!
//! A flat cosine-similarity index over unit-norm embeddings (the Embedder
//! artifact produces unit vectors, so dot product == cosine). Supports
//! persistence to a simple JSON file so "the firm server hosts the case-law
//! index" is an actual on-disk artifact an island owns.
//!
//! Brute-force scan is exact and, at the corpus sizes of the experiments
//! (10–10k docs), faster than any ANN structure would be — noted in
//! EXPERIMENTS.md §Perf.

use std::path::Path;

use crate::config::json::Json;

/// One indexed document.
#[derive(Clone, Debug, PartialEq)]
pub struct Doc {
    pub id: u64,
    pub text: String,
    pub embedding: Vec<f32>,
}

/// Flat cosine index.
#[derive(Clone, Debug, Default)]
pub struct VectorStore {
    dim: usize,
    docs: Vec<Doc>,
}

/// A search hit.
#[derive(Clone, Debug, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
}

impl VectorStore {
    pub fn new(dim: usize) -> VectorStore {
        VectorStore { dim, docs: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Insert a document; the embedding must match the index dimension.
    pub fn insert(&mut self, id: u64, text: &str, embedding: Vec<f32>) -> anyhow::Result<()> {
        anyhow::ensure!(embedding.len() == self.dim, "embedding dim {} != index dim {}", embedding.len(), self.dim);
        self.docs.push(Doc { id, text: text.to_string(), embedding });
        Ok(())
    }

    pub fn get(&self, id: u64) -> Option<&Doc> {
        self.docs.iter().find(|d| d.id == id)
    }

    /// Exact top-k by cosine (dot product over unit vectors).
    pub fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut hits: Vec<Hit> = self
            .docs
            .iter()
            .map(|d| Hit { id: d.id, score: dot(query, &d.embedding) })
            .collect();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
        hits.truncate(k);
        hits
    }

    /// Approximate on-disk footprint in KB (E11 uses this to price moving
    /// the dataset instead of the query).
    pub fn payload_kb(&self) -> f64 {
        let bytes: usize = self.docs.iter().map(|d| d.text.len() + d.embedding.len() * 4 + 16).sum();
        bytes as f64 / 1024.0
    }

    // ---------------- persistence ----------------
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::num(self.dim as f64)),
            (
                "docs",
                Json::Arr(
                    self.docs
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("id", Json::num(d.id as f64)),
                                ("text", Json::str(&d.text)),
                                ("emb", Json::Arr(d.embedding.iter().map(|&x| Json::num(x as f64)).collect())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<VectorStore> {
        let dim = v.get("dim").as_i64().ok_or_else(|| anyhow::anyhow!("missing dim"))? as usize;
        let mut store = VectorStore::new(dim);
        for d in v.get("docs").as_arr().unwrap_or(&[]) {
            let id = d.get("id").as_i64().unwrap_or(0) as u64;
            let text = d.get("text").as_str().unwrap_or("").to_string();
            let emb: Vec<f32> = d
                .get("emb")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64().map(|f| f as f32))
                .collect();
            store.insert(id, &text, emb)?;
        }
        Ok(store)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<VectorStore> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        VectorStore::from_json(&v)
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(v: Vec<f32>) -> Vec<f32> {
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        v.into_iter().map(|x| x / n).collect()
    }

    #[test]
    fn search_ranks_by_cosine() {
        let mut s = VectorStore::new(2);
        s.insert(1, "east", unit(vec![1.0, 0.0])).unwrap();
        s.insert(2, "north", unit(vec![0.0, 1.0])).unwrap();
        s.insert(3, "northeast", unit(vec![1.0, 1.0])).unwrap();
        let hits = s.search(&unit(vec![1.0, 0.1]), 2);
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits[1].id, 3);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let mut s = VectorStore::new(4);
        assert!(s.insert(1, "bad", vec![1.0, 0.0]).is_err());
    }

    #[test]
    fn topk_truncates_and_handles_small_stores() {
        let mut s = VectorStore::new(2);
        s.insert(1, "a", unit(vec![1.0, 0.0])).unwrap();
        assert_eq!(s.search(&[1.0, 0.0], 10).len(), 1);
        let empty = VectorStore::new(2);
        assert!(empty.search(&[1.0, 0.0], 3).is_empty());
    }

    #[test]
    fn json_round_trip() {
        let mut s = VectorStore::new(3);
        s.insert(7, "case law precedent", unit(vec![1.0, 2.0, 3.0])).unwrap();
        s.insert(8, "contract dispute", unit(vec![-1.0, 0.5, 0.0])).unwrap();
        let s2 = VectorStore::from_json(&s.to_json()).unwrap();
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.get(7).unwrap().text, "case law precedent");
        let (a, b) = (&s.get(8).unwrap().embedding, &s2.get(8).unwrap().embedding);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn save_load_file() {
        let mut s = VectorStore::new(2);
        s.insert(1, "doc", unit(vec![0.6, 0.8])).unwrap();
        let path = std::env::temp_dir().join("islandrun_vs_test.json");
        s.save(&path).unwrap();
        let s2 = VectorStore::load(&path).unwrap();
        assert_eq!(s2.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn payload_grows_with_docs() {
        let mut s = VectorStore::new(8);
        let base = s.payload_kb();
        for i in 0..100 {
            s.insert(i, "some document text here", vec![0.0; 8]).unwrap();
        }
        assert!(s.payload_kb() > base + 4.0);
    }
}
