//! Workload trace generators for every experiment (DESIGN.md §4).
//!
//! Each generated request carries a *ground-truth* sensitivity class (what a
//! perfect MIST would assign) so experiments can count true privacy
//! violations independently of classifier accuracy. Mixes:
//!
//! - §XI "Workload Characteristics": 40% high / 35% moderate / 25% low.
//! - §I.A Scenario 4 healthcare day: 1000 queries = 200 high (symptom
//!   analysis), 500 moderate (literature search), 300 low (health tips).
//! - priority tiers for E5 (primary/secondary/burstable).

use crate::types::{PriorityTier, Request};
use crate::util::Rng;

/// Ground-truth sensitivity class of a generated request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SensClass {
    /// s_r ≈ 0.2–0.3: general knowledge, cloud acceptable.
    Low,
    /// s_r ≈ 0.5: internal, private edge tolerable.
    Moderate,
    /// s_r ≈ 0.9–1.0: PII/PHI, personal islands only.
    High,
}

impl SensClass {
    /// Ground-truth sensitivity score the class maps to.
    pub fn score(self) -> f64 {
        match self {
            SensClass::Low => 0.3,
            SensClass::Moderate => 0.5,
            SensClass::High => 0.9,
        }
    }
}

/// A trace item: the request plus its ground truth.
#[derive(Clone, Debug)]
pub struct TraceItem {
    pub request: Request,
    pub truth: SensClass,
}

const PEOPLE: &[&str] = &["john doe", "jane smith", "arun patel", "maria garcia", "wei chen", "fatima khan"];
const DISEASES: &[&str] = &["diabetes", "hypertension", "asthma", "migraine", "anemia"];
const DRUGS: &[&str] = &["metformin", "lisinopril", "insulin", "atorvastatin"];
const TOPICS: &[&str] = &["kubernetes", "rust", "jax", "raft", "vector databases", "tls"];
const TEAMS: &[&str] = &["platform", "billing", "search", "mobile", "infra"];

fn low_prompt(rng: &mut Rng) -> String {
    let forms = [
        format!("what are common complications of {}", rng.pick(DISEASES)),
        format!("explain how {} works in simple terms", rng.pick(TOPICS)),
        "tips for staying healthy while traveling".to_string(),
        "how do i sort a list in python".to_string(),
        format!("summarize the history of {}", rng.pick(TOPICS)),
    ];
    forms[rng.below(forms.len())].clone()
}

fn moderate_prompt(rng: &mut Rng) -> String {
    let forms = [
        format!("summarize the notes from yesterdays {} sync", rng.pick(TEAMS)),
        format!("what did we decide about the {} migration", rng.pick(TOPICS)),
        format!("search medical literature for {} treatment guidelines", rng.pick(DISEASES)),
        format!("draft the agenda for the {} team standup", rng.pick(TEAMS)),
        format!("estimate effort for the {} upgrade next sprint", rng.pick(TOPICS)),
    ];
    forms[rng.below(forms.len())].clone()
}

fn high_prompt(rng: &mut Rng) -> String {
    let person = rng.pick(PEOPLE);
    let forms = [
        format!(
            "patient {} ssn {}-{}-{} diagnosed with {}",
            person,
            rng.range_u64(100, 999),
            rng.range_u64(10, 99),
            rng.range_u64(1000, 9999),
            rng.pick(DISEASES)
        ),
        format!("analyze treatment options for patient {} with {} and elevated hba1c", person, rng.pick(DISEASES)),
        format!("patient mrn {} prescribed {} {} mg daily", rng.range_u64(10000, 99999), rng.pick(DRUGS), rng.range_u64(5, 500)),
        format!(
            "wire transfer from account {} routing {} for {}",
            rng.range_u64(1_000_000_000, 9_999_999_999),
            rng.range_u64(100_000_000, 999_999_999),
            person
        ),
        format!(
            "charge card 4111-1111-1111-{} for {} account",
            rng.range_u64(1000, 9999),
            person
        ),
    ];
    forms[rng.below(forms.len())].clone()
}

/// Generate a prompt of the given ground-truth class.
pub fn prompt_for(class: SensClass, rng: &mut Rng) -> String {
    match class {
        SensClass::Low => low_prompt(rng),
        SensClass::Moderate => moderate_prompt(rng),
        SensClass::High => high_prompt(rng),
    }
}

/// Priority assignment used by the experiments: high-sensitivity work is
/// primary, moderate secondary, low burstable (matches the paper's examples:
/// patient diagnosis=primary, code review=secondary, general chat=burstable).
pub fn priority_for(class: SensClass) -> PriorityTier {
    match class {
        SensClass::High => PriorityTier::Primary,
        SensClass::Moderate => PriorityTier::Secondary,
        SensClass::Low => PriorityTier::Burstable,
    }
}

/// §XI workload mix: 40% high / 35% moderate / 25% low.
pub fn paper_mix(n: usize, seed: u64) -> Vec<TraceItem> {
    weighted_mix(n, seed, 0.40, 0.35)
}

/// Scenario 4 healthcare day: 20% high / 50% moderate / 30% low (200/500/300
/// out of 1000).
pub fn healthcare_day(n: usize, seed: u64) -> Vec<TraceItem> {
    weighted_mix(n, seed, 0.20, 0.50)
}

/// Arbitrary mix: `p_high` fraction high, `p_mod` moderate, rest low.
pub fn weighted_mix(n: usize, seed: u64, p_high: f64, p_mod: f64) -> Vec<TraceItem> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // deterministic stratified assignment keeps exact proportions
        let u = (i as f64 + 0.5) / n as f64;
        let class = if u < p_high {
            SensClass::High
        } else if u < p_high + p_mod {
            SensClass::Moderate
        } else {
            SensClass::Low
        };
        let request = Request::new(i as u64, &prompt_for(class, &mut rng))
            .with_user(&format!("user-{}", rng.below(4)))
            .with_priority(priority_for(class));
        out.push(TraceItem { request, truth: class });
    }
    // shuffle arrival order, deterministic in the seed
    let mut order_rng = Rng::new(seed ^ 0xD1CE);
    order_rng.shuffle(&mut out);
    out
}

/// RAG trace: every request needs the named dataset (E11, legal scenario).
pub fn rag_trace(n: usize, dataset: &str, seed: u64) -> Vec<TraceItem> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let prompt = format!(
                "find precedent about {} in our repository",
                rng.pick(&["shipping contracts", "data privacy", "non-compete clauses", "patent claims", "negligence"])
            );
            let request = Request::new(i as u64, &prompt).with_dataset(dataset).with_priority(PriorityTier::Secondary);
            TraceItem { request, truth: SensClass::High } // privileged by policy
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class_counts(items: &[TraceItem]) -> (usize, usize, usize) {
        let h = items.iter().filter(|i| i.truth == SensClass::High).count();
        let m = items.iter().filter(|i| i.truth == SensClass::Moderate).count();
        let l = items.iter().filter(|i| i.truth == SensClass::Low).count();
        (h, m, l)
    }

    #[test]
    fn paper_mix_proportions_exact() {
        let items = paper_mix(1000, 1);
        let (h, m, l) = class_counts(&items);
        assert_eq!((h, m, l), (400, 350, 250));
    }

    #[test]
    fn healthcare_day_matches_scenario4() {
        let items = healthcare_day(1000, 2);
        let (h, m, l) = class_counts(&items);
        assert_eq!((h, m, l), (200, 500, 300));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = paper_mix(50, 7);
        let b = paper_mix(50, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.truth, y.truth);
        }
        let c = paper_mix(50, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.request.prompt != y.request.prompt));
    }

    #[test]
    fn high_prompts_contain_identifiers() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let p = high_prompt(&mut rng);
            assert!(
                p.contains("patient")
                    || p.contains("ssn")
                    || p.contains("wire transfer")
                    || p.contains("card")
                    || p.contains("mrn"),
                "{p}"
            );
        }
    }

    #[test]
    fn low_prompts_contain_no_people() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let p = low_prompt(&mut rng);
            for person in PEOPLE {
                assert!(!p.contains(person), "{p}");
            }
        }
    }

    #[test]
    fn priorities_follow_sensitivity() {
        assert_eq!(priority_for(SensClass::High), PriorityTier::Primary);
        assert_eq!(priority_for(SensClass::Moderate), PriorityTier::Secondary);
        assert_eq!(priority_for(SensClass::Low), PriorityTier::Burstable);
    }

    #[test]
    fn rag_trace_requires_dataset() {
        let items = rag_trace(10, "case_law", 5);
        assert!(items.iter().all(|i| i.request.required_dataset.as_deref() == Some("case_law")));
    }

    #[test]
    fn request_ids_unique() {
        let items = paper_mix(200, 9);
        let mut ids: Vec<u64> = items.iter().map(|i| i.request.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 200);
    }
}
