//! Network simulator: the "water" between islands.
//!
//! The paper's testbed spans loopback, LAN, WAN, Bluetooth mesh and cellular
//! links (scenarios §I.A). This module models per-link round-trip latency
//! (base + lognormal-ish jitter), bandwidth (for payload transfer time) and
//! loss. Calibrated so end-to-end island latencies land in the paper's §XI.B
//! bands: local 50–500 ms, private edge 100–1000 ms, cloud 200–2000 ms
//! (validated by eval E4 and `tests/integration_e2e.rs`).
//!
//! Simulated time: the eval harness runs in *virtual* time (no sleeping) so
//! 10k-request experiments finish in seconds; the serving path can optionally
//! sleep for real-time demos (`Delay::RealTime`).

use crate::types::LinkKind;
use crate::util::Rng;

/// Link model parameters.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// One-way base propagation+processing delay (ms).
    pub base_ms: f64,
    /// Jitter standard deviation (ms), sampled ~ |N(0, jitter)|.
    pub jitter_ms: f64,
    /// Usable bandwidth in KB/ms (== MB/s) for payload transfer.
    pub bandwidth_kb_per_ms: f64,
    /// Packet-level failure probability per round trip.
    pub loss: f64,
}

impl LinkModel {
    /// Paper-calibrated defaults per link class.
    pub fn for_kind(kind: LinkKind) -> LinkModel {
        match kind {
            LinkKind::Loopback => LinkModel { base_ms: 0.05, jitter_ms: 0.02, bandwidth_kb_per_ms: 10_000.0, loss: 0.0 },
            LinkKind::Lan => LinkModel { base_ms: 2.0, jitter_ms: 1.0, bandwidth_kb_per_ms: 100.0, loss: 0.0005 },
            LinkKind::Wan => LinkModel { base_ms: 40.0, jitter_ms: 15.0, bandwidth_kb_per_ms: 12.0, loss: 0.002 },
            LinkKind::Bluetooth => LinkModel { base_ms: 25.0, jitter_ms: 10.0, bandwidth_kb_per_ms: 0.25, loss: 0.01 },
            LinkKind::Cellular => LinkModel { base_ms: 80.0, jitter_ms: 40.0, bandwidth_kb_per_ms: 3.0, loss: 0.01 },
        }
    }
}

/// Outcome of one simulated transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferOutcome {
    /// Delivered after the given round-trip time (ms).
    Delivered { rtt_ms: f64 },
    /// Lost (caller retries or fails the request).
    Lost,
}

/// Network simulator over a set of link models.
#[derive(Clone, Debug)]
pub struct NetSim {
    rng: Rng,
}

impl NetSim {
    pub fn new(seed: u64) -> NetSim {
        NetSim { rng: Rng::new(seed) }
    }

    /// Simulate one round trip carrying `payload_kb` each way.
    pub fn round_trip(&mut self, kind: LinkKind, payload_kb: f64) -> TransferOutcome {
        let m = LinkModel::for_kind(kind);
        if self.rng.chance(m.loss) {
            return TransferOutcome::Lost;
        }
        let jitter = self.rng.normal().abs() * m.jitter_ms;
        let transfer = 2.0 * payload_kb / m.bandwidth_kb_per_ms;
        TransferOutcome::Delivered { rtt_ms: 2.0 * m.base_ms + jitter + transfer }
    }

    /// Round trip with up to `retries` retries on loss; returns total time
    /// including failed attempts, or None if every attempt was lost.
    pub fn round_trip_retry(&mut self, kind: LinkKind, payload_kb: f64, retries: usize) -> Option<f64> {
        let mut total = 0.0;
        for attempt in 0..=retries {
            match self.round_trip(kind, payload_kb) {
                TransferOutcome::Delivered { rtt_ms } => return Some(total + rtt_ms),
                TransferOutcome::Lost => {
                    // timeout charge for the lost attempt + backoff
                    let m = LinkModel::for_kind(kind);
                    total += 4.0 * m.base_ms + (attempt as f64) * 10.0;
                }
            }
        }
        None
    }

    /// Time (ms) to move a one-way bulk payload — used by the data-locality
    /// experiment (E11) to price "data to compute" uploads.
    pub fn bulk_transfer_ms(&mut self, kind: LinkKind, payload_kb: f64) -> f64 {
        let m = LinkModel::for_kind(kind);
        let jitter = self.rng.normal().abs() * m.jitter_ms;
        m.base_ms + jitter + payload_kb / m.bandwidth_kb_per_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_rtt(kind: LinkKind, payload_kb: f64) -> f64 {
        let mut sim = NetSim::new(1);
        let mut total = 0.0;
        let mut n = 0;
        for _ in 0..2000 {
            if let TransferOutcome::Delivered { rtt_ms } = sim.round_trip(kind, payload_kb) {
                total += rtt_ms;
                n += 1;
            }
        }
        total / n as f64
    }

    #[test]
    fn link_ordering_matches_physics() {
        let lo = mean_rtt(LinkKind::Loopback, 1.0);
        let lan = mean_rtt(LinkKind::Lan, 1.0);
        let wan = mean_rtt(LinkKind::Wan, 1.0);
        let cell = mean_rtt(LinkKind::Cellular, 1.0);
        assert!(lo < lan && lan < wan && wan < cell, "{lo} {lan} {wan} {cell}");
    }

    #[test]
    fn wan_rtt_in_paper_band() {
        // §XI.B cloud latency includes 2x WAN base (~80ms) + jitter; the
        // network share should sit in the tens-to-hundreds of ms.
        let wan = mean_rtt(LinkKind::Wan, 4.0);
        assert!(wan > 60.0 && wan < 250.0, "wan={wan}");
    }

    #[test]
    fn payload_size_increases_latency() {
        let small = mean_rtt(LinkKind::Bluetooth, 1.0);
        let big = mean_rtt(LinkKind::Bluetooth, 50.0);
        assert!(big > small + 100.0, "bt small={small} big={big}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = NetSim::new(9);
        let mut b = NetSim::new(9);
        for _ in 0..100 {
            assert_eq!(a.round_trip(LinkKind::Wan, 2.0), b.round_trip(LinkKind::Wan, 2.0));
        }
    }

    #[test]
    fn retry_recovers_from_loss() {
        let mut sim = NetSim::new(3);
        let mut delivered = 0;
        for _ in 0..500 {
            if sim.round_trip_retry(LinkKind::Bluetooth, 1.0, 3).is_some() {
                delivered += 1;
            }
        }
        // loss=1%, 4 attempts -> essentially always delivered
        assert!(delivered >= 499, "delivered={delivered}");
    }

    #[test]
    fn bulk_transfer_scales_linearly() {
        let mut sim = NetSim::new(5);
        let t1: f64 = (0..200).map(|_| sim.bulk_transfer_ms(LinkKind::Wan, 100.0)).sum::<f64>() / 200.0;
        let t2: f64 = (0..200).map(|_| sim.bulk_transfer_ms(LinkKind::Wan, 10_000.0)).sum::<f64>() / 200.0;
        let ratio = (t2 - 40.0) / (t1 - 40.0); // subtract base (jitter remains)
        assert!(ratio > 25.0, "ratio={ratio}");
    }

    #[test]
    fn loopback_never_loses() {
        let mut sim = NetSim::new(7);
        for _ in 0..5000 {
            assert!(matches!(sim.round_trip(LinkKind::Loopback, 1.0), TransferOutcome::Delivered { .. }));
        }
    }
}
