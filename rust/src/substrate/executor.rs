//! Thread-pool executor: the event-loop substrate for the coordinator.
//!
//! Stand-in for an async runtime (tokio is unavailable in this offline
//! build — DESIGN.md §2). Provides:
//!   - a fixed worker pool executing boxed jobs,
//!   - `scope`-free parallel map for the eval harness,
//!   - graceful shutdown draining the queue.
//!
//! The request path uses it to run island executions concurrently while the
//! WAVES router stays single-threaded (the paper's WAVES is a centralized
//! client-side decision point, §XII "Single-Point-of-Failure in WAVES").

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::LockExt;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct Pool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `n` worker threads (min 1).
    pub fn new(n: usize) -> Pool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("islandrun-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock_clean().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    // islandlint: allow(serving-path-panic) -- pool construction is boot-time: if the OS
                    // refuses to spawn worker threads the process cannot serve at all, so failing fast
                    // here beats limping along with a partial pool.
                    .expect("spawn worker")
            })
            .collect();
        Pool { tx, workers }
    }

    /// Submit a fire-and-forget job. A send only fails when every worker has
    /// died (all receiver clones dropped); the job is dropped rather than
    /// panicking the submitter.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let _ = self.tx.send(Msg::Run(Box::new(f)));
    }

    /// Run `f` over every item, in parallel, preserving the order of
    /// results. Items whose worker died mid-job are omitted (the returned
    /// vector can be shorter than the input under worker panics).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (idx, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.spawn(move || {
                let r = f(item);
                let _ = tx.send((idx, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut received = 0;
        while received < n {
            match rx.recv() {
                Ok((idx, r)) => {
                    if let Some(slot) = slots.get_mut(idx) {
                        *slot = Some(r);
                    }
                    received += 1;
                }
                // every sender dropped without replying: a worker died
                // mid-job; return what completed instead of hanging
                Err(_) => break,
            }
        }
        slots.into_iter().flatten().collect()
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot future-like cell: spawn work, await the result later.
pub struct Promise<T> {
    rx: mpsc::Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    /// Start `f` on the pool and return a promise for its result.
    pub fn spawn<F: FnOnce() -> T + Send + 'static>(pool: &Pool, f: F) -> Promise<T> {
        let (tx, rx) = mpsc::channel();
        pool.spawn(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Block until the result is ready. `None` when the job was lost: the
    /// pool shut down before running it, or the job itself panicked.
    pub fn wait(self) -> Option<T> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll.
    pub fn poll(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_all_run() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_empty() {
        let pool = Pool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn promise_wait_and_poll() {
        let pool = Pool::new(1);
        let p = Promise::spawn(&pool, || 7u32);
        assert_eq!(p.wait(), Some(7));
        let p2 = Promise::spawn(&pool, || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            1u32
        });
        // may or may not be ready instantly; eventually resolves
        let mut got = p2.poll();
        for _ in 0..100 {
            if got.is_some() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
            got = p2.poll();
        }
        assert_eq!(got, Some(1));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = Pool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang; queued jobs may or may not run
    }

    #[test]
    fn pool_min_one_worker() {
        let pool = Pool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
