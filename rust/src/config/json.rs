//! Minimal JSON parser/serializer (offline stand-in for serde_json).
//!
//! Handles the full JSON grammar minus exotic number forms; good enough for
//! `artifacts/meta.json`, deployment configs and eval outputs. Numbers are
//! f64 (like JavaScript); integers round-trip exactly up to 2^53.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------- accessors ----------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index lookup; `Json::Null` if out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // ---------------- constructors ----------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---------------- parse ----------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------- serialize ----------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&(*n as i64).to_string());
                } else {
                    out.push_str(&n.to_string());
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(1).get("b").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(2), &Json::Null);
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        // raw utf-8 passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_round_trip_exactly() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert!(v.to_string().starts_with("9007199254740992"));
        assert_eq!(Json::Num(42.0).to_string(), "42");
    }

    #[test]
    fn string_escaping_round_trip() {
        let s = Json::Str("line1\nline2\t\"quoted\" \\ slash \u{1}".into());
        let back = Json::parse(&s.to_string()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_reports_offset() {
        let e = Json::parse("[1, @]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn parses_real_meta_json_shape() {
        let text = r#"{"vocab":256,"lm_loss_curve":[[0,5.5],[19,3.8]],
                       "golden":[{"text":"x","feat_nonzero_idx":[1,2]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("vocab").as_i64(), Some(256));
        assert_eq!(v.get("lm_loss_curve").idx(1).idx(1).as_f64(), Some(3.8));
        assert_eq!(v.get("golden").idx(0).get("feat_nonzero_idx").idx(1).as_i64(), Some(2));
    }
}
