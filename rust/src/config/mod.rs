//! Configuration system: router weights, TIDE thresholds, deployment presets.
//!
//! Configs load from JSON files (own parser in [`json`]) or from the named
//! presets that reproduce the paper's deployment scenarios (§III.D,
//! Fig. 3). Every knob the paper calls "user-configurable" is here:
//! Eq. 1 weights, §IX.A buffer thresholds, §IX.C hysteresis bounds,
//! router mode (§VI.C scalarized vs constraint-based).

pub mod json;

use std::path::Path;

use crate::types::{Certification, CostModel, Island, IslandId, Jurisdiction, LinkKind, TrustTier};
use json::Json;

/// §IX.A user-configurable resource buffer presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferProfile {
    /// buffer = 30%: offload when local capacity < 70%.
    Conservative,
    /// buffer = 20%: offload when local capacity < 80%.
    Moderate,
    /// buffer = 10%: offload when local capacity < 90%.
    Aggressive,
}

impl BufferProfile {
    /// Remaining-capacity threshold below which WAVES prefers offloading.
    pub fn buffer(self) -> f64 {
        match self {
            BufferProfile::Conservative => 0.30,
            BufferProfile::Moderate => 0.20,
            BufferProfile::Aggressive => 0.10,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "conservative" => Some(Self::Conservative),
            "moderate" => Some(Self::Moderate),
            "aggressive" => Some(Self::Aggressive),
            _ => None,
        }
    }
}

/// §VI.C: scalarized (Eq. 1 weighted sum) vs constraint-based routing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterMode {
    /// Algorithm 1: filter by constraints, then argmin of Eq. 1.
    Scalarized,
    /// Hard constraints (privacy, capacity, budget) then argmin latency.
    ConstraintBased,
}

/// Eq. 1 user-preference weights (w1 cost, w2 latency, w3 1-privacy).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Weights {
    pub cost: f64,
    pub latency: f64,
    pub privacy: f64,
}

impl Default for Weights {
    fn default() -> Self {
        // Balanced default; experiments sweep these (E1/E2 notes).
        Weights { cost: 0.4, latency: 0.3, privacy: 0.3 }
    }
}

impl Weights {
    /// Normalize to sum 1 (keeps Eq. 1 scores comparable across configs).
    pub fn normalized(self) -> Weights {
        let s = self.cost + self.latency + self.privacy;
        if s <= 0.0 {
            return Weights::default();
        }
        Weights { cost: self.cost / s, latency: self.latency / s, privacy: self.privacy / s }
    }
}

/// Full router configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub weights: Weights,
    pub mode: RouterMode,
    pub buffer: BufferProfile,
    /// §IX.C hysteresis: fall back to cloud below this capacity...
    pub hysteresis_low: f64,
    /// ...and return to local only above this capacity.
    pub hysteresis_high: f64,
    /// Per-user request rate limit (requests per second; Attack 4).
    pub rate_limit_rps: f64,
    /// Per-user daily budget ceiling in dollars (cost agent).
    pub budget_ceiling: f64,
    /// §IX.B tier thresholds: secondary goes local only when R > this.
    pub secondary_local_threshold: f64,
    /// burstable goes local only when R > this.
    pub burstable_local_threshold: f64,
    /// TIDE sampling period in ms (paper: 1000 ms; sims use faster).
    pub tide_period_ms: u64,
    /// Heartbeat period for LIGHTHOUSE liveness.
    pub heartbeat_period_ms: u64,
    /// Heartbeats missed before an island is marked offline.
    pub heartbeat_miss_limit: u32,
    /// Failure-aware execution: how many times a request may be re-routed
    /// to the next Pareto candidate after its routed island dies between
    /// routing and execute. Past the budget the request is rejected
    /// (audited as exhausted-retries, never silently lost).
    pub failover_retry_budget: u32,
    /// TIDE degraded-island signal: consecutive zero-capacity samples (at
    /// heartbeat cadence) before an island is treated as offline by WAVES.
    pub degrade_zero_samples: u32,
    /// Bounded admission-queue capacity for the non-blocking `enqueue`
    /// path. A full queue sheds the incoming request fail-closed (audited,
    /// `rejected_queue_full` metric) — backpressure, not unbounded memory.
    pub queue_capacity: usize,
    /// Worker threads draining the admission queue
    /// (`Orchestrator::start_queue`).
    pub serve_workers: usize,
    /// Request-scoped tracing master switch. Off means every
    /// `TraceContext` is inert: no span recording, no ring, no ids.
    pub trace_enabled: bool,
    /// Head-sampling keep probability for ordinary served traces in [0, 1].
    /// Tail rules (non-served terminals, slowest decile) apply regardless.
    pub trace_head_rate: f64,
    /// Completed-trace ring capacity (oldest kept traces evicted first).
    pub trace_ring_capacity: usize,
    /// Artifacts directory with the AOT HLO files.
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            weights: Weights::default(),
            mode: RouterMode::Scalarized,
            buffer: BufferProfile::Moderate,
            // §IX.C: fall back to cloud when R < 70%, recover local when
            // R > 80% (10% dead zone prevents flapping).
            hysteresis_low: 0.70,
            hysteresis_high: 0.80,
            rate_limit_rps: 50.0,
            budget_ceiling: 10.0,
            secondary_local_threshold: 0.50,
            burstable_local_threshold: 0.80,
            tide_period_ms: 1000,
            heartbeat_period_ms: 500,
            heartbeat_miss_limit: 3,
            failover_retry_budget: 2,
            degrade_zero_samples: 8,
            queue_capacity: 1024,
            serve_workers: 4,
            trace_enabled: true,
            trace_head_rate: 1.0,
            trace_ring_capacity: 512,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl Config {
    /// Parse from a JSON object; missing fields keep defaults.
    pub fn from_json(v: &Json) -> Config {
        let mut c = Config::default();
        if let Some(w) = v.get("weights").as_obj() {
            c.weights = Weights {
                cost: w.get("cost").and_then(|x| x.as_f64()).unwrap_or(c.weights.cost),
                latency: w.get("latency").and_then(|x| x.as_f64()).unwrap_or(c.weights.latency),
                privacy: w.get("privacy").and_then(|x| x.as_f64()).unwrap_or(c.weights.privacy),
            };
        }
        if let Some(m) = v.get("mode").as_str() {
            c.mode = if m == "constraint" { RouterMode::ConstraintBased } else { RouterMode::Scalarized };
        }
        if let Some(b) = v.get("buffer").as_str() {
            if let Some(bp) = BufferProfile::parse(b) {
                c.buffer = bp;
            }
        }
        if let Some(x) = v.get("rate_limit_rps").as_f64() {
            c.rate_limit_rps = x;
        }
        if let Some(x) = v.get("budget_ceiling").as_f64() {
            c.budget_ceiling = x;
        }
        if let Some(x) = v.get("failover_retry_budget").as_f64() {
            c.failover_retry_budget = x.max(0.0) as u32;
        }
        if let Some(x) = v.get("degrade_zero_samples").as_f64() {
            c.degrade_zero_samples = x.max(1.0) as u32;
        }
        if let Some(x) = v.get("queue_capacity").as_f64() {
            c.queue_capacity = x.max(1.0) as usize;
        }
        if let Some(x) = v.get("serve_workers").as_f64() {
            c.serve_workers = x.max(1.0) as usize;
        }
        if let Some(x) = v.get("trace_enabled").as_bool() {
            c.trace_enabled = x;
        }
        if let Some(x) = v.get("trace_head_rate").as_f64() {
            c.trace_head_rate = x.clamp(0.0, 1.0);
        }
        if let Some(x) = v.get("trace_ring_capacity").as_f64() {
            c.trace_ring_capacity = x.max(1.0) as usize;
        }
        if let Some(x) = v.get("artifacts_dir").as_str() {
            c.artifacts_dir = x.to_string();
        }
        c
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Config::from_json(&v))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "weights",
                Json::obj(vec![
                    ("cost", Json::num(self.weights.cost)),
                    ("latency", Json::num(self.weights.latency)),
                    ("privacy", Json::num(self.weights.privacy)),
                ]),
            ),
            ("mode", Json::str(if self.mode == RouterMode::ConstraintBased { "constraint" } else { "scalarized" })),
            (
                "buffer",
                Json::str(match self.buffer {
                    BufferProfile::Conservative => "conservative",
                    BufferProfile::Moderate => "moderate",
                    BufferProfile::Aggressive => "aggressive",
                }),
            ),
            ("rate_limit_rps", Json::num(self.rate_limit_rps)),
            ("budget_ceiling", Json::num(self.budget_ceiling)),
            ("failover_retry_budget", Json::num(self.failover_retry_budget as f64)),
            ("degrade_zero_samples", Json::num(self.degrade_zero_samples as f64)),
            ("queue_capacity", Json::num(self.queue_capacity as f64)),
            ("serve_workers", Json::num(self.serve_workers as f64)),
            ("trace_enabled", Json::Bool(self.trace_enabled)),
            ("trace_head_rate", Json::num(self.trace_head_rate)),
            ("trace_ring_capacity", Json::num(self.trace_ring_capacity as f64)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Deployment presets (paper §III.D scenarios A/B/C + Fig. 3 topology)
// ---------------------------------------------------------------------------

fn island(
    id: u32,
    name: &str,
    tier: TrustTier,
    latency_ms: f64,
    cost: CostModel,
    privacy: f64,
    cert: Certification,
    jur: Jurisdiction,
    slots: Option<usize>,
    link: LinkKind,
) -> Island {
    Island {
        id: IslandId(id),
        name: name.to_string(),
        tier,
        latency_ms,
        cost,
        privacy,
        certification: cert,
        jurisdiction: jur,
        capacity_slots: slots,
        link,
        battery: None,
        datasets: Vec::new(),
        models: vec!["tinylm".to_string()],
    }
}

/// Fig. 3 / §XI Scenario 1 topology: personal island group + home NAS +
/// private edge + two cloud islands. This is the default mesh used by the
/// examples and most experiments.
pub fn preset_personal_group() -> Vec<Island> {
    use Certification::*;
    use Jurisdiction::*;
    use TrustTier::*;
    let mut islands = vec![
        island(0, "laptop", Personal, 5.0, CostModel::Free, 1.0, Iso27001, SameCountry, Some(4), LinkKind::Loopback),
        island(1, "mobile", Personal, 20.0, CostModel::Free, 1.0, Iso27001, SameCountry, Some(1), LinkKind::Lan),
        island(2, "smart-tv", Personal, 30.0, CostModel::Free, 1.0, SelfCertified, SameCountry, Some(1), LinkKind::Lan),
        island(3, "home-nas", Personal, 15.0, CostModel::Free, 1.0, Iso27001, SameCountry, Some(2), LinkKind::Lan),
        island(4, "private-edge", PrivateEdge, 60.0, CostModel::Fixed(0.002), 0.8, Soc2, SameCountry, Some(8), LinkKind::Wan),
        island(5, "cloud-llm", Cloud, 180.0, CostModel::PerRequest(0.02), 0.4, Soc2, Foreign, None, LinkKind::Wan),
        island(6, "cloud-serverless", Cloud, 220.0, CostModel::PerRequest(0.008), 0.3, SelfCertified, Foreign, None, LinkKind::Wan),
    ];
    islands[1].battery = Some(0.8);
    islands[0].datasets.push("codebase".to_string());
    islands[3].datasets.push("family_photos".to_string());
    islands
}

/// §III.D Scenario B: healthcare provider (HIPAA). Workstation + PHI edge +
/// cloud for non-PHI education content.
pub fn preset_healthcare() -> Vec<Island> {
    use Certification::*;
    use Jurisdiction::*;
    use TrustTier::*;
    let mut islands = vec![
        island(0, "clinic-workstation", Personal, 8.0, CostModel::Free, 1.0, Iso27001, SameCountry, Some(2), LinkKind::Loopback),
        island(1, "onprem-phi-server", PrivateEdge, 40.0, CostModel::Fixed(0.003), 0.8, Iso27001, SameCountry, Some(6), LinkKind::Lan),
        island(2, "cloud-gpt", Cloud, 200.0, CostModel::PerRequest(0.03), 0.4, Soc2, Foreign, None, LinkKind::Wan),
    ];
    islands[0].datasets.push("phi_db".to_string());
    islands[1].datasets.push("medical_literature".to_string());
    islands
}

/// §III.D Scenario C: legal firm with a 10TB case-law vector store on the
/// firm server; cloud excluded for case-related queries by policy.
pub fn preset_legal() -> Vec<Island> {
    use Certification::*;
    use Jurisdiction::*;
    use TrustTier::*;
    let mut islands = vec![
        island(0, "attorney-laptop", Personal, 5.0, CostModel::Free, 1.0, Iso27001, SameCountry, Some(2), LinkKind::Loopback),
        island(1, "firm-server", PrivateEdge, 35.0, CostModel::Fixed(0.001), 0.9, Iso27001, SameCountry, Some(12), LinkKind::Lan),
        island(2, "cloud-llm", Cloud, 190.0, CostModel::PerRequest(0.02), 0.4, Soc2, Foreign, None, LinkKind::Wan),
    ];
    islands[1].datasets.push("case_law".to_string());
    islands
}

/// Scenario 2 (hiking friends): two phones linked over Bluetooth, one with
/// low battery + good signal, the other the reverse.
pub fn preset_hiking_pair() -> Vec<Island> {
    use Certification::*;
    use Jurisdiction::*;
    use TrustTier::*;
    let mut islands = vec![
        island(0, "phone-a", Personal, 10.0, CostModel::Free, 1.0, SelfCertified, SameCountry, Some(1), LinkKind::Loopback),
        island(1, "phone-b", Personal, 45.0, CostModel::Free, 1.0, SelfCertified, SameCountry, Some(1), LinkKind::Bluetooth),
        island(2, "cloud-via-cellular", Cloud, 400.0, CostModel::PerRequest(0.02), 0.4, Soc2, Foreign, None, LinkKind::Cellular),
    ];
    islands[0].battery = Some(0.15); // friend A: low battery, strong signal
    islands[1].battery = Some(0.90); // friend B: high battery, weak signal
    islands
}

/// Look up a preset by name (CLI `--preset`).
pub fn preset(name: &str) -> Option<Vec<Island>> {
    match name {
        "personal" => Some(preset_personal_group()),
        "healthcare" => Some(preset_healthcare()),
        "legal" => Some(preset_legal()),
        "hiking" => Some(preset_hiking_pair()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_normalize() {
        let w = Weights { cost: 2.0, latency: 1.0, privacy: 1.0 }.normalized();
        assert!((w.cost - 0.5).abs() < 1e-12);
        assert!((w.cost + w.latency + w.privacy - 1.0).abs() < 1e-12);
        // degenerate weights fall back to defaults
        let d = Weights { cost: 0.0, latency: 0.0, privacy: 0.0 }.normalized();
        assert_eq!(d, Weights::default());
    }

    #[test]
    fn buffer_profiles_match_paper() {
        assert_eq!(BufferProfile::Conservative.buffer(), 0.30);
        assert_eq!(BufferProfile::Moderate.buffer(), 0.20);
        assert_eq!(BufferProfile::Aggressive.buffer(), 0.10);
        assert_eq!(BufferProfile::parse("aggressive"), Some(BufferProfile::Aggressive));
        assert_eq!(BufferProfile::parse("nope"), None);
    }

    #[test]
    fn config_json_round_trip() {
        let mut c = Config::default();
        c.weights = Weights { cost: 0.5, latency: 0.25, privacy: 0.25 };
        c.mode = RouterMode::ConstraintBased;
        c.rate_limit_rps = 7.5;
        c.queue_capacity = 64;
        c.serve_workers = 2;
        c.trace_enabled = false;
        c.trace_head_rate = 0.25;
        c.trace_ring_capacity = 128;
        let j = c.to_json();
        let c2 = Config::from_json(&j);
        assert_eq!(c2.weights, c.weights);
        assert_eq!(c2.mode, c.mode);
        assert_eq!(c2.rate_limit_rps, c.rate_limit_rps);
        assert_eq!(c2.queue_capacity, 64);
        assert_eq!(c2.serve_workers, 2);
        assert!(!c2.trace_enabled);
        assert_eq!(c2.trace_head_rate, 0.25);
        assert_eq!(c2.trace_ring_capacity, 128);
    }

    #[test]
    fn config_from_partial_json_keeps_defaults() {
        let v = Json::parse(r#"{"rate_limit_rps": 5}"#).unwrap();
        let c = Config::from_json(&v);
        assert_eq!(c.rate_limit_rps, 5.0);
        assert_eq!(c.weights, Weights::default());
    }

    #[test]
    fn presets_shape() {
        let p = preset_personal_group();
        assert_eq!(p.len(), 7);
        // tier-1 devices are all P=1.0, free, bounded
        for i in &p[..4] {
            assert_eq!(i.privacy, 1.0);
            assert_eq!(i.request_cost(100), 0.0);
            assert!(!i.unbounded());
        }
        // cloud islands are unbounded with lower privacy
        for i in &p[5..] {
            assert!(i.unbounded());
            assert!(i.privacy < 0.5);
        }
        assert!(preset("healthcare").unwrap().iter().any(|i| i.has_dataset("phi_db")));
        assert!(preset("legal").unwrap().iter().any(|i| i.has_dataset("case_law")));
        assert!(preset("nonexistent").is_none());
    }

    #[test]
    fn hiking_preset_battery_asymmetry() {
        let p = preset_hiking_pair();
        assert!(p[0].battery.unwrap() < 0.2);
        assert!(p[1].battery.unwrap() > 0.8);
    }

    #[test]
    fn unique_island_ids_in_presets() {
        for name in ["personal", "healthcare", "legal", "hiking"] {
            let p = preset(name).unwrap();
            let mut ids: Vec<u32> = p.iter().map(|i| i.id.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), p.len(), "duplicate ids in preset {name}");
        }
    }
}
