//! IslandRun leader binary: CLI entrypoint (see `islandrun help`).
fn main() {
    islandrun::cli::main();
}
