//! §VIII.C attack scenarios 1–5, scripted against the real components.
//!
//! | # | Attack                               | Expected mitigation          |
//! |---|--------------------------------------|------------------------------|
//! | 1 | routing manipulation (fake TIDE)     | hard privacy constraint      |
//! | 2 | island impersonation                 | attestation at registration  |
//! | 3 | placeholder frequency analysis       | per-session random ids       |
//! | 4 | DoS island flooding                  | rate limit + tiered routing  |
//! | 5 | LIGHTHOUSE byzantine coordinator     | cached list (full BFT = FW)  |

use crate::agents::lighthouse::registry::{RegisterResult, Token};
use crate::agents::lighthouse::Lighthouse;
use crate::agents::mist::sanitize::PlaceholderMap;
use crate::agents::mist::Mist;
use crate::agents::tide::hysteresis::Preference;
use crate::agents::waves::Waves;
use crate::config::{preset_personal_group, Config};
use crate::islands::Fleet;
use crate::server::{Backend, Orchestrator, SubmitRequest};
use crate::types::{IslandId, PriorityTier, Request};

/// Result of one attack drill.
#[derive(Clone, Debug)]
pub struct AttackOutcome {
    pub name: &'static str,
    pub mitigated: bool,
    pub details: String,
}

/// Attack 1: compromised TIDE reports false local exhaustion, hoping to
/// force a sensitive request onto the cloud.
pub fn attack1_routing_manipulation() -> AttackOutcome {
    let waves = Waves::new(Config::default());
    let states: Vec<_> = preset_personal_group()
        .into_iter()
        .map(|island| {
            let cap = if island.unbounded() { 1.0 } else { 0.0 }; // forged exhaustion
            crate::agents::waves::IslandState { island, capacity: cap, online: true, degraded: false }
        })
        .collect();
    let request = Request::new(1, "patient john doe ssn 123-45-6789").with_priority(PriorityTier::Primary);
    let decision = waves.route(&request, 0.9, &states, 0.0, Preference::Cloud, f64::INFINITY);
    let mitigated = match decision.target() {
        Some(id) => {
            let island = states.iter().find(|s| s.island.id == id).unwrap();
            island.island.privacy >= 0.9
        }
        None => true, // fail-closed rejection also preserves privacy
    };
    AttackOutcome {
        name: "A1 routing-manipulation",
        mitigated,
        details: format!("decision under forged exhaustion: {decision:?}"),
    }
}

/// Attack 2: adversary advertises a fake island claiming T=1.0 / P=1.0.
pub fn attack2_island_impersonation() -> AttackOutcome {
    let lighthouse = Lighthouse::new(0xA77E57, 500.0, 3);
    for island in preset_personal_group() {
        lighthouse.register_owned(island, 0.0);
    }
    let mut evil = preset_personal_group().remove(5); // a cloud island…
    evil.id = IslandId(99);
    evil.name = "free-gpu-totally-legit".to_string();
    evil.privacy = 1.0; // …claiming personal-tier privacy
    // attacker has no mesh secret; tries a guessed token
    let result = lighthouse.register(evil, Token(0x1337), 0.0);
    let mitigated = result == RegisterResult::RejectedBadAttestation
        && !lighthouse.islands().iter().any(|i| i.id == IslandId(99));
    AttackOutcome { name: "A2 island-impersonation", mitigated, details: format!("registration -> {result:?}") }
}

/// Attack 3: cloud provider correlates placeholders across sessions to
/// de-anonymize entities by frequency analysis.
pub fn attack3_placeholder_analysis() -> AttackOutcome {
    // The adversary observes the same entity sanitized in many sessions.
    // Mitigation: per-session random identifiers → cross-session join keys
    // don't exist. We measure: does the same entity map to the same
    // placeholder in more than a trivial fraction of session pairs?
    let entity_text = "john doe has diabetes";
    let n = 40;
    let mut ids: Vec<String> = Vec::new();
    for session in 0..n {
        let mut map = PlaceholderMap::new(0xC0FFEE ^ (session as u64 * 0x9E3779B9));
        let s = map.sanitize(entity_text, 0.4);
        ids.push(s.split_whitespace().next().unwrap_or("").to_string());
    }
    let mut collisions = 0;
    for i in 0..n as usize {
        for j in (i + 1)..n as usize {
            if ids[i] == ids[j] {
                collisions += 1;
            }
        }
    }
    let pairs = n as usize * (n as usize - 1) / 2;
    // With ids drawn from a ~10^6-value per-session space, the expected
    // cross-session collision rate is ≈ 10^-6.
    let rate = collisions as f64 / pairs as f64;
    AttackOutcome {
        name: "A3 placeholder-analysis",
        mitigated: rate < 0.02,
        details: format!("cross-session placeholder collision rate {:.4} ({collisions}/{pairs})", rate),
    }
}

/// Attack 4: flood SHORE with junk to exhaust local resources and push the
/// victim's sensitive work to the cloud (cost + privacy pressure).
pub fn attack4_island_flooding() -> AttackOutcome {
    let mut cfg = Config::default();
    cfg.rate_limit_rps = 5.0;
    let fleet = Fleet::new(preset_personal_group(), 3);
    let orch = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 9);
    let attacker = orch.open_session("mallory");
    let victim = orch.open_session("alice");

    let mut flood_admitted = 0;
    for _ in 0..200 {
        let flood = SubmitRequest::new("junk junk junk").priority(PriorityTier::Burstable);
        if orch.submit_request(attacker, flood).is_ok() {
            flood_admitted += 1;
        }
    }
    // victim's primary (sensitive) request must still run on a P=1.0 island
    let out = orch
        .submit_request(
            victim,
            SubmitRequest::new("patient john doe ssn 123-45-6789 needs dosage review").priority(PriorityTier::Primary),
        )
        .expect("victim admitted");
    let victim_private = match out.decision.target() {
        Some(id) => preset_personal_group().iter().find(|i| i.id == id).map(|i| i.privacy >= 0.9).unwrap_or(false),
        None => true,
    };
    let mitigated = flood_admitted <= 10 && victim_private;
    AttackOutcome {
        name: "A4 island-flooding",
        mitigated,
        details: format!("flood admitted {flood_admitted}/200; victim on private island: {victim_private}"),
    }
}

/// Attack 5: LIGHTHOUSE goes byzantine (crashes / lies); routing must
/// continue off the cached island list (full BFT is future work, §VIII.C).
pub fn attack5_lighthouse_byzantine() -> AttackOutcome {
    let lighthouse = Lighthouse::new(5, 500.0, 3);
    for island in preset_personal_group() {
        lighthouse.register_owned(island, 0.0);
    }
    let before = lighthouse.islands();
    lighthouse.kill();
    let cached = lighthouse.islands();
    let usable = !cached.is_empty() && cached.len() == before.len();
    // and routing still succeeds against the cached view
    let waves = Waves::new(Config::default());
    let states: Vec<_> = cached
        .iter()
        .map(|i| crate::agents::waves::IslandState { island: i.clone(), capacity: 1.0, online: true, degraded: false })
        .collect();
    let d = waves.route(&Request::new(1, "hello"), 0.2, &states, 1.0, Preference::Local, f64::INFINITY);
    let mitigated = usable && d.target().is_some();
    AttackOutcome {
        name: "A5 lighthouse-byzantine",
        mitigated,
        details: format!("cached islands {} / routing ok: {}", cached.len(), d.target().is_some()),
    }
}

/// Run the full §VIII.C drill.
pub fn run_all() -> Vec<AttackOutcome> {
    vec![
        attack1_routing_manipulation(),
        attack2_island_impersonation(),
        attack3_placeholder_analysis(),
        attack4_island_flooding(),
        attack5_lighthouse_byzantine(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_five_attacks_mitigated() {
        for outcome in run_all() {
            assert!(outcome.mitigated, "{}: {}", outcome.name, outcome.details);
        }
    }

    #[test]
    fn attack1_details_show_no_cloud_target() {
        let o = attack1_routing_manipulation();
        assert!(o.mitigated);
        assert!(!o.details.contains("island-5") && !o.details.contains("island-6"), "{}", o.details);
    }
}
