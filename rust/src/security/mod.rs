//! Security: §VIII threat-model attack simulations and their mitigations.
//!
//! Each attack from §VIII.C is scripted against the real components and
//! returns a verdict; E12 and `examples/attack_drill.rs` run the full drill.

pub mod attacks;

pub use attacks::{run_all, AttackOutcome};
