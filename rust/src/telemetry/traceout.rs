//! Exporters for completed traces: per-trace JSON, JSONL, and Chrome
//! `trace_event` format.
//!
//! The JSON shape is the contract shared by `GET /v1/traces/:id`, the
//! `islandrun trace --out` JSONL artifact, and the consistency stress: one
//! object per trace with the root span, flat child spans, and the terminal
//! outcome/reason. The Chrome form (`--chrome-out`) renders every span as a
//! complete `"ph": "X"` event — virtual-clock milliseconds scaled to the
//! microseconds `chrome://tracing` / Perfetto expect — with one timeline row
//! (`tid`) per trace so concurrent requests stack instead of overlapping.

use crate::config::json::Json;

use super::trace::{CompletedTrace, Span};

fn attrs_json(attrs: &[(&'static str, Json)]) -> Json {
    Json::obj(attrs.iter().map(|(k, v)| (*k, v.clone())).collect())
}

/// One span as JSON (ids in canonical hex, times in virtual-clock ms).
pub fn span_json(span: &Span) -> Json {
    Json::obj(vec![
        ("span_id", Json::str(&span.id.to_hex())),
        (
            "parent_span_id",
            match span.parent {
                Some(p) => Json::str(&p.to_hex()),
                None => Json::Null,
            },
        ),
        ("name", Json::str(span.name)),
        ("start_ms", Json::num(span.start_ms)),
        ("end_ms", Json::num(span.end_ms)),
        ("attrs", attrs_json(&span.attrs)),
    ])
}

/// One complete trace as JSON: the `GET /v1/traces/:id` response body and
/// one JSONL line.
pub fn trace_json(trace: &CompletedTrace) -> Json {
    Json::obj(vec![
        ("trace_id", Json::str(&trace.trace_id.to_hex())),
        ("user", Json::str(&trace.user)),
        ("outcome", Json::str(trace.outcome)),
        ("reason", Json::str(trace.reason)),
        ("duration_ms", Json::num(trace.duration_ms())),
        ("root", span_json(&trace.root)),
        ("spans", Json::Arr(trace.spans.iter().map(span_json).collect())),
    ])
}

/// All traces as JSONL, one object per line, oldest first.
pub fn to_jsonl(traces: &[CompletedTrace]) -> String {
    let mut out = String::new();
    for t in traces {
        out.push_str(&trace_json(t).to_string());
        out.push('\n');
    }
    out
}

fn chrome_event(trace: &CompletedTrace, span: &Span, tid: f64, is_root: bool) -> Json {
    let mut args = vec![("trace_id", Json::str(&trace.trace_id.to_hex()))];
    if is_root {
        args.push(("outcome", Json::str(trace.outcome)));
        args.push(("reason", Json::str(trace.reason)));
    }
    for (k, v) in &span.attrs {
        args.push((*k, v.clone()));
    }
    Json::obj(vec![
        ("name", Json::str(span.name)),
        ("cat", Json::str(trace.outcome)),
        ("ph", Json::str("X")),
        // virtual-clock ms -> trace_event microseconds
        ("ts", Json::num(span.start_ms * 1000.0)),
        ("dur", Json::num((span.end_ms - span.start_ms).max(0.0) * 1000.0)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(tid)),
        ("args", Json::obj(args)),
    ])
}

/// All traces as one Chrome `trace_event` document (the `"traceEvents"`
/// array form, loadable in `chrome://tracing` and Perfetto).
pub fn to_chrome_json(traces: &[CompletedTrace]) -> Json {
    let mut events = Vec::new();
    for (i, t) in traces.iter().enumerate() {
        let tid = (i + 1) as f64;
        events.push(chrome_event(t, &t.root, tid, true));
        for s in &t.spans {
            events.push(chrome_event(t, s, tid, false));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::trace::{TraceConfig, TraceSink};
    use super::*;

    fn sample_traces() -> Vec<CompletedTrace> {
        let sink = TraceSink::new(TraceConfig::default(), 11);
        let a = TraceSink::start(&sink, 0.0, None);
        a.set_user("alice");
        a.add_span("queue_wait", 0.0, 2.0, vec![("depth", Json::num(1.0))]);
        a.add_span("decode", 3.0, 9.0, vec![("chunks", Json::num(2.0))]);
        a.end_request_span(10.0, "served", "ok");
        let b = TraceSink::start(&sink, 4.0, None);
        b.end_request_span(6.0, "shed", "queue_full");
        sink.snapshot()
    }

    #[test]
    fn jsonl_round_trips_through_parser() {
        let traces = sample_traces();
        let jsonl = to_jsonl(&traces);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("user").as_str(), Some("alice"));
        assert_eq!(first.get("outcome").as_str(), Some("served"));
        assert_eq!(first.get("duration_ms").as_f64(), Some(10.0));
        assert_eq!(first.get("spans").as_arr().unwrap().len(), 2);
        let span = &first.get("spans").as_arr().unwrap()[0];
        assert_eq!(span.get("name").as_str(), Some("queue_wait"));
        assert_eq!(
            span.get("parent_span_id").as_str(),
            first.get("root").get("span_id").as_str(),
            "children hang off the root"
        );
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("reason").as_str(), Some("queue_full"));
    }

    #[test]
    fn chrome_events_scale_ms_to_micros() {
        let traces = sample_traces();
        let doc = Json::parse(&to_chrome_json(&traces).to_string()).unwrap();
        let events = doc.get("traceEvents").as_arr().unwrap();
        // 1 root + 2 children for the first trace, 1 root for the second
        assert_eq!(events.len(), 4);
        for ev in events {
            assert_eq!(ev.get("ph").as_str(), Some("X"));
            assert_eq!(ev.get("pid").as_f64(), Some(1.0));
        }
        let decode = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("decode"))
            .expect("decode span exported");
        assert_eq!(decode.get("ts").as_f64(), Some(3000.0));
        assert_eq!(decode.get("dur").as_f64(), Some(6000.0));
        assert_eq!(decode.get("args").get("chunks").as_f64(), Some(2.0));
        // traces get distinct timeline rows
        let tids: std::collections::BTreeSet<i64> =
            events.iter().filter_map(|e| e.get("tid").as_i64()).collect();
        assert_eq!(tids.len(), 2);
        // root events carry the terminal
        let root = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("request") && e.get("cat").as_str() == Some("shed"))
            .expect("shed root exported");
        assert_eq!(root.get("args").get("reason").as_str(), Some("queue_full"));
    }
}
