//! Metrics registry: counters, gauges and latency histograms.
//!
//! Owned by the rust coordinator (L3 owns "metrics" per the architecture);
//! every agent and island executor reports here. Thread-safe via a single
//! mutex — the hot path records a few counters per request, far from
//! contention at the request rates this testbed reaches (verified in the
//! §Perf pass).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::{Histogram, Table};

/// Central metrics registry.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter by `n`.
    pub fn count(&self, name: &str, n: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Set a gauge to an absolute value.
    pub fn gauge(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), v);
    }

    /// Record a histogram sample (e.g. latency in ms).
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().record(v);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Snapshot of a histogram by name.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.inner.lock().unwrap().histograms.get(name).cloned()
    }

    /// Render everything as a report table (used by `islandrun stats`).
    pub fn report(&self) -> Table {
        let g = self.inner.lock().unwrap();
        let mut t = Table::new("metrics", &["metric", "value"]);
        for (k, v) in &g.counters {
            t.row(&[k.clone(), v.to_string()]);
        }
        for (k, v) in &g.gauges {
            t.row(&[k.clone(), format!("{v:.3}")]);
        }
        for (k, h) in &g.histograms {
            t.row(&[k.clone(), h.summary()]);
        }
        t
    }

    /// Clear all metrics (between experiment repetitions).
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.histograms.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("requests", 1);
        m.count("requests", 2);
        assert_eq!(m.counter_value("requests"), 3);
        assert_eq!(m.counter_value("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.gauge("capacity", 0.7);
        m.gauge("capacity", 0.4);
        assert_eq!(m.gauge_value("capacity"), Some(0.4));
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for x in [10.0, 20.0, 30.0] {
            m.observe("latency_ms", x);
        }
        let h = m.histogram("latency_ms").unwrap();
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn report_and_reset() {
        let m = Metrics::new();
        m.count("a", 1);
        m.gauge("b", 2.0);
        m.observe("c", 3.0);
        let rendered = m.report().render();
        assert!(rendered.contains("| a"));
        assert!(rendered.contains("| b"));
        assert!(rendered.contains("| c"));
        m.reset();
        assert_eq!(m.counter_value("a"), 0);
        assert!(m.histogram("c").is_none());
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.count("n", 1);
                        m.observe("h", 1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter_value("n"), 4000);
        assert_eq!(m.histogram("h").unwrap().count(), 4000);
    }
}
