//! Metrics registry: typed counter/gauge/histogram families with label sets.
//!
//! Owned by the rust coordinator (L3 owns "metrics" per the architecture);
//! every agent and island executor reports here. The API has two tiers:
//!
//! * **Registered handles** (`Counter`, `Gauge`, `Hist` and their labeled
//!   `*Vec` families) — resolved once at registration time, each holding a
//!   cached `Arc` to its atomic cell. Bumping a handle is a single atomic
//!   op: no name lookup, no lock, no allocation on the serving hot path.
//!   [`crate::telemetry::ServingMetrics`] pre-registers every serving-path
//!   metric this way.
//! * **Legacy string-keyed calls** (`count`/`gauge`/`observe`) — get-or-
//!   register by name on every call. Kept for cold paths and as the
//!   baseline the throughput bench compares handle bumps against.
//!
//! Histograms are lock-free ([`AtomicHistogram`]): fixed log-scaled buckets
//! with atomic counters, so recording a latency sample never serializes
//! behind other threads. [`Metrics::render_prometheus`] (in
//! [`prometheus`]) exports everything in Prometheus text exposition format.

pub mod events;
pub mod hist;
pub mod prometheus;
pub mod serving;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

pub use events::{EventLog, RequestEvent};
pub use hist::AtomicHistogram;
pub use prometheus::lint_exposition;
pub use serving::ServingMetrics;

use crate::util::{AtomicF64, Histogram, Table};

/// A metric cell that can be zeroed in place (for `Metrics::reset`).
trait Cell: Default {
    fn zero(&self);
}

impl Cell for AtomicU64 {
    fn zero(&self) {
        self.store(0, Ordering::SeqCst);
    }
}

impl Cell for AtomicF64 {
    fn zero(&self) {
        self.store(0.0);
    }
}

impl Cell for AtomicHistogram {
    fn zero(&self) {
        self.reset();
    }
}

/// One metric family: a help string, an ordered label-key list, and one cell
/// per distinct label-value combination. The unlabeled case is a family with
/// an empty key list and a single child at the empty label vector.
pub(crate) struct Family<C> {
    pub(crate) help: String,
    pub(crate) labels: Vec<String>,
    pub(crate) children: RwLock<BTreeMap<Vec<String>, Arc<C>>>,
}

impl<C: Cell> Family<C> {
    fn new(help: &str, labels: &[&str]) -> Self {
        Family {
            help: help.to_string(),
            labels: labels.iter().map(|s| s.to_string()).collect(),
            children: RwLock::new(BTreeMap::new()),
        }
    }

    /// Get or create the child cell for a label-value combination.
    fn child(&self, values: &[&str]) -> Arc<C> {
        assert_eq!(
            values.len(),
            self.labels.len(),
            "label arity mismatch: family declares {:?}, got {} values",
            self.labels,
            values.len()
        );
        let key: Vec<String> = values.iter().map(|s| s.to_string()).collect();
        if let Some(c) = self.children.read().unwrap().get(&key) {
            return Arc::clone(c);
        }
        let mut w = self.children.write().unwrap();
        Arc::clone(w.entry(key).or_default())
    }

    /// Sorted (label values, cell) snapshot of all children.
    fn snapshot_children(&self) -> Vec<(Vec<String>, Arc<C>)> {
        self.children.read().unwrap().iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    }
}

/// Handle to one counter cell. Cloning is cheap (`Arc` bump); bumping is a
/// single atomic add with no registry access.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::SeqCst);
    }

    pub fn value(&self) -> u64 {
        self.cell.load(Ordering::SeqCst)
    }
}

/// Handle to one gauge cell (absolute-valued f64).
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicF64>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.cell.store(v);
    }

    /// Adjust the gauge by `delta` (atomic read-modify-write). For up/down
    /// counts maintained from multiple threads — where interleaved
    /// absolute `set`s could publish a stale value — deltas always
    /// converge to the true count.
    pub fn add(&self, delta: f64) {
        self.cell.fetch_add(delta);
    }

    pub fn value(&self) -> f64 {
        self.cell.load()
    }
}

/// Handle to one lock-free histogram cell.
#[derive(Clone)]
pub struct Hist {
    cell: Arc<AtomicHistogram>,
}

impl Hist {
    pub fn observe(&self, v: f64) {
        self.cell.record(v);
    }

    pub fn snapshot(&self) -> Histogram {
        self.cell.snapshot()
    }

    pub fn count(&self) -> u64 {
        self.cell.count()
    }
}

/// A labeled counter family; `with(values)` resolves (and caches in the
/// registry) the child for one label-value combination. Call `with` once at
/// setup and keep the returned [`Counter`] — that is the zero-lookup path.
#[derive(Clone)]
pub struct CounterVec {
    family: Arc<Family<AtomicU64>>,
}

impl CounterVec {
    pub fn with(&self, values: &[&str]) -> Counter {
        Counter { cell: self.family.child(values) }
    }
}

/// A labeled gauge family.
#[derive(Clone)]
pub struct GaugeVec {
    family: Arc<Family<AtomicF64>>,
}

impl GaugeVec {
    pub fn with(&self, values: &[&str]) -> Gauge {
        Gauge { cell: self.family.child(values) }
    }
}

/// A labeled histogram family.
#[derive(Clone)]
pub struct HistogramVec {
    family: Arc<Family<AtomicHistogram>>,
}

impl HistogramVec {
    pub fn with(&self, values: &[&str]) -> Hist {
        Hist { cell: self.family.child(values) }
    }
}

const UNREGISTERED_HELP: &str = "(registered on first use)";

/// Central metrics registry.
#[derive(Default)]
pub struct Metrics {
    pub(crate) counters: RwLock<BTreeMap<String, Arc<Family<AtomicU64>>>>,
    pub(crate) gauges: RwLock<BTreeMap<String, Arc<Family<AtomicF64>>>>,
    pub(crate) histograms: RwLock<BTreeMap<String, Arc<Family<AtomicHistogram>>>>,
}

fn family<C: Cell>(
    table: &RwLock<BTreeMap<String, Arc<Family<C>>>>,
    name: &str,
    help: &str,
    labels: &[&str],
) -> Arc<Family<C>> {
    if let Some(f) = table.read().unwrap().get(name) {
        assert!(
            f.labels.len() == labels.len() && f.labels.iter().zip(labels).all(|(a, b)| a.as_str() == *b),
            "metric {name:?} re-registered with different labels ({:?} vs {labels:?})",
            f.labels
        );
        return Arc::clone(f);
    }
    let mut w = table.write().unwrap();
    Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Family::new(help, labels))))
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- registration: resolve handles once, bump them lock-free after ----

    /// Register (or look up) an unlabeled counter and return its handle.
    pub fn register_counter(&self, name: &str, help: &str) -> Counter {
        Counter { cell: family(&self.counters, name, help, &[]).child(&[]) }
    }

    /// Register a labeled counter family.
    pub fn counter_vec(&self, name: &str, help: &str, labels: &[&str]) -> CounterVec {
        CounterVec { family: family(&self.counters, name, help, labels) }
    }

    /// Register (or look up) an unlabeled gauge and return its handle.
    pub fn register_gauge(&self, name: &str, help: &str) -> Gauge {
        Gauge { cell: family(&self.gauges, name, help, &[]).child(&[]) }
    }

    /// Register a labeled gauge family.
    pub fn gauge_vec(&self, name: &str, help: &str, labels: &[&str]) -> GaugeVec {
        GaugeVec { family: family(&self.gauges, name, help, labels) }
    }

    /// Register (or look up) an unlabeled histogram and return its handle.
    pub fn register_histogram(&self, name: &str, help: &str) -> Hist {
        Hist { cell: family(&self.histograms, name, help, &[]).child(&[]) }
    }

    /// Register a labeled histogram family.
    pub fn histogram_vec(&self, name: &str, help: &str, labels: &[&str]) -> HistogramVec {
        HistogramVec { family: family(&self.histograms, name, help, labels) }
    }

    // ---- legacy string-keyed API: get-or-register by name on every call ----

    /// Increment a named counter by `n`. String-keyed slow path: resolves the
    /// name through the registry on every call. Hot paths should hold a
    /// [`Counter`] handle instead (see [`ServingMetrics`]).
    pub fn count(&self, name: &str, n: u64) {
        family(&self.counters, name, UNREGISTERED_HELP, &[]).child(&[]).fetch_add(n, Ordering::SeqCst);
    }

    /// Set a gauge to an absolute value (string-keyed slow path).
    pub fn gauge(&self, name: &str, v: f64) {
        family(&self.gauges, name, UNREGISTERED_HELP, &[]).child(&[]).store(v);
    }

    /// Record a histogram sample (string-keyed slow path).
    pub fn observe(&self, name: &str, v: f64) {
        family(&self.histograms, name, UNREGISTERED_HELP, &[]).child(&[]).record(v);
    }

    // ---- queries ----

    /// Total over all children of a counter family (0 if absent). For a
    /// labeled family this is the sum across label combinations.
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.counters.read().unwrap().get(name) {
            Some(f) => f.children.read().unwrap().values().map(|c| c.load(Ordering::SeqCst)).sum(),
            None => 0,
        }
    }

    /// Value of an unlabeled gauge (None if never set).
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        let table = self.gauges.read().unwrap();
        let f = table.get(name)?;
        let children = f.children.read().unwrap();
        children.get(&Vec::new()).map(|g| g.load())
    }

    /// Snapshot of a histogram family by name, merged across all label
    /// combinations. None if the name was never registered.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        let table = self.histograms.read().unwrap();
        let f = table.get(name)?;
        let mut merged = Histogram::new();
        for child in f.children.read().unwrap().values() {
            merged.merge(&child.snapshot());
        }
        Some(merged)
    }

    /// Per-child values of a counter family: (label values, count), sorted.
    pub fn counter_children(&self, name: &str) -> Vec<(Vec<String>, u64)> {
        match self.counters.read().unwrap().get(name) {
            Some(f) => f.snapshot_children().into_iter().map(|(k, c)| (k, c.load(Ordering::SeqCst))).collect(),
            None => Vec::new(),
        }
    }

    /// Per-child snapshots of a histogram family: (label values, histogram).
    pub fn histogram_children(&self, name: &str) -> Vec<(Vec<String>, Histogram)> {
        match self.histograms.read().unwrap().get(name) {
            Some(f) => f.snapshot_children().into_iter().map(|(k, h)| (k, h.snapshot())).collect(),
            None => Vec::new(),
        }
    }

    /// `name{k="v",...}` display form for a child (plain name if unlabeled).
    fn series_name(name: &str, labels: &[String], values: &[String]) -> String {
        if values.is_empty() {
            return name.to_string();
        }
        let pairs: Vec<String> =
            labels.iter().zip(values).map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        format!("{name}{{{}}}", pairs.join(","))
    }

    /// Render everything as a report table (used by `islandrun stats`).
    pub fn report(&self) -> Table {
        let mut t = Table::new("metrics", &["metric", "value"]);
        for (name, f) in self.counters.read().unwrap().iter() {
            for (values, c) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), c.load(Ordering::SeqCst).to_string()]);
            }
        }
        for (name, f) in self.gauges.read().unwrap().iter() {
            for (values, g) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), format!("{:.3}", g.load())]);
            }
        }
        for (name, f) in self.histograms.read().unwrap().iter() {
            for (values, h) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), h.snapshot().summary()]);
            }
        }
        t
    }

    /// Clear all metrics (between experiment repetitions). Every cell —
    /// including histogram buckets — is zeroed in place rather than dropped,
    /// so handles resolved before the reset keep recording into live cells.
    pub fn reset(&self) {
        for f in self.counters.read().unwrap().values() {
            for c in f.children.read().unwrap().values() {
                c.zero();
            }
        }
        for f in self.gauges.read().unwrap().values() {
            for g in f.children.read().unwrap().values() {
                g.zero();
            }
        }
        for f in self.histograms.read().unwrap().values() {
            for h in f.children.read().unwrap().values() {
                h.zero();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("requests", 1);
        m.count("requests", 2);
        assert_eq!(m.counter_value("requests"), 3);
        assert_eq!(m.counter_value("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.gauge("capacity", 0.7);
        m.gauge("capacity", 0.4);
        assert_eq!(m.gauge_value("capacity"), Some(0.4));
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for x in [10.0, 20.0, 30.0] {
            m.observe("latency_ms", x);
        }
        let h = m.histogram("latency_ms").unwrap();
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn report_and_reset() {
        let m = Metrics::new();
        m.count("a", 1);
        m.gauge("b", 2.0);
        m.observe("c", 3.0);
        let rendered = m.report().render();
        assert!(rendered.contains("| a"));
        assert!(rendered.contains("| b"));
        assert!(rendered.contains("| c"));
        m.reset();
        assert_eq!(m.counter_value("a"), 0);
        // cells are zeroed in place, not dropped: the family survives empty
        assert_eq!(m.histogram("c").unwrap().count(), 0);
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.count("n", 1);
                        m.observe("h", 1.0);
                        m.gauge("g", 0.5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter_value("n"), 4000);
        assert_eq!(m.histogram("h").unwrap().count(), 4000);
        assert_eq!(m.gauge_value("g"), Some(0.5));
    }

    #[test]
    fn registered_handles_share_cells_with_legacy_names() {
        let m = Metrics::new();
        let c = m.register_counter("served", "requests served");
        c.inc();
        m.count("served", 2); // legacy path lands in the same cell
        assert_eq!(c.value(), 3);
        assert_eq!(m.counter_value("served"), 3);

        let g = m.register_gauge("depth", "queue depth");
        g.set(7.0);
        assert_eq!(m.gauge_value("depth"), Some(7.0));

        let h = m.register_histogram("wait", "queue wait");
        h.observe(4.0);
        m.observe("wait", 6.0);
        assert_eq!(m.histogram("wait").unwrap().count(), 2);
        assert_eq!(h.snapshot().count(), 2);
    }

    #[test]
    fn labeled_families_track_children_separately() {
        let m = Metrics::new();
        let v = m.counter_vec("resolved", "by outcome", &["outcome", "reason"]);
        let served = v.with(&["served", "ok"]);
        let shed = v.with(&["shed", "queue_full"]);
        served.add(5);
        shed.add(2);
        // counter_value sums across label combinations
        assert_eq!(m.counter_value("resolved"), 7);
        let children = m.counter_children("resolved");
        assert_eq!(children.len(), 2);
        assert_eq!(children[0], (vec!["served".to_string(), "ok".to_string()], 5));
        assert_eq!(children[1], (vec!["shed".to_string(), "queue_full".to_string()], 2));

        let hv = m.histogram_vec("lat", "latency by island", &["island"]);
        hv.with(&["island-0"]).observe(10.0);
        hv.with(&["island-1"]).observe(30.0);
        let merged = m.histogram("lat").unwrap();
        assert_eq!(merged.count(), 2);
        assert!((merged.mean() - 20.0).abs() < 1e-9);
        assert_eq!(m.histogram_children("lat").len(), 2);
    }

    #[test]
    #[should_panic(expected = "label arity mismatch")]
    fn wrong_label_arity_panics() {
        let m = Metrics::new();
        let v = m.counter_vec("x", "help", &["a", "b"]);
        v.with(&["only-one"]);
    }

    #[test]
    fn reset_keeps_handles_live() {
        let m = Metrics::new();
        let c = m.register_counter("c", "h");
        let h = m.register_histogram("hst", "h");
        c.inc();
        h.observe(1.0);
        m.reset();
        c.inc();
        h.observe(2.0);
        assert_eq!(m.counter_value("c"), 1);
        let s = m.histogram("hst").unwrap();
        assert_eq!(s.count(), 1);
        assert_eq!(s.max(), 2.0);
    }

    #[test]
    fn report_renders_labeled_series() {
        let m = Metrics::new();
        m.counter_vec("resolved", "h", &["outcome"]).with(&["served"]).inc();
        let rendered = m.report().render();
        assert!(rendered.contains("resolved{outcome=\"served\"}"), "{rendered}");
    }
}
