//! Metrics registry: typed counter/gauge/histogram families with label sets.
//!
//! Owned by the rust coordinator (L3 owns "metrics" per the architecture);
//! every agent and island executor reports here. The API has two tiers:
//!
//! * **Registered handles** (`Counter`, `Gauge`, `Hist` and their labeled
//!   `*Vec` families) — resolved once at registration time, each holding a
//!   cached `Arc` to its atomic cell. Bumping a handle is a single atomic
//!   op: no name lookup, no lock, no allocation on the serving hot path.
//!   [`crate::telemetry::ServingMetrics`] pre-registers every serving-path
//!   metric this way.
//! * **Legacy string-keyed calls** (`count`/`gauge`/`observe`) — get-or-
//!   register by name on every call. Kept for cold paths and as the
//!   baseline the throughput bench compares handle bumps against.
//!
//! Histograms are lock-free ([`AtomicHistogram`]): fixed log-scaled buckets
//! with atomic counters, so recording a latency sample never serializes
//! behind other threads. [`Metrics::render_prometheus`] (in
//! [`prometheus`]) exports everything in Prometheus text exposition format.

pub mod events;
pub mod hist;
pub mod prometheus;
pub mod serving;
pub mod trace;
pub mod traceout;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

pub use events::{EventLog, RequestEvent};
pub use hist::AtomicHistogram;
pub use prometheus::lint_exposition;
pub use serving::ServingMetrics;
pub use trace::{
    format_traceparent, parse_traceparent, CompletedTrace, Span, SpanId, TraceConfig, TraceContext,
    TraceId, TraceSink,
};

use crate::util::{AtomicF64, Histogram, Table};

use crate::util::sync::RwLockExt;

/// A metric cell that can be zeroed in place (for `Metrics::reset`).
trait Cell: Default {
    fn zero(&self);
}

impl Cell for AtomicU64 {
    fn zero(&self) {
        self.store(0, Ordering::SeqCst);
    }
}

impl Cell for AtomicF64 {
    fn zero(&self) {
        self.store(0.0);
    }
}

impl Cell for AtomicHistogram {
    fn zero(&self) {
        self.reset();
    }
}

/// One metric family: a help string, an ordered label-key list, and one cell
/// per distinct label-value combination. The unlabeled case is a family with
/// an empty key list and a single child at the empty label vector.
pub(crate) struct Family<C> {
    pub(crate) help: String,
    pub(crate) labels: Vec<String>,
    pub(crate) children: RwLock<BTreeMap<Vec<String>, Arc<C>>>,
}

impl<C: Cell> Family<C> {
    fn new(help: &str, labels: &[&str]) -> Self {
        Family {
            help: help.to_string(),
            labels: labels.iter().map(|s| s.to_string()).collect(),
            children: RwLock::new(BTreeMap::new()),
        }
    }

    /// Get or create the child cell for a label-value combination.
    fn child(&self, values: &[&str]) -> Arc<C> {
        assert_eq!(
            values.len(),
            self.labels.len(),
            "label arity mismatch: family declares {:?}, got {} values",
            self.labels,
            values.len()
        );
        let key: Vec<String> = values.iter().map(|s| s.to_string()).collect();
        if let Some(c) = self.children.read_clean().get(&key) {
            return Arc::clone(c);
        }
        let mut w = self.children.write_clean();
        Arc::clone(w.entry(key).or_default())
    }

    /// Sorted (label values, cell) snapshot of all children.
    fn snapshot_children(&self) -> Vec<(Vec<String>, Arc<C>)> {
        self.children.read_clean().iter().map(|(k, v)| (k.clone(), Arc::clone(v))).collect()
    }
}

/// Handle to one counter cell. Cloning is cheap (`Arc` bump); bumping is a
/// single atomic add with no registry access.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::SeqCst);
    }

    pub fn value(&self) -> u64 {
        self.cell.load(Ordering::SeqCst)
    }
}

/// Handle to one gauge cell (absolute-valued f64).
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicF64>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.cell.store(v);
    }

    /// Adjust the gauge by `delta` (atomic read-modify-write). For up/down
    /// counts maintained from multiple threads — where interleaved
    /// absolute `set`s could publish a stale value — deltas always
    /// converge to the true count.
    pub fn add(&self, delta: f64) {
        self.cell.fetch_add(delta);
    }

    pub fn value(&self) -> f64 {
        self.cell.load()
    }
}

/// Handle to one lock-free histogram cell.
#[derive(Clone)]
pub struct Hist {
    cell: Arc<AtomicHistogram>,
}

impl Hist {
    pub fn observe(&self, v: f64) {
        self.cell.record(v);
    }

    pub fn snapshot(&self) -> Histogram {
        self.cell.snapshot()
    }

    pub fn count(&self) -> u64 {
        self.cell.count()
    }
}

/// A labeled counter family; `with(values)` resolves (and caches in the
/// registry) the child for one label-value combination. Call `with` once at
/// setup and keep the returned [`Counter`] — that is the zero-lookup path.
#[derive(Clone)]
pub struct CounterVec {
    family: Arc<Family<AtomicU64>>,
}

impl CounterVec {
    pub fn with(&self, values: &[&str]) -> Counter {
        Counter { cell: self.family.child(values) }
    }
}

/// A labeled gauge family.
#[derive(Clone)]
pub struct GaugeVec {
    family: Arc<Family<AtomicF64>>,
}

impl GaugeVec {
    pub fn with(&self, values: &[&str]) -> Gauge {
        Gauge { cell: self.family.child(values) }
    }
}

/// A labeled histogram family.
#[derive(Clone)]
pub struct HistogramVec {
    family: Arc<Family<AtomicHistogram>>,
}

impl HistogramVec {
    pub fn with(&self, values: &[&str]) -> Hist {
        Hist { cell: self.family.child(values) }
    }
}

const UNREGISTERED_HELP: &str = "(registered on first use)";

/// Which of the three registry tables a name lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// Why a registration was refused. Returned by the `try_*` registration
/// methods; the infallible methods convert it into a counted, detached-cell
/// fallback instead of panicking (same doctrine as poisoned locks in
/// [`crate::util::sync`]: telemetry bugs degrade observability, they do not
/// take down the serving path).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegisterError {
    /// The name is already registered as a different metric kind.
    KindConflict { name: String, existing: MetricKind, requested: MetricKind },
    /// The name is already registered with a different label schema.
    LabelMismatch { name: String, existing: Vec<String>, requested: Vec<String> },
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegisterError::KindConflict { name, existing, requested } => write!(
                f,
                "metric {name:?} is already registered as a {}, cannot re-register as a {}",
                existing.as_str(),
                requested.as_str()
            ),
            RegisterError::LabelMismatch { name, existing, requested } => write!(
                f,
                "metric {name:?} is already registered with labels {existing:?}, cannot re-register with {requested:?}"
            ),
        }
    }
}

impl std::error::Error for RegisterError {}

/// Central metrics registry.
#[derive(Default)]
pub struct Metrics {
    pub(crate) counters: RwLock<BTreeMap<String, Arc<Family<AtomicU64>>>>,
    pub(crate) gauges: RwLock<BTreeMap<String, Arc<Family<AtomicF64>>>>,
    pub(crate) histograms: RwLock<BTreeMap<String, Arc<Family<AtomicHistogram>>>>,
    /// Registrations refused for kind/label conflicts; rendered as
    /// `islandrun_telemetry_register_conflicts_total`. Sticky across
    /// [`Metrics::reset`]: a conflict is a wiring bug, not a sample.
    pub(crate) register_conflicts: AtomicU64,
}

fn try_family<C: Cell>(
    table: &RwLock<BTreeMap<String, Arc<Family<C>>>>,
    kind: MetricKind,
    other_kind: Option<MetricKind>,
    name: &str,
    help: &str,
    labels: &[&str],
) -> Result<Arc<Family<C>>, RegisterError> {
    if let Some(f) = table.read_clean().get(name) {
        let same =
            f.labels.len() == labels.len() && f.labels.iter().zip(labels).all(|(a, b)| a.as_str() == *b);
        if !same {
            return Err(RegisterError::LabelMismatch {
                name: name.to_string(),
                existing: f.labels.clone(),
                requested: labels.iter().map(|s| s.to_string()).collect(),
            });
        }
        return Ok(Arc::clone(f));
    }
    if let Some(existing) = other_kind {
        return Err(RegisterError::KindConflict { name: name.to_string(), existing, requested: kind });
    }
    let mut w = table.write_clean();
    Ok(Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(Family::new(help, labels)))))
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Which kind `name` is currently registered as, if any.
    pub fn kind_of(&self, name: &str) -> Option<MetricKind> {
        if self.counters.read_clean().contains_key(name) {
            return Some(MetricKind::Counter);
        }
        if self.gauges.read_clean().contains_key(name) {
            return Some(MetricKind::Gauge);
        }
        if self.histograms.read_clean().contains_key(name) {
            return Some(MetricKind::Histogram);
        }
        None
    }

    /// Registrations refused so far (kind conflicts and label mismatches).
    pub fn register_conflicts(&self) -> u64 {
        self.register_conflicts.load(Ordering::SeqCst)
    }

    fn conflict<T>(&self, _err: RegisterError, fallback: T) -> T {
        self.register_conflicts.fetch_add(1, Ordering::SeqCst);
        fallback
    }

    // ---- fallible registration: typed errors for conflicting re-use ----

    /// Register (or look up) an unlabeled counter, refusing kind/label
    /// conflicts with a typed error.
    pub fn try_register_counter(&self, name: &str, help: &str) -> Result<Counter, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Counter);
        try_family(&self.counters, MetricKind::Counter, other, name, help, &[])
            .map(|f| Counter { cell: f.child(&[]) })
    }

    /// Register a labeled counter family, refusing kind/label conflicts.
    pub fn try_counter_vec(
        &self,
        name: &str,
        help: &str,
        labels: &[&str],
    ) -> Result<CounterVec, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Counter);
        try_family(&self.counters, MetricKind::Counter, other, name, help, labels)
            .map(|family| CounterVec { family })
    }

    /// Register (or look up) an unlabeled gauge, refusing kind/label conflicts.
    pub fn try_register_gauge(&self, name: &str, help: &str) -> Result<Gauge, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Gauge);
        try_family(&self.gauges, MetricKind::Gauge, other, name, help, &[])
            .map(|f| Gauge { cell: f.child(&[]) })
    }

    /// Register a labeled gauge family, refusing kind/label conflicts.
    pub fn try_gauge_vec(&self, name: &str, help: &str, labels: &[&str]) -> Result<GaugeVec, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Gauge);
        try_family(&self.gauges, MetricKind::Gauge, other, name, help, labels)
            .map(|family| GaugeVec { family })
    }

    /// Register (or look up) an unlabeled histogram, refusing kind/label
    /// conflicts.
    pub fn try_register_histogram(&self, name: &str, help: &str) -> Result<Hist, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Histogram);
        try_family(&self.histograms, MetricKind::Histogram, other, name, help, &[])
            .map(|f| Hist { cell: f.child(&[]) })
    }

    /// Register a labeled histogram family, refusing kind/label conflicts.
    pub fn try_histogram_vec(
        &self,
        name: &str,
        help: &str,
        labels: &[&str],
    ) -> Result<HistogramVec, RegisterError> {
        let other = self.kind_of(name).filter(|k| *k != MetricKind::Histogram);
        try_family(&self.histograms, MetricKind::Histogram, other, name, help, labels)
            .map(|family| HistogramVec { family })
    }

    // ---- registration: resolve handles once, bump them lock-free after ----
    //
    // The infallible forms delegate to the `try_*` methods. On conflict they
    // bump `register_conflicts` and hand back a *detached* cell: a live handle
    // whose family was never inserted into the registry, so bumps still work
    // (no panic on the serving path) but never render. The conflict counter in
    // the exposition is what makes the wiring bug visible.

    /// Register (or look up) an unlabeled counter and return its handle.
    pub fn register_counter(&self, name: &str, help: &str) -> Counter {
        self.try_register_counter(name, help)
            .unwrap_or_else(|e| self.conflict(e, Counter { cell: Family::<AtomicU64>::new(help, &[]).child(&[]) }))
    }

    /// Register a labeled counter family.
    pub fn counter_vec(&self, name: &str, help: &str, labels: &[&str]) -> CounterVec {
        self.try_counter_vec(name, help, labels)
            .unwrap_or_else(|e| self.conflict(e, CounterVec { family: Arc::new(Family::new(help, labels)) }))
    }

    /// Register (or look up) an unlabeled gauge and return its handle.
    pub fn register_gauge(&self, name: &str, help: &str) -> Gauge {
        self.try_register_gauge(name, help)
            .unwrap_or_else(|e| self.conflict(e, Gauge { cell: Family::<AtomicF64>::new(help, &[]).child(&[]) }))
    }

    /// Register a labeled gauge family.
    pub fn gauge_vec(&self, name: &str, help: &str, labels: &[&str]) -> GaugeVec {
        self.try_gauge_vec(name, help, labels)
            .unwrap_or_else(|e| self.conflict(e, GaugeVec { family: Arc::new(Family::new(help, labels)) }))
    }

    /// Register (or look up) an unlabeled histogram and return its handle.
    pub fn register_histogram(&self, name: &str, help: &str) -> Hist {
        self.try_register_histogram(name, help).unwrap_or_else(|e| {
            self.conflict(e, Hist { cell: Family::<AtomicHistogram>::new(help, &[]).child(&[]) })
        })
    }

    /// Register a labeled histogram family.
    pub fn histogram_vec(&self, name: &str, help: &str, labels: &[&str]) -> HistogramVec {
        self.try_histogram_vec(name, help, labels)
            .unwrap_or_else(|e| self.conflict(e, HistogramVec { family: Arc::new(Family::new(help, labels)) }))
    }

    // ---- legacy string-keyed API: get-or-register by name on every call ----

    /// Increment a named counter by `n`. String-keyed slow path: resolves the
    /// name through the registry on every call. Hot paths should hold a
    /// [`Counter`] handle instead (see [`ServingMetrics`]).
    pub fn count(&self, name: &str, n: u64) {
        self.register_counter(name, UNREGISTERED_HELP).add(n);
    }

    /// Set a gauge to an absolute value (string-keyed slow path).
    pub fn gauge(&self, name: &str, v: f64) {
        self.register_gauge(name, UNREGISTERED_HELP).set(v);
    }

    /// Record a histogram sample (string-keyed slow path).
    pub fn observe(&self, name: &str, v: f64) {
        self.register_histogram(name, UNREGISTERED_HELP).observe(v);
    }

    // ---- queries ----

    /// Total over all children of a counter family (0 if absent). For a
    /// labeled family this is the sum across label combinations.
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.counters.read_clean().get(name) {
            Some(f) => f.children.read_clean().values().map(|c| c.load(Ordering::SeqCst)).sum(),
            None => 0,
        }
    }

    /// Value of an unlabeled gauge (None if never set).
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        let table = self.gauges.read_clean();
        let f = table.get(name)?;
        let children = f.children.read_clean();
        children.get(&Vec::new()).map(|g| g.load())
    }

    /// Snapshot of a histogram family by name, merged across all label
    /// combinations. None if the name was never registered.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        let table = self.histograms.read_clean();
        let f = table.get(name)?;
        let mut merged = Histogram::new();
        for child in f.children.read_clean().values() {
            merged.merge(&child.snapshot());
        }
        Some(merged)
    }

    /// Per-child values of a counter family: (label values, count), sorted.
    pub fn counter_children(&self, name: &str) -> Vec<(Vec<String>, u64)> {
        match self.counters.read_clean().get(name) {
            Some(f) => f.snapshot_children().into_iter().map(|(k, c)| (k, c.load(Ordering::SeqCst))).collect(),
            None => Vec::new(),
        }
    }

    /// Per-child snapshots of a histogram family: (label values, histogram).
    pub fn histogram_children(&self, name: &str) -> Vec<(Vec<String>, Histogram)> {
        match self.histograms.read_clean().get(name) {
            Some(f) => f.snapshot_children().into_iter().map(|(k, h)| (k, h.snapshot())).collect(),
            None => Vec::new(),
        }
    }

    /// `name{k="v",...}` display form for a child (plain name if unlabeled).
    fn series_name(name: &str, labels: &[String], values: &[String]) -> String {
        if values.is_empty() {
            return name.to_string();
        }
        let pairs: Vec<String> =
            labels.iter().zip(values).map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        format!("{name}{{{}}}", pairs.join(","))
    }

    /// Render everything as a report table (used by `islandrun stats`).
    pub fn report(&self) -> Table {
        let mut t = Table::new("metrics", &["metric", "value"]);
        for (name, f) in self.counters.read_clean().iter() {
            for (values, c) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), c.load(Ordering::SeqCst).to_string()]);
            }
        }
        for (name, f) in self.gauges.read_clean().iter() {
            for (values, g) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), format!("{:.3}", g.load())]);
            }
        }
        for (name, f) in self.histograms.read_clean().iter() {
            for (values, h) in f.snapshot_children() {
                t.row(&[Self::series_name(name, &f.labels, &values), h.snapshot().summary()]);
            }
        }
        t
    }

    /// Clear all metrics (between experiment repetitions). Every cell —
    /// including histogram buckets — is zeroed in place rather than dropped,
    /// so handles resolved before the reset keep recording into live cells.
    pub fn reset(&self) {
        for f in self.counters.read_clean().values() {
            for c in f.children.read_clean().values() {
                c.zero();
            }
        }
        for f in self.gauges.read_clean().values() {
            for g in f.children.read_clean().values() {
                g.zero();
            }
        }
        for f in self.histograms.read_clean().values() {
            for h in f.children.read_clean().values() {
                h.zero();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("requests", 1);
        m.count("requests", 2);
        assert_eq!(m.counter_value("requests"), 3);
        assert_eq!(m.counter_value("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.gauge("capacity", 0.7);
        m.gauge("capacity", 0.4);
        assert_eq!(m.gauge_value("capacity"), Some(0.4));
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for x in [10.0, 20.0, 30.0] {
            m.observe("latency_ms", x);
        }
        let h = m.histogram("latency_ms").unwrap();
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn report_and_reset() {
        let m = Metrics::new();
        m.count("a", 1);
        m.gauge("b", 2.0);
        m.observe("c", 3.0);
        let rendered = m.report().render();
        assert!(rendered.contains("| a"));
        assert!(rendered.contains("| b"));
        assert!(rendered.contains("| c"));
        m.reset();
        assert_eq!(m.counter_value("a"), 0);
        // cells are zeroed in place, not dropped: the family survives empty
        assert_eq!(m.histogram("c").unwrap().count(), 0);
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.count("n", 1);
                        m.observe("h", 1.0);
                        m.gauge("g", 0.5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter_value("n"), 4000);
        assert_eq!(m.histogram("h").unwrap().count(), 4000);
        assert_eq!(m.gauge_value("g"), Some(0.5));
    }

    #[test]
    fn registered_handles_share_cells_with_legacy_names() {
        let m = Metrics::new();
        let c = m.register_counter("served", "requests served");
        c.inc();
        m.count("served", 2); // legacy path lands in the same cell
        assert_eq!(c.value(), 3);
        assert_eq!(m.counter_value("served"), 3);

        let g = m.register_gauge("depth", "queue depth");
        g.set(7.0);
        assert_eq!(m.gauge_value("depth"), Some(7.0));

        let h = m.register_histogram("wait", "queue wait");
        h.observe(4.0);
        m.observe("wait", 6.0);
        assert_eq!(m.histogram("wait").unwrap().count(), 2);
        assert_eq!(h.snapshot().count(), 2);
    }

    #[test]
    fn labeled_families_track_children_separately() {
        let m = Metrics::new();
        let v = m.counter_vec("resolved", "by outcome", &["outcome", "reason"]);
        let served = v.with(&["served", "ok"]);
        let shed = v.with(&["shed", "queue_full"]);
        served.add(5);
        shed.add(2);
        // counter_value sums across label combinations
        assert_eq!(m.counter_value("resolved"), 7);
        let children = m.counter_children("resolved");
        assert_eq!(children.len(), 2);
        assert_eq!(children[0], (vec!["served".to_string(), "ok".to_string()], 5));
        assert_eq!(children[1], (vec!["shed".to_string(), "queue_full".to_string()], 2));

        let hv = m.histogram_vec("lat", "latency by island", &["island"]);
        hv.with(&["island-0"]).observe(10.0);
        hv.with(&["island-1"]).observe(30.0);
        let merged = m.histogram("lat").unwrap();
        assert_eq!(merged.count(), 2);
        assert!((merged.mean() - 20.0).abs() < 1e-9);
        assert_eq!(m.histogram_children("lat").len(), 2);
    }

    #[test]
    fn kind_conflicts_yield_typed_errors_and_detached_fallbacks() {
        let m = Metrics::new();
        m.register_counter("depth", "a counter").inc();
        assert_eq!(m.kind_of("depth"), Some(MetricKind::Counter));

        let err = m.try_register_gauge("depth", "now a gauge?").unwrap_err();
        assert_eq!(
            err,
            RegisterError::KindConflict {
                name: "depth".to_string(),
                existing: MetricKind::Counter,
                requested: MetricKind::Gauge,
            }
        );
        assert!(err.to_string().contains("already registered as a counter"));
        assert_eq!(m.register_conflicts(), 0, "try_* refusals are not counted, infallible fallbacks are");

        // The infallible path degrades to a detached (unrendered) cell and
        // counts the conflict instead of panicking on the serving path.
        let g = m.register_gauge("depth", "now a gauge?");
        g.set(9.0);
        assert_eq!(m.register_conflicts(), 1);
        assert_eq!(m.gauge_value("depth"), None, "detached gauge never enters the registry");
        assert_eq!(m.counter_value("depth"), 1, "the original counter is untouched");

        // Legacy string-keyed bumps against the conflicting name also degrade.
        m.observe("depth", 3.0);
        assert_eq!(m.register_conflicts(), 2);
        assert!(m.histogram("depth").is_none());
    }

    #[test]
    fn label_mismatch_yields_typed_error() {
        let m = Metrics::new();
        m.counter_vec("resolved", "by outcome", &["outcome", "reason"]);
        let err = m.try_counter_vec("resolved", "by outcome", &["outcome"]).unwrap_err();
        match err {
            RegisterError::LabelMismatch { name, existing, requested } => {
                assert_eq!(name, "resolved");
                assert_eq!(existing, vec!["outcome".to_string(), "reason".to_string()]);
                assert_eq!(requested, vec!["outcome".to_string()]);
            }
            other => panic!("expected LabelMismatch, got {other:?}"),
        }
        // identical re-registration is sharing, not a conflict
        let v = m.try_counter_vec("resolved", "by outcome", &["outcome", "reason"]).unwrap();
        v.with(&["served", "ok"]).inc();
        assert_eq!(m.counter_value("resolved"), 1);
        assert_eq!(m.register_conflicts(), 0);
    }

    #[test]
    #[should_panic(expected = "label arity mismatch")]
    fn wrong_label_arity_panics() {
        let m = Metrics::new();
        let v = m.counter_vec("x", "help", &["a", "b"]);
        v.with(&["only-one"]);
    }

    #[test]
    fn reset_keeps_handles_live() {
        let m = Metrics::new();
        let c = m.register_counter("c", "h");
        let h = m.register_histogram("hst", "h");
        c.inc();
        h.observe(1.0);
        m.reset();
        c.inc();
        h.observe(2.0);
        assert_eq!(m.counter_value("c"), 1);
        let s = m.histogram("hst").unwrap();
        assert_eq!(s.count(), 1);
        assert_eq!(s.max(), 2.0);
    }

    #[test]
    fn report_renders_labeled_series() {
        let m = Metrics::new();
        m.counter_vec("resolved", "h", &["outcome"]).with(&["served"]).inc();
        let rendered = m.report().render();
        assert!(rendered.contains("resolved{outcome=\"served\"}"), "{rendered}");
    }
}
