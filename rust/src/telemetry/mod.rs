//! Metrics registry: counters, gauges and latency histograms.
//!
//! Owned by the rust coordinator (L3 owns "metrics" per the architecture);
//! every agent and island executor reports here. Thread-safe and
//! lock-minimal: counters and gauges are atomics reached through an
//! `RwLock`-ed name table (read-locked on the hot path, write-locked only
//! the first time a name appears), histograms keep a single mutex because
//! recording mutates bucket arrays. Many threads submit through
//! `Arc<Orchestrator>` concurrently; the per-request cost here is a few
//! atomic adds plus one short histogram lock.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::util::{AtomicF64, Histogram, Table};

/// Central metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: RwLock<BTreeMap<String, Arc<AtomicF64>>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn counter_cell(&self, name: &str) -> Arc<AtomicU64> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut w = self.counters.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    fn gauge_cell(&self, name: &str) -> Arc<AtomicF64> {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            return Arc::clone(g);
        }
        let mut w = self.gauges.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    /// Increment a named counter by `n`.
    pub fn count(&self, name: &str, n: u64) {
        self.counter_cell(name).fetch_add(n, Ordering::SeqCst);
    }

    /// Set a gauge to an absolute value.
    pub fn gauge(&self, name: &str, v: f64) {
        self.gauge_cell(name).store(v);
    }

    /// Record a histogram sample (e.g. latency in ms).
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.histograms.lock().unwrap();
        g.entry(name.to_string()).or_default().record(v);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.read().unwrap().get(name).map(|c| c.load(Ordering::SeqCst)).unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.read().unwrap().get(name).map(|g| g.load())
    }

    /// Snapshot of a histogram by name.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.histograms.lock().unwrap().get(name).cloned()
    }

    /// Render everything as a report table (used by `islandrun stats`).
    pub fn report(&self) -> Table {
        let mut t = Table::new("metrics", &["metric", "value"]);
        for (k, v) in self.counters.read().unwrap().iter() {
            t.row(&[k.clone(), v.load(Ordering::SeqCst).to_string()]);
        }
        for (k, v) in self.gauges.read().unwrap().iter() {
            t.row(&[k.clone(), format!("{:.3}", v.load())]);
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            t.row(&[k.clone(), h.summary()]);
        }
        t
    }

    /// Clear all metrics (between experiment repetitions). Counter and gauge
    /// cells are zeroed in place rather than dropped so a racing `count()`
    /// that already fetched a cell still lands its increment in a live
    /// counter instead of an orphaned one.
    pub fn reset(&self) {
        for c in self.counters.read().unwrap().values() {
            c.store(0, Ordering::SeqCst);
        }
        for g in self.gauges.read().unwrap().values() {
            g.store(0.0);
        }
        self.histograms.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("requests", 1);
        m.count("requests", 2);
        assert_eq!(m.counter_value("requests"), 3);
        assert_eq!(m.counter_value("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.gauge("capacity", 0.7);
        m.gauge("capacity", 0.4);
        assert_eq!(m.gauge_value("capacity"), Some(0.4));
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for x in [10.0, 20.0, 30.0] {
            m.observe("latency_ms", x);
        }
        let h = m.histogram("latency_ms").unwrap();
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn report_and_reset() {
        let m = Metrics::new();
        m.count("a", 1);
        m.gauge("b", 2.0);
        m.observe("c", 3.0);
        let rendered = m.report().render();
        assert!(rendered.contains("| a"));
        assert!(rendered.contains("| b"));
        assert!(rendered.contains("| c"));
        m.reset();
        assert_eq!(m.counter_value("a"), 0);
        assert!(m.histogram("c").is_none());
    }

    #[test]
    fn thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.count("n", 1);
                        m.observe("h", 1.0);
                        m.gauge("g", 0.5);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter_value("n"), 4000);
        assert_eq!(m.histogram("h").unwrap().count(), 4000);
        assert_eq!(m.gauge_value("g"), Some(0.5));
    }
}
