//! Per-request analytics events: one structured record per resolved ticket,
//! kept in a bounded ring buffer with JSONL export for the eval harness.
//!
//! Metrics (mod.rs) answer "how many / how fast in aggregate"; the event log
//! answers "what happened to request 17492" — lifecycle timestamps
//! (enqueue→route→prefill→first-token→resolve), the island and tier that
//! served it, failover and sanitization counts, and the typed outcome. The
//! buffer is bounded: when full, the oldest event is dropped and a drop
//! counter bumped, so a long-running server never grows without limit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::json::Json;

use crate::util::sync::LockExt;

/// Default ring capacity: enough for a full bench run's tail without
/// unbounded growth on long-lived servers.
pub const DEFAULT_EVENT_CAPACITY: usize = 4096;

/// One resolved request. Timestamps are virtual-clock milliseconds; a stage
/// a request never reached is `NaN` and exports as JSON `null`.
#[derive(Clone, Debug)]
pub struct RequestEvent {
    pub request_id: u64,
    pub user: String,
    /// Outcome class label: `served` / `shed` / `cancelled` / `failed`.
    pub outcome: &'static str,
    /// Outcome reason label, e.g. `queue_full`, `deadline_mid_decode`.
    pub reason: &'static str,
    /// Serving island (`island-N`), if one was assigned.
    pub island: Option<String>,
    /// Trust tier of the serving island.
    pub tier: Option<&'static str>,
    /// Privacy score of the serving island.
    pub privacy: Option<f64>,
    /// MIST sensitivity score after floor clamping.
    pub s_r: f64,
    pub failovers: u32,
    pub sanitized: bool,
    /// Conversation turns rewritten by MIST for this request.
    pub sanitized_turns: u64,
    pub enqueued_ms: f64,
    pub routed_ms: f64,
    pub prefill_ms: f64,
    pub first_token_ms: f64,
    pub resolved_ms: f64,
    pub tokens_generated: u32,
    pub latency_ms: f64,
    pub cost_usd: f64,
    /// Hex trace id joining this event to the trace ring and audit log.
    /// `None` only when tail sampling dropped the trace (or tracing is off).
    pub trace_id: Option<String>,
}

fn ms(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

impl RequestEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("request_id", Json::num(self.request_id as f64)),
            ("user", Json::str(&self.user)),
            ("outcome", Json::str(self.outcome)),
            ("reason", Json::str(self.reason)),
            ("island", self.island.as_deref().map(Json::str).unwrap_or(Json::Null)),
            ("tier", self.tier.map(Json::str).unwrap_or(Json::Null)),
            ("privacy", self.privacy.map(Json::num).unwrap_or(Json::Null)),
            ("s_r", Json::num(self.s_r)),
            ("failovers", Json::num(self.failovers as f64)),
            ("sanitized", Json::Bool(self.sanitized)),
            ("sanitized_turns", Json::num(self.sanitized_turns as f64)),
            ("enqueued_ms", ms(self.enqueued_ms)),
            ("routed_ms", ms(self.routed_ms)),
            ("prefill_ms", ms(self.prefill_ms)),
            ("first_token_ms", ms(self.first_token_ms)),
            ("resolved_ms", ms(self.resolved_ms)),
            ("tokens_generated", Json::num(self.tokens_generated as f64)),
            ("latency_ms", ms(self.latency_ms)),
            ("cost_usd", Json::num(self.cost_usd)),
            ("trace_id", self.trace_id.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ])
    }
}

/// Bounded ring buffer of [`RequestEvent`]s.
pub struct EventLog {
    inner: Mutex<VecDeque<RequestEvent>>,
    capacity: usize,
    dropped: AtomicU64,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::new(DEFAULT_EVENT_CAPACITY)
    }
}

impl EventLog {
    pub fn new(capacity: usize) -> Self {
        EventLog {
            inner: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
            capacity: capacity.max(1),
            dropped: AtomicU64::new(0),
        }
    }

    /// Append an event, evicting the oldest when the ring is full.
    pub fn push(&self, ev: RequestEvent) {
        let mut q = self.inner.lock_clean();
        if q.len() == self.capacity {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::SeqCst);
        }
        q.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.inner.lock_clean().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    /// Copy of the buffered events, oldest first.
    pub fn snapshot(&self) -> Vec<RequestEvent> {
        self.inner.lock_clean().iter().cloned().collect()
    }

    /// JSONL export: one JSON object per line, oldest first.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.inner.lock_clean().iter() {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(id: u64) -> RequestEvent {
        RequestEvent {
            request_id: id,
            user: "u".to_string(),
            outcome: "served",
            reason: "ok",
            island: Some("island-1".to_string()),
            tier: Some("personal"),
            privacy: Some(0.9),
            s_r: 0.4,
            failovers: 0,
            sanitized: false,
            sanitized_turns: 0,
            enqueued_ms: 1.0,
            routed_ms: 2.0,
            prefill_ms: 3.0,
            first_token_ms: 4.0,
            resolved_ms: 9.0,
            tokens_generated: 16,
            latency_ms: 8.0,
            cost_usd: 0.001,
            trace_id: Some(format!("{:032x}", id + 1)),
        }
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let log = EventLog::new(3);
        for id in 0..5 {
            log.push(event(id));
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.dropped(), 2);
        let ids: Vec<u64> = log.snapshot().iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn jsonl_parses_back_line_by_line() {
        let log = EventLog::new(8);
        log.push(event(1));
        let mut ev = event(2);
        ev.first_token_ms = f64::NAN; // never reached first token
        ev.island = None;
        ev.tier = None;
        ev.trace_id = None; // sampling dropped the trace
        log.push(ev);
        let jsonl = log.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("outcome"), &Json::str("served"));
        assert_eq!(first.get("island"), &Json::str("island-1"));
        assert_eq!(first.get("trace_id"), &Json::str(&format!("{:032x}", 2)));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("first_token_ms"), &Json::Null);
        assert_eq!(second.get("island"), &Json::Null);
        assert_eq!(second.get("trace_id"), &Json::Null);
    }
}
