//! Prometheus text exposition: render the whole registry as
//! `# HELP`/`# TYPE`-annotated sample lines, plus a format lint used by the
//! telemetry consistency tests (and by `islandrun stats --prom` consumers
//! that want to validate a dump before shipping it to a scraper).
//!
//! Conventions (documented in the README "Observability" section):
//! * every metric is prefixed `islandrun_`;
//! * counters get a `_total` suffix;
//! * histograms expose cumulative `_bucket{le="..."}` series ending in
//!   `le="+Inf"`, plus `_sum` and `_count`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use super::Metrics;

use crate::util::sync::RwLockExt;

const PREFIX: &str = "islandrun_";

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// `k="v",...` pairs (no braces); empty string when unlabeled.
fn label_pairs(keys: &[String], values: &[String]) -> String {
    keys.iter()
        .zip(values)
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

fn series(full: &str, pairs: &str) -> String {
    if pairs.is_empty() {
        full.to_string()
    } else {
        format!("{full}{{{pairs}}}")
    }
}

/// A bucket series needs `le` appended to the child's own labels.
fn series_with_le(full: &str, pairs: &str, le: &str) -> String {
    if pairs.is_empty() {
        format!("{full}{{le=\"{le}\"}}")
    } else {
        format!("{full}{{{pairs},le=\"{le}\"}}")
    }
}

impl Metrics {
    /// Render every registered family in Prometheus text exposition format.
    /// Families and children are emitted in sorted order, so the output is
    /// deterministic for a given registry state.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        // Process-level health counters that live outside the registry maps:
        // lock-poison recoveries (see `util::sync`) and registrations refused
        // for kind/label conflicts. Both are wiring-bug telltales that must be
        // scrapable even though nothing registers them explicitly.
        let _ = writeln!(
            out,
            "# HELP {PREFIX}lock_poison_recoveries_total lock guards recovered from a poisoned state"
        );
        let _ = writeln!(out, "# TYPE {PREFIX}lock_poison_recoveries_total counter");
        let _ = writeln!(
            out,
            "{PREFIX}lock_poison_recoveries_total {}",
            crate::util::sync::poison_recoveries()
        );
        let _ = writeln!(
            out,
            "# HELP {PREFIX}telemetry_register_conflicts_total metric registrations refused for kind or label conflicts"
        );
        let _ = writeln!(out, "# TYPE {PREFIX}telemetry_register_conflicts_total counter");
        let _ = writeln!(out, "{PREFIX}telemetry_register_conflicts_total {}", self.register_conflicts());
        for (name, f) in self.counters.read_clean().iter() {
            let full = format!("{PREFIX}{name}_total");
            let _ = writeln!(out, "# HELP {full} {}", escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {full} counter");
            for (values, c) in f.snapshot_children() {
                let pairs = label_pairs(&f.labels, &values);
                let _ = writeln!(out, "{} {}", series(&full, &pairs), c.load(Ordering::SeqCst));
            }
        }
        for (name, f) in self.gauges.read_clean().iter() {
            let full = format!("{PREFIX}{name}");
            let _ = writeln!(out, "# HELP {full} {}", escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {full} gauge");
            for (values, g) in f.snapshot_children() {
                let pairs = label_pairs(&f.labels, &values);
                let _ = writeln!(out, "{} {}", series(&full, &pairs), g.load());
            }
        }
        for (name, f) in self.histograms.read_clean().iter() {
            let full = format!("{PREFIX}{name}");
            let _ = writeln!(out, "# HELP {full} {}", escape_help(&f.help));
            let _ = writeln!(out, "# TYPE {full} histogram");
            for (values, h) in f.snapshot_children() {
                let pairs = label_pairs(&f.labels, &values);
                let snap = h.snapshot();
                let bucket = format!("{full}_bucket");
                for (le, cum) in snap.cumulative_buckets() {
                    let _ = writeln!(out, "{} {}", series_with_le(&bucket, &pairs, &format!("{le}")), cum);
                }
                let _ = writeln!(out, "{} {}", series_with_le(&bucket, &pairs, "+Inf"), snap.count());
                let _ = writeln!(out, "{} {}", series(&format!("{full}_sum"), &pairs), snap.sum());
                let _ = writeln!(out, "{} {}", series(&format!("{full}_count"), &pairs), snap.count());
            }
        }
        out
    }
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse the inside of a label block into (key, unescaped value) pairs.
fn parse_labels(s: &str, line_no: usize) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut rest = s.trim();
    while !rest.is_empty() {
        let eq = rest.find('=').ok_or_else(|| format!("line {line_no}: label without '='"))?;
        let key = rest[..eq].trim();
        if !valid_label_name(key) {
            return Err(format!("line {line_no}: invalid label name {key:?}"));
        }
        rest = rest[eq + 1..].trim_start();
        if !rest.starts_with('"') {
            return Err(format!("line {line_no}: label value for {key:?} is not quoted"));
        }
        let mut val = String::new();
        let mut close = None;
        let mut chars = rest.char_indices().skip(1);
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => val.push('\n'),
                    Some((_, '\\')) => val.push('\\'),
                    Some((_, '"')) => val.push('"'),
                    other => {
                        return Err(format!(
                            "line {line_no}: bad escape \\{} in label {key:?}",
                            other.map(|(_, c)| c).unwrap_or(' ')
                        ))
                    }
                },
                '"' => {
                    close = Some(i);
                    break;
                }
                _ => val.push(c),
            }
        }
        let close = close.ok_or_else(|| format!("line {line_no}: unterminated label value for {key:?}"))?;
        out.push((key.to_string(), val));
        rest = rest[close + 1..].trim_start();
        if let Some(r) = rest.strip_prefix(',') {
            rest = r.trim_start();
            if rest.is_empty() {
                return Err(format!("line {line_no}: trailing comma in label block"));
            }
        } else if !rest.is_empty() {
            return Err(format!("line {line_no}: expected ',' between labels, got {rest:?}"));
        }
    }
    Ok(out)
}

/// Split a sample line into (name, label pairs, value).
fn parse_sample(line: &str, line_no: usize) -> Result<(String, Vec<(String, String)>, f64), String> {
    let (name, labels, rest) = if let Some(open) = line.find('{') {
        let name = &line[..open];
        // find the closing brace, honoring quotes and escapes
        let mut close = None;
        let mut in_quotes = false;
        let mut chars = line.char_indices().skip_while(|&(i, _)| i <= open);
        while let Some((i, c)) = chars.next() {
            match c {
                '"' => in_quotes = !in_quotes,
                '\\' if in_quotes => {
                    let _ = chars.next();
                }
                '}' if !in_quotes => {
                    close = Some(i);
                    break;
                }
                _ => {}
            }
        }
        let close = close.ok_or_else(|| format!("line {line_no}: unterminated label block"))?;
        (name, parse_labels(&line[open + 1..close], line_no)?, line[close + 1..].trim())
    } else {
        let mut it = line.splitn(2, char::is_whitespace);
        let name = it.next().unwrap_or("");
        (name, Vec::new(), it.next().unwrap_or("").trim())
    };
    if !valid_metric_name(name) {
        return Err(format!("line {line_no}: invalid metric name {name:?}"));
    }
    // value, optionally followed by an integer timestamp
    let mut toks = rest.split_whitespace();
    let value_tok = toks.next().ok_or_else(|| format!("line {line_no}: sample {name:?} has no value"))?;
    let value: f64 = value_tok
        .parse()
        .map_err(|_| format!("line {line_no}: sample {name:?} has non-numeric value {value_tok:?}"))?;
    if let Some(ts) = toks.next() {
        if ts.parse::<i64>().is_err() {
            return Err(format!("line {line_no}: trailing token {ts:?} is not a timestamp"));
        }
    }
    if toks.next().is_some() {
        return Err(format!("line {line_no}: trailing garbage after sample {name:?}"));
    }
    Ok((name.to_string(), labels, value))
}

/// Validate Prometheus text exposition output. Checks:
/// * unique `# HELP` / `# TYPE` per family, and both present for any family
///   with samples;
/// * `# TYPE` precedes the family's first sample;
/// * metric and label names are well-formed, label values properly quoted
///   and escaped;
/// * no duplicate series (same name + label set twice);
/// * no family whose name collides with a histogram family's generated
///   `_bucket`/`_sum`/`_count` sample names;
/// * per histogram child: cumulative bucket counts are monotone
///   non-decreasing over increasing `le`, the series ends at `le="+Inf"`,
///   and the `+Inf` count equals the child's `_count`.
pub fn lint_exposition(text: &str) -> Result<(), String> {
    let mut helps: BTreeSet<String> = BTreeSet::new();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut seen_series: BTreeSet<String> = BTreeSet::new();
    let mut families_with_samples: BTreeSet<String> = BTreeSet::new();
    // histogram child accounting, keyed by (family, serialized labels sans le)
    let mut buckets: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();

    let child_key = |labels: &[(String, String)]| -> String {
        let mut pairs: Vec<String> =
            labels.iter().filter(|(k, _)| k != "le").map(|(k, v)| format!("{k}={v:?}")).collect();
        pairs.sort();
        pairs.join(",")
    };

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("line {line_no}: HELP for invalid name {name:?}"));
            }
            if !helps.insert(name.to_string()) {
                return Err(format!("line {line_no}: duplicate HELP for {name}"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("line {line_no}: TYPE for invalid name {name:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {line_no}: unknown TYPE {kind:?} for {name}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("line {line_no}: duplicate TYPE for {name}"));
            }
            if families_with_samples.contains(name) {
                return Err(format!("line {line_no}: TYPE for {name} appears after its samples"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // free-form comment
        }

        let (name, labels, value) = parse_sample(line, line_no)?;
        // resolve the owning family: exact TYPE match, else histogram suffix
        let family = if types.contains_key(&name) {
            name.clone()
        } else {
            let base = ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|suf| name.strip_suffix(suf))
                .map(str::to_string);
            match base {
                Some(b) if types.get(&b).map(String::as_str) == Some("histogram") => b,
                _ => return Err(format!("line {line_no}: sample {name} has no preceding TYPE")),
            }
        };
        families_with_samples.insert(family.clone());

        let series_id = format!("{name}|{}", {
            let mut pairs: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v:?}")).collect();
            pairs.sort();
            pairs.join(",")
        });
        if !seen_series.insert(series_id) {
            return Err(format!("line {line_no}: duplicate series for {name}"));
        }

        if name.ends_with("_bucket") && types.get(&family).map(String::as_str) == Some("histogram") {
            let le = labels
                .iter()
                .find(|(k, _)| k == "le")
                .map(|(_, v)| v.clone())
                .ok_or_else(|| format!("line {line_no}: bucket sample without le label"))?;
            let bound: f64 = le
                .parse()
                .map_err(|_| format!("line {line_no}: unparsable le bound {le:?}"))?;
            buckets.entry((family, child_key(&labels))).or_default().push((bound, value));
        } else if name.ends_with("_count") && !types.contains_key(&name) {
            counts.insert((family, child_key(&labels)), value);
        }
    }

    for name in &helps {
        if !types.contains_key(name) {
            return Err(format!("{name}: HELP without TYPE"));
        }
    }
    for name in types.keys() {
        if !helps.contains(name) {
            return Err(format!("{name}: TYPE without HELP"));
        }
    }

    // Family names must not collide with another family's generated sample
    // names: a histogram `h` owns `h_bucket` / `h_sum` / `h_count`, so a
    // separate family claiming one of those names makes every sample line
    // ambiguous between the two owners.
    for (name, kind) in &types {
        if kind == "histogram" {
            for suf in ["_bucket", "_sum", "_count"] {
                let derived = format!("{name}{suf}");
                if types.contains_key(&derived) {
                    return Err(format!(
                        "{derived}: family name collides with histogram {name}'s {suf} samples"
                    ));
                }
            }
        }
    }

    for ((family, key), mut series) in buckets {
        series.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut prev = f64::NEG_INFINITY;
        for &(_, count) in &series {
            if count < prev {
                return Err(format!("{family}{{{key}}}: bucket counts not monotone"));
            }
            prev = count;
        }
        // series is non-empty: every key in `buckets` was inserted with at
        // least one (bound, count) push
        let Some(&(last_bound, last_count)) = series.last() else { continue };
        if !last_bound.is_infinite() {
            return Err(format!("{family}{{{key}}}: bucket series does not end at le=\"+Inf\""));
        }
        match counts.get(&(family.clone(), key.clone())) {
            Some(&c) if c == last_count => {}
            Some(&c) => {
                return Err(format!("{family}{{{key}}}: +Inf bucket {last_count} != _count {c}"));
            }
            None => return Err(format!("{family}{{{key}}}: histogram child missing _count")),
        }
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_round_trips_through_lint() {
        let m = Metrics::new();
        m.register_counter("requests_served", "requests served end to end").add(7);
        m.counter_vec("requests_resolved", "terminal outcomes", &["outcome", "reason"])
            .with(&["served", "ok"])
            .add(5);
        m.register_gauge("queue_depth", "admission queue depth").set(3.0);
        let hv = m.histogram_vec("island_latency_ms", "per-island latency", &["island", "tier"]);
        let h = hv.with(&["island-0", "personal"]);
        for x in [1.0, 5.0, 25.0] {
            h.observe(x);
        }
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE islandrun_requests_served_total counter"), "{text}");
        assert!(text.contains("islandrun_requests_resolved_total{outcome=\"served\",reason=\"ok\"} 5"), "{text}");
        assert!(text.contains("islandrun_queue_depth 3"), "{text}");
        assert!(text.contains("island=\"island-0\",tier=\"personal\",le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("islandrun_island_latency_ms_count{island=\"island-0\",tier=\"personal\"} 3"), "{text}");
        lint_exposition(&text).unwrap();
    }

    #[test]
    fn label_values_are_escaped() {
        let m = Metrics::new();
        m.counter_vec("odd", "odd labels", &["k"]).with(&["a\"b\\c\nd"]).inc();
        let text = m.render_prometheus();
        assert!(text.contains(r#"islandrun_odd_total{k="a\"b\\c\nd"} 1"#), "{text}");
        lint_exposition(&text).unwrap();
    }

    #[test]
    fn empty_histogram_child_still_lints() {
        let m = Metrics::new();
        m.register_histogram("latency_ms", "never recorded");
        let text = m.render_prometheus();
        assert!(text.contains("islandrun_latency_ms_bucket{le=\"+Inf\"} 0"), "{text}");
        lint_exposition(&text).unwrap();
    }

    #[test]
    fn lint_rejects_duplicate_help() {
        let text = "# HELP x_total a\n# HELP x_total b\n# TYPE x_total counter\n";
        assert!(lint_exposition(text).unwrap_err().contains("duplicate HELP"));
    }

    #[test]
    fn lint_rejects_sample_without_type() {
        let text = "mystery_metric 4\n";
        assert!(lint_exposition(text).unwrap_err().contains("no preceding TYPE"));
    }

    #[test]
    fn lint_rejects_non_monotone_buckets() {
        let text = "\
# HELP h latency
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_bucket{le=\"2\"} 3
h_bucket{le=\"+Inf\"} 5
h_sum 9
h_count 5
";
        assert!(lint_exposition(text).unwrap_err().contains("not monotone"));
    }

    #[test]
    fn lint_rejects_missing_inf_bucket() {
        let text = "\
# HELP h latency
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_sum 9
h_count 5
";
        assert!(lint_exposition(text).unwrap_err().contains("does not end at le"));
    }

    #[test]
    fn lint_rejects_inf_count_mismatch() {
        let text = "\
# HELP h latency
# TYPE h histogram
h_bucket{le=\"+Inf\"} 4
h_sum 9
h_count 5
";
        assert!(lint_exposition(text).unwrap_err().contains("!= _count"));
    }

    #[test]
    fn lint_rejects_histogram_suffix_collision() {
        let text = "\
# HELP h latency
# TYPE h histogram
# HELP h_count inflight
# TYPE h_count counter
h_bucket{le=\"+Inf\"} 0
h_sum 0
h_count 0
";
        assert!(lint_exposition(text).unwrap_err().contains("collides with histogram"));
    }

    #[test]
    fn process_counters_render_and_lint() {
        let m = Metrics::new();
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE islandrun_lock_poison_recoveries_total counter"), "{text}");
        assert!(text.contains("# TYPE islandrun_telemetry_register_conflicts_total counter"), "{text}");
        assert!(text.contains("islandrun_telemetry_register_conflicts_total 0"), "{text}");
        lint_exposition(&text).unwrap();
    }

    #[test]
    fn conflicting_registration_is_counted_in_the_exposition() {
        let m = Metrics::new();
        m.register_counter("depth", "a counter").inc();
        m.register_gauge("depth", "now a gauge?").set(4.0); // kind conflict: detached
        let text = m.render_prometheus();
        assert!(text.contains("islandrun_telemetry_register_conflicts_total 1"), "{text}");
        assert!(text.contains("islandrun_depth_total 1"), "{text}");
        assert!(!text.contains("islandrun_depth 4"), "detached gauge must not render: {text}");
        lint_exposition(&text).unwrap();
    }

    #[test]
    fn lint_rejects_duplicate_series_and_bad_escape() {
        let dup = "# HELP c_total n\n# TYPE c_total counter\nc_total{a=\"x\"} 1\nc_total{a=\"x\"} 2\n";
        assert!(lint_exposition(dup).unwrap_err().contains("duplicate series"));
        let bad = "# HELP c_total n\n# TYPE c_total counter\nc_total{a=\"x\\q\"} 1\n";
        assert!(lint_exposition(bad).unwrap_err().contains("bad escape"));
    }
}
