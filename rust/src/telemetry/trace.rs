//! Request-scoped distributed tracing: span trees, tail-based sampling, and
//! W3C `traceparent` context propagation.
//!
//! Every request gets one **root span** opened at admission (`enqueue` or the
//! HTTP submit handler) and closed at exactly one [`crate::server::Resolution`]
//! terminal. Stage-level child spans (queue wait, WAVES routing, MIST
//! sanitize, failover hops, prefill, decode, SSE relay) hang off that root, so
//! a slow request can be attributed to the stage that burned its deadline
//! instead of an aggregate histogram.
//!
//! Design constraints, in order:
//!
//! * **Typed context, no thread-locals.** [`TraceContext`] is a cheap
//!   cloneable handle threaded through `SubmitRequest` and the worker
//!   plumbing. A context that was never started (tracing disabled, or the
//!   request predates the sink) is a no-op: every method tolerates it.
//! * **Deterministic ids.** Trace and span ids come from the seeded
//!   [`crate::util::Rng`] — never wall-clock entropy — so Sim runs reproduce
//!   byte-identical trace files.
//! * **Tail-based sampling.** The keep/drop decision happens when the trace
//!   *finishes*: shed, cancelled, and failed requests are always kept, as are
//!   traces slower than the running p90 of recent durations (the "slowest
//!   decile"); ordinary served traces survive only a head-sampling coin
//!   flipped at root creation ([`TraceConfig::head_rate`]).
//! * **Bounded memory.** Kept traces land in a ring of
//!   [`TraceConfig::ring_capacity`] entries; the oldest are evicted first.
//!
//! Exporters (Chrome `trace_event` JSON and JSONL) live in
//! [`crate::telemetry::traceout`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::config::json::Json;
use crate::util::sync::LockExt;
use crate::util::Rng;

/// Sliding window of recent trace durations used for the slowest-decile rule.
const DURATION_WINDOW: usize = 256;

/// Minimum samples before the slow-trace threshold activates; below this the
/// threshold is `+inf` (nothing is "slow" until there is a population).
const SLOW_MIN_SAMPLES: usize = 20;

/// 128-bit trace identifier (W3C `trace-id`, 32 lowercase hex chars).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u128);

impl TraceId {
    /// Canonical 32-char lowercase hex form.
    pub fn to_hex(self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse the canonical form. Rejects wrong length, uppercase, non-hex,
    /// and the all-zero id (invalid per the W3C spec).
    pub fn from_hex(s: &str) -> Option<TraceId> {
        if s.len() != 32 || !s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
            return None;
        }
        match u128::from_str_radix(s, 16) {
            Ok(0) | Err(_) => None,
            Ok(v) => Some(TraceId(v)),
        }
    }
}

/// 64-bit span identifier (W3C `parent-id`, 16 lowercase hex chars).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SpanId(pub u64);

impl SpanId {
    /// Canonical 16-char lowercase hex form.
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse the canonical form (rejects uppercase, bad length, all-zero).
    pub fn from_hex(s: &str) -> Option<SpanId> {
        if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
            return None;
        }
        match u64::from_str_radix(s, 16) {
            Ok(0) | Err(_) => None,
            Ok(v) => Some(SpanId(v)),
        }
    }
}

/// Parse a W3C `traceparent` header value: `00-<trace-id>-<parent-id>-<flags>`.
///
/// Strict on shape (version 00, exact field lengths, lowercase hex, non-zero
/// ids) but callers are expected to **fail open**: a `None` here means "mint a
/// fresh root", never "reject the request".
pub fn parse_traceparent(value: &str) -> Option<(TraceId, SpanId)> {
    let mut parts = value.trim().split('-');
    let version = parts.next()?;
    let trace = parts.next()?;
    let span = parts.next()?;
    let flags = parts.next()?;
    if parts.next().is_some() || version != "00" {
        return None;
    }
    if flags.len() != 2 || !flags.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
        return None;
    }
    Some((TraceId::from_hex(trace)?, SpanId::from_hex(span)?))
}

/// Render a `traceparent` header value (version 00, sampled flag set).
pub fn format_traceparent(trace: TraceId, span: SpanId) -> String {
    format!("00-{}-{}-01", trace.to_hex(), span.to_hex())
}

/// One recorded interval inside a trace. Child spans carry the root as their
/// parent; the root's own parent is the remote span from an inbound
/// `traceparent`, if any.
#[derive(Clone, Debug)]
pub struct Span {
    pub id: SpanId,
    pub parent: Option<SpanId>,
    pub name: &'static str,
    pub start_ms: f64,
    pub end_ms: f64,
    pub attrs: Vec<(&'static str, Json)>,
}

/// Sampling and capacity knobs, mirrored from [`crate::config::Config`].
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Master switch; when false every started context is a no-op.
    pub enabled: bool,
    /// Head-sampling keep probability for ordinary served traces, in [0, 1].
    /// `1.0` is "always" (the setting the consistency stress forces).
    pub head_rate: f64,
    /// Completed-trace ring size; oldest kept traces are evicted first.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: true, head_rate: 1.0, ring_capacity: 512 }
    }
}

/// Mutable per-trace state behind the context's mutex.
struct TraceState {
    rng: Rng,
    user: String,
    spans: Vec<Span>,
    end_ms: f64,
    outcome: &'static str,
    reason: &'static str,
    finished: bool,
    kept: bool,
}

/// Shared body of one live trace. Held via `Arc` by every context clone and —
/// once finished and kept — by the sink's ring, so late spans (the SSE relay
/// records after the terminal fires) still attach to the exported tree.
struct TraceInner {
    trace_id: TraceId,
    root_id: SpanId,
    remote_parent: Option<SpanId>,
    start_ms: f64,
    head_keep: bool,
    sink: Weak<TraceSink>,
    state: Mutex<TraceState>,
}

impl TraceInner {
    fn materialize(&self) -> CompletedTrace {
        let st = self.state.lock_clean();
        CompletedTrace {
            trace_id: self.trace_id,
            user: st.user.clone(),
            outcome: st.outcome,
            reason: st.reason,
            root: Span {
                id: self.root_id,
                parent: self.remote_parent,
                name: "request",
                start_ms: self.start_ms,
                end_ms: st.end_ms,
                attrs: Vec::new(),
            },
            spans: st.spans.clone(),
        }
    }
}

/// Cheap cloneable handle to one request's trace. `Default` (and a context
/// from a disabled sink) is inert: every method is a no-op returning `None`.
#[derive(Clone, Default)]
pub struct TraceContext {
    inner: Option<Arc<TraceInner>>,
}

impl std::fmt::Debug for TraceContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.inner.as_ref() {
            Some(inner) => write!(f, "TraceContext({})", inner.trace_id.to_hex()),
            None => write!(f, "TraceContext(none)"),
        }
    }
}

impl TraceContext {
    /// The inert context: carries no trace, records nothing.
    pub fn none() -> TraceContext {
        TraceContext::default()
    }

    /// True when a root span is open (or was opened) behind this handle.
    pub fn is_active(&self) -> bool {
        self.inner.is_some()
    }

    /// The trace id, if active.
    pub fn trace_id(&self) -> Option<TraceId> {
        self.inner.as_ref().map(|i| i.trace_id)
    }

    /// Hex trace id, if active (the form events/audit/export all use).
    pub fn trace_hex(&self) -> Option<String> {
        self.inner.as_ref().map(|i| i.trace_id.to_hex())
    }

    /// `traceparent` value identifying this request's root span — what the
    /// HTTP layer echoes back so external callers can correlate.
    pub fn traceparent(&self) -> Option<String> {
        self.inner.as_ref().map(|i| format_traceparent(i.trace_id, i.root_id))
    }

    /// Stamp the owning user (first writer wins). Used by the HTTP layer for
    /// tenant isolation on `GET /v1/traces/:id`.
    pub fn set_user(&self, user: &str) {
        if let Some(inner) = self.inner.as_ref() {
            let mut st = inner.state.lock_clean();
            if st.user.is_empty() {
                st.user = user.to_string();
            }
        }
    }

    /// Record one completed child interval under the root. Timestamps are
    /// virtual-clock ms from the orchestrator (never wall time in Sim);
    /// `end_ms` is clamped to `start_ms` so spans are never negative.
    pub fn add_span(
        &self,
        name: &'static str,
        start_ms: f64,
        end_ms: f64,
        attrs: Vec<(&'static str, Json)>,
    ) {
        let Some(inner) = self.inner.as_ref() else {
            return;
        };
        let mut st = inner.state.lock_clean();
        let id = next_span_id(&mut st.rng);
        st.spans.push(Span {
            id,
            parent: Some(inner.root_id),
            name,
            start_ms,
            end_ms: end_ms.max(start_ms),
            attrs,
        });
    }

    /// Close the root span at a `Resolution` terminal and run the tail
    /// sampling decision. Returns the hex trace id when the trace was kept
    /// (what `RequestEvent`/`AuditEntry` carry), `None` when sampling dropped
    /// it or the context is inert. Idempotent: the first terminal wins and
    /// later calls replay its answer, so double-resolve races cannot record a
    /// trace twice.
    ///
    /// Every non-test `Resolution` terminal site in `server/` must call this
    /// (enforced by islandlint R6 `span-discipline`).
    pub fn end_request_span(
        &self,
        end_ms: f64,
        outcome: &'static str,
        reason: &'static str,
    ) -> Option<String> {
        let inner = self.inner.as_ref()?;
        let mut st = inner.state.lock_clean();
        if st.finished {
            return if st.kept { Some(inner.trace_id.to_hex()) } else { None };
        }
        st.finished = true;
        st.end_ms = end_ms.max(inner.start_ms);
        st.outcome = outcome;
        st.reason = reason;
        let Some(sink) = inner.sink.upgrade() else {
            return None;
        };
        let duration = st.end_ms - inner.start_ms;
        let slow = duration > sink.note_duration(duration);
        let keep = outcome != "served" || inner.head_keep || slow;
        st.kept = keep;
        drop(st);
        if keep {
            sink.keep(Arc::clone(inner));
            Some(inner.trace_id.to_hex())
        } else {
            sink.sampled_out.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Owner of completed traces: mints contexts, applies the tail-sampling
/// policy, and holds the bounded ring the exporters and `GET /v1/traces/:id`
/// read from.
pub struct TraceSink {
    cfg: TraceConfig,
    rng: Mutex<Rng>,
    ring: Mutex<VecDeque<Arc<TraceInner>>>,
    durations: Mutex<VecDeque<f64>>,
    /// f64 bit-pattern of the current slowest-decile threshold.
    slow_thr: AtomicU64,
    started: AtomicU64,
    kept_total: AtomicU64,
    sampled_out: AtomicU64,
}

impl TraceSink {
    pub fn new(cfg: TraceConfig, seed: u64) -> Arc<TraceSink> {
        Arc::new(TraceSink {
            cfg,
            rng: Mutex::new(Rng::new(seed ^ 0x7452_4143_4553_4e4b)),
            ring: Mutex::new(VecDeque::new()),
            durations: Mutex::new(VecDeque::new()),
            slow_thr: AtomicU64::new(f64::INFINITY.to_bits()),
            started: AtomicU64::new(0),
            kept_total: AtomicU64::new(0),
            sampled_out: AtomicU64::new(0),
        })
    }

    /// Open a new root span at `now_ms`. `remote` carries a validated inbound
    /// `traceparent` pair: the trace id is adopted and the remote span becomes
    /// the root's parent. Returns the inert context when tracing is disabled.
    pub fn start(sink: &Arc<TraceSink>, now_ms: f64, remote: Option<(TraceId, SpanId)>) -> TraceContext {
        if !sink.cfg.enabled {
            return TraceContext::none();
        }
        let (trace_id, root_id, head_keep, trace_rng) = {
            let mut rng = sink.rng.lock_clean();
            let trace_id = match remote {
                Some((t, _)) => t,
                None => next_trace_id(&mut rng),
            };
            let root_id = next_span_id(&mut rng);
            let head_keep = rng.chance(sink.cfg.head_rate);
            (trace_id, root_id, head_keep, rng.fork())
        };
        sink.started.fetch_add(1, Ordering::Relaxed);
        TraceContext {
            inner: Some(Arc::new(TraceInner {
                trace_id,
                root_id,
                remote_parent: remote.map(|(_, s)| s),
                start_ms: now_ms,
                head_keep,
                sink: Arc::downgrade(sink),
                state: Mutex::new(TraceState {
                    rng: trace_rng,
                    user: String::new(),
                    spans: Vec::new(),
                    end_ms: now_ms,
                    outcome: "open",
                    reason: "open",
                    finished: false,
                    kept: false,
                }),
            })),
        }
    }

    /// Reuse an already-started context (the HTTP layer starts traces at
    /// submit time) or open a fresh root for direct `enqueue` callers.
    pub fn adopt_or_start(
        sink: &Arc<TraceSink>,
        existing: &TraceContext,
        now_ms: f64,
    ) -> TraceContext {
        if existing.is_active() {
            existing.clone()
        } else {
            TraceSink::start(sink, now_ms, None)
        }
    }

    /// Note a completed duration in the sliding window and return the
    /// refreshed slowest-decile threshold (`+inf` until enough samples).
    fn note_duration(&self, duration_ms: f64) -> f64 {
        let mut ds = self.durations.lock_clean();
        ds.push_back(duration_ms);
        if ds.len() > DURATION_WINDOW {
            ds.pop_front();
        }
        let thr = if ds.len() < SLOW_MIN_SAMPLES {
            f64::INFINITY
        } else {
            let mut sorted: Vec<f64> = ds.iter().copied().collect();
            sorted.sort_by(|a, b| a.total_cmp(b));
            sorted[(sorted.len() * 9) / 10]
        };
        self.slow_thr.store(thr.to_bits(), Ordering::Relaxed);
        thr
    }

    fn keep(&self, inner: Arc<TraceInner>) {
        self.kept_total.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.ring.lock_clean();
        ring.push_back(inner);
        while ring.len() > self.cfg.ring_capacity.max(1) {
            ring.pop_front();
        }
    }

    /// The active sampling/capacity configuration.
    pub fn config(&self) -> TraceConfig {
        self.cfg
    }

    /// True when tracing is on (contexts will actually record).
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Root spans opened so far.
    pub fn started(&self) -> u64 {
        self.started.load(Ordering::Relaxed)
    }

    /// Traces retained by the tail policy (including ones since evicted).
    pub fn kept(&self) -> u64 {
        self.kept_total.load(Ordering::Relaxed)
    }

    /// Served traces dropped by sampling (these are the `trace_id: None`
    /// rows in the event and audit logs).
    pub fn sampled_out(&self) -> u64 {
        self.sampled_out.load(Ordering::Relaxed)
    }

    /// Kept traces currently resident in the ring.
    pub fn len(&self) -> usize {
        self.ring.lock_clean().len()
    }

    /// True when no trace is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up one kept trace by id (newest wins on adoption collisions).
    pub fn get(&self, id: TraceId) -> Option<CompletedTrace> {
        self.ring.lock_clean().iter().rev().find(|t| t.trace_id == id).map(|t| t.materialize())
    }

    /// Materialize every resident trace, oldest first (export order).
    pub fn snapshot(&self) -> Vec<CompletedTrace> {
        self.ring.lock_clean().iter().map(|t| t.materialize()).collect()
    }
}

/// An immutable, export-ready view of one kept trace.
#[derive(Clone, Debug)]
pub struct CompletedTrace {
    pub trace_id: TraceId,
    pub user: String,
    pub outcome: &'static str,
    pub reason: &'static str,
    pub root: Span,
    pub spans: Vec<Span>,
}

impl CompletedTrace {
    /// End-to-end latency of the request (root span width).
    pub fn duration_ms(&self) -> f64 {
        (self.root.end_ms - self.root.start_ms).max(0.0)
    }
}

fn next_trace_id(rng: &mut Rng) -> TraceId {
    loop {
        let v = ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
        if v != 0 {
            return TraceId(v);
        }
    }
}

fn next_span_id(rng: &mut Rng) -> SpanId {
    loop {
        let v = rng.next_u64();
        if v != 0 {
            return SpanId(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink_with(head_rate: f64, ring_capacity: usize) -> Arc<TraceSink> {
        TraceSink::new(TraceConfig { enabled: true, head_rate, ring_capacity }, 7)
    }

    #[test]
    fn traceparent_round_trips() {
        let sink = sink_with(1.0, 8);
        let ctx = TraceSink::start(&sink, 0.0, None);
        let header = ctx.traceparent().unwrap();
        let (tid, sid) = parse_traceparent(&header).unwrap();
        assert_eq!(Some(tid), ctx.trace_id());
        assert_eq!(format_traceparent(tid, sid), header);
        assert_eq!(header.len(), 55);
    }

    #[test]
    fn traceparent_rejects_malformed() {
        let good = "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01";
        assert!(parse_traceparent(good).is_some());
        for bad in [
            "",
            "garbage",
            "01-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01",
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331",
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01-extra",
            "00-0AF7651916CD43DD8448EB211C80319C-b7ad6b7169203331-01",
            "00-0af7651916cd43dd8448eb211c80319-b7ad6b7169203331-01",
            "00-00000000000000000000000000000000-b7ad6b7169203331-01",
            "00-0af7651916cd43dd8448eb211c80319c-0000000000000000-01",
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b716920333g-01",
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-0g",
        ] {
            assert!(parse_traceparent(bad).is_none(), "should reject {bad:?}");
        }
    }

    #[test]
    fn ids_are_deterministic_and_nonzero() {
        let a = sink_with(1.0, 8);
        let b = sink_with(1.0, 8);
        for _ in 0..16 {
            let ca = TraceSink::start(&a, 0.0, None);
            let cb = TraceSink::start(&b, 0.0, None);
            assert_eq!(ca.trace_hex(), cb.trace_hex(), "same seed, same ids");
            assert_ne!(ca.trace_id().unwrap().0, 0);
        }
    }

    #[test]
    fn remote_parent_is_adopted() {
        let sink = sink_with(1.0, 8);
        let remote = "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01";
        let pair = parse_traceparent(remote).unwrap();
        let ctx = TraceSink::start(&sink, 1.0, Some(pair));
        assert_eq!(ctx.trace_hex().unwrap(), "0af7651916cd43dd8448eb211c80319c");
        let id = ctx.end_request_span(2.0, "failed", "execution_error").unwrap();
        let got = sink.get(TraceId::from_hex(&id).unwrap()).unwrap();
        assert_eq!(got.root.parent, Some(pair.1), "root keeps the remote span as parent");
    }

    #[test]
    fn tail_policy_always_keeps_non_served() {
        let sink = sink_with(0.0, 64);
        for (outcome, reason) in
            [("shed", "queue_full"), ("cancelled", "mid_decode"), ("failed", "fail_closed")]
        {
            let ctx = TraceSink::start(&sink, 0.0, None);
            assert!(ctx.end_request_span(1.0, outcome, reason).is_some());
        }
        // fast served traces at head_rate 0 are dropped
        let ctx = TraceSink::start(&sink, 0.0, None);
        assert!(ctx.end_request_span(1.0, "served", "ok").is_none());
        assert_eq!(sink.kept(), 3);
        assert_eq!(sink.sampled_out(), 1);
    }

    #[test]
    fn tail_policy_keeps_slowest_decile() {
        let sink = sink_with(0.0, 256);
        let mut kept = Vec::new();
        for i in 1..=40u32 {
            let ctx = TraceSink::start(&sink, 0.0, None);
            if ctx.end_request_span(f64::from(i), "served", "ok").is_some() {
                kept.push(i);
            }
        }
        // threshold is +inf until SLOW_MIN_SAMPLES; after that each strictly
        // slower duration clears the running p90 and is kept
        assert!(kept.iter().all(|&i| (i as usize) >= SLOW_MIN_SAMPLES));
        assert!(kept.contains(&40), "the slowest trace must be kept");
        assert!(!kept.is_empty() && kept.len() < 40);
    }

    #[test]
    fn head_sampling_keeps_served_at_rate_one() {
        let sink = sink_with(1.0, 64);
        let ctx = TraceSink::start(&sink, 0.0, None);
        let id = ctx.end_request_span(5.0, "served", "ok").unwrap();
        let trace = sink.get(TraceId::from_hex(&id).unwrap()).unwrap();
        assert_eq!(trace.outcome, "served");
        assert_eq!(trace.reason, "ok");
        assert!((trace.duration_ms() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ring_is_bounded_and_evicts_oldest() {
        let sink = sink_with(0.0, 4);
        let mut ids = Vec::new();
        for _ in 0..10 {
            let ctx = TraceSink::start(&sink, 0.0, None);
            ids.push(ctx.end_request_span(1.0, "failed", "fail_closed").unwrap());
        }
        assert_eq!(sink.len(), 4);
        assert!(sink.get(TraceId::from_hex(&ids[0]).unwrap()).is_none(), "oldest evicted");
        assert!(sink.get(TraceId::from_hex(&ids[9]).unwrap()).is_some());
        assert_eq!(sink.kept(), 10, "kept counts retention decisions, not residency");
    }

    #[test]
    fn disabled_sink_yields_inert_contexts() {
        let sink = TraceSink::new(TraceConfig { enabled: false, ..TraceConfig::default() }, 7);
        let ctx = TraceSink::start(&sink, 0.0, None);
        assert!(!ctx.is_active());
        assert!(ctx.trace_hex().is_none());
        assert!(ctx.traceparent().is_none());
        ctx.add_span("route", 0.0, 1.0, vec![]);
        assert!(ctx.end_request_span(1.0, "served", "ok").is_none());
        assert_eq!(sink.started(), 0);
        assert!(sink.is_empty());
    }

    #[test]
    fn end_is_idempotent_first_terminal_wins() {
        let sink = sink_with(1.0, 8);
        let ctx = TraceSink::start(&sink, 0.0, None);
        let first = ctx.end_request_span(3.0, "cancelled", "mid_decode");
        let second = ctx.end_request_span(9.0, "served", "ok");
        assert_eq!(first, second, "replay returns the original decision");
        assert_eq!(sink.kept(), 1);
        let trace = sink.snapshot().pop().unwrap();
        assert_eq!(trace.reason, "mid_decode");
        assert!((trace.duration_ms() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn late_spans_attach_after_finish() {
        let sink = sink_with(1.0, 8);
        let ctx = TraceSink::start(&sink, 0.0, None);
        ctx.set_user("alice");
        ctx.add_span("queue_wait", 0.0, 2.0, vec![("depth", Json::num(3.0))]);
        let id = ctx.end_request_span(5.0, "served", "ok").unwrap();
        // the SSE relay records after the terminal resolves the ticket
        ctx.add_span("sse_relay", 5.0, 6.0, vec![("events", Json::num(4.0))]);
        let trace = sink.get(TraceId::from_hex(&id).unwrap()).unwrap();
        assert_eq!(trace.user, "alice");
        let names: Vec<&str> = trace.spans.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["queue_wait", "sse_relay"]);
        assert!(trace.spans.iter().all(|s| s.parent == Some(trace.root.id)));
    }

    #[test]
    fn set_user_first_writer_wins() {
        let sink = sink_with(1.0, 8);
        let ctx = TraceSink::start(&sink, 0.0, None);
        ctx.set_user("alice");
        ctx.set_user("mallory");
        ctx.end_request_span(1.0, "failed", "session_closed");
        assert_eq!(sink.snapshot().pop().unwrap().user, "alice");
    }

    #[test]
    fn adopt_or_start_reuses_active_contexts() {
        let sink = sink_with(1.0, 8);
        let started = TraceSink::start(&sink, 0.0, None);
        let adopted = TraceSink::adopt_or_start(&sink, &started, 4.0);
        assert_eq!(started.trace_hex(), adopted.trace_hex());
        let fresh = TraceSink::adopt_or_start(&sink, &TraceContext::none(), 4.0);
        assert!(fresh.is_active());
        assert_ne!(fresh.trace_hex(), started.trace_hex());
    }

    #[test]
    fn span_ends_clamp_to_start() {
        let sink = sink_with(1.0, 8);
        let ctx = TraceSink::start(&sink, 10.0, None);
        ctx.add_span("route", 5.0, 3.0, vec![]);
        ctx.end_request_span(4.0, "shed", "deadline_expired");
        let trace = sink.snapshot().pop().unwrap();
        assert!(trace.duration_ms() >= 0.0);
        assert!(trace.spans[0].end_ms >= trace.spans[0].start_ms);
    }
}
