//! Lock-free histogram: the same log-scaled bucket grid as
//! [`crate::util::Histogram`], but with atomic bucket counters so recording
//! a sample from the serving hot path never takes a lock. Queries snapshot
//! into the plain [`Histogram`] so all percentile/summary code is shared.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::stats;
use crate::util::{AtomicF64, Histogram};

/// Concurrent histogram over positive f64 samples (e.g. milliseconds).
///
/// `record` is wait-free apart from two short CAS loops maintaining min/max;
/// bucket, count and sum updates are single atomic adds. Relaxed ordering is
/// enough: readers only consume full snapshots, and a snapshot racing a
/// record may miss at most the in-flight sample (counts stay consistent with
/// the buckets actually copied because `count` is re-derived per bucket on
/// merge-free queries — see `snapshot`).
pub struct AtomicHistogram {
    /// bucket i covers the same [lo, hi) range as `util::Histogram` bucket i
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicF64,
    min: AtomicF64,
    max: AtomicF64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        AtomicHistogram {
            buckets: (0..stats::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicF64::new(0.0),
            min: AtomicF64::new(f64::INFINITY),
            max: AtomicF64::new(f64::NEG_INFINITY),
        }
    }

    /// Record one sample. Non-finite or negative samples are clamped to 0,
    /// matching [`Histogram::record`] (they still count).
    pub fn record(&self, x: f64) {
        let x = if x.is_finite() && x > 0.0 { x } else { 0.0 };
        self.buckets[stats::bucket_index(x)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(x);
        // CAS loops terminate fast: each retry means another thread moved the
        // extremum strictly toward (or past) ours.
        loop {
            let cur = self.min.load();
            if x >= cur || self.min.compare_exchange(cur, x) {
                break;
            }
        }
        loop {
            let cur = self.max.load();
            if x <= cur || self.max.compare_exchange(cur, x) {
                break;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the atomic state into a plain [`Histogram`] for querying.
    ///
    /// Taken concurrently with `record`, the snapshot is a consistent recent
    /// state up to in-flight samples: count is re-derived from the copied
    /// buckets so `count()` always equals the bucket total.
    pub fn snapshot(&self) -> Histogram {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        let (min, max) = if count == 0 {
            (f64::INFINITY, f64::NEG_INFINITY)
        } else {
            (self.min.load(), self.max.load())
        };
        Histogram::from_parts(buckets, count, self.sum.load(), min, max)
    }

    /// Zero in place (between experiment repetitions); racing records land in
    /// the zeroed cells rather than an orphaned histogram.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0.0);
        self.min.store(f64::INFINITY);
        self.max.store(f64::NEG_INFINITY);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_plain_histogram() {
        let a = AtomicHistogram::new();
        let mut h = Histogram::new();
        let mut r = crate::util::Rng::new(7);
        for _ in 0..5000 {
            let x = r.range_f64(0.5, 800.0);
            a.record(x);
            h.record(x);
        }
        let s = a.snapshot();
        assert_eq!(s.count(), h.count());
        assert!((s.mean() - h.mean()).abs() < 1e-9);
        assert_eq!(s.p50(), h.p50());
        assert_eq!(s.p99(), h.p99());
        assert_eq!(s.min(), h.min());
        assert_eq!(s.max(), h.max());
    }

    #[test]
    fn degenerate_samples_clamp_like_plain() {
        let a = AtomicHistogram::new();
        a.record(f64::NAN);
        a.record(-5.0);
        let s = a.snapshot();
        assert_eq!(s.count(), 2);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = AtomicHistogram::new().snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn concurrent_records_all_land() {
        use std::sync::Arc;
        let a = Arc::new(AtomicHistogram::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for i in 0..2000 {
                        a.record((t * 2000 + i) as f64 * 0.01 + 0.01);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = a.snapshot();
        assert_eq!(s.count(), 16_000);
        assert!(s.min() > 0.0 && s.max() < 161.0);
    }

    #[test]
    fn reset_zeroes_in_place() {
        let a = AtomicHistogram::new();
        a.record(5.0);
        a.reset();
        assert_eq!(a.snapshot().count(), 0);
        a.record(2.0);
        let s = a.snapshot();
        assert_eq!(s.count(), 1);
        assert_eq!(s.max(), 2.0);
    }
}
