//! Pre-registered serving-path metrics: every counter, gauge and histogram
//! the orchestrator touches per request, resolved to typed handles once at
//! construction. A request's hot path then performs only atomic bumps —
//! no name lookups, no registry locks, no allocation.
//!
//! Label conventions (see the README "Observability" section):
//! * `island` — `island-N` (the [`crate::types::IslandId`] display form);
//! * `tier` — [`crate::types::TrustTier::name`]: `personal` /
//!   `private-edge` / `cloud`;
//! * `privacy` — the island's privacy score, fixed to two decimals;
//! * `outcome` / `reason` — [`Resolution::class`] / [`Resolution::reason`].

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use crate::server::Resolution;

use super::{Counter, CounterVec, Gauge, Hist, HistogramVec, Metrics};

use crate::util::sync::RwLockExt;

/// Cached cells for one island's per-island series. Resolved at routing
/// time and carried with the prepared request, so recording a served
/// request's latency is a single atomic histogram insert.
pub struct IslandCells {
    /// `island_latency_ms{island,tier,privacy}` — end-to-end latency of
    /// requests served by this island.
    pub latency_ms: Hist,
    /// `served_by_island{island,tier,privacy}` — requests served.
    pub served: Counter,
}

/// One pre-resolved counter per [`Resolution`] variant — the
/// `requests_resolved{outcome,reason}` family without any per-request
/// lookup.
pub struct ResolvedCells {
    served: Counter,
    shed_queue_full: Counter,
    shed_deadline_expired: Counter,
    shed_invalid_request: Counter,
    shed_rate_limited: Counter,
    shed_worker_panic: Counter,
    shed_shutdown: Counter,
    cancelled_while_queued: Counter,
    cancelled_before_execution: Counter,
    cancelled_mid_decode: Counter,
    cancelled_deadline_mid_decode: Counter,
    failed_fail_closed: Counter,
    failed_failover_exhausted: Counter,
    failed_execution_error: Counter,
    failed_session_closed: Counter,
}

impl ResolvedCells {
    fn register(vec: &CounterVec) -> Self {
        let cell = |r: Resolution| vec.with(&[r.class(), r.reason()]);
        use crate::server::{CancelPoint as C, FailReason as F, ShedReason as S};
        ResolvedCells {
            served: cell(Resolution::Served),
            shed_queue_full: cell(Resolution::Shed(S::QueueFull)),
            shed_deadline_expired: cell(Resolution::Shed(S::DeadlineExpired)),
            shed_invalid_request: cell(Resolution::Shed(S::InvalidRequest)),
            shed_rate_limited: cell(Resolution::Shed(S::RateLimited)),
            shed_worker_panic: cell(Resolution::Shed(S::WorkerPanic)),
            shed_shutdown: cell(Resolution::Shed(S::Shutdown)),
            cancelled_while_queued: cell(Resolution::Cancelled(C::WhileQueued)),
            cancelled_before_execution: cell(Resolution::Cancelled(C::BeforeExecution)),
            cancelled_mid_decode: cell(Resolution::Cancelled(C::MidDecode)),
            cancelled_deadline_mid_decode: cell(Resolution::Cancelled(C::DeadlineMidDecode)),
            failed_fail_closed: cell(Resolution::Failed(F::FailClosed)),
            failed_failover_exhausted: cell(Resolution::Failed(F::FailoverExhausted)),
            failed_execution_error: cell(Resolution::Failed(F::ExecutionError)),
            failed_session_closed: cell(Resolution::Failed(F::SessionClosed)),
        }
    }

    /// The counter for one resolution — a direct field match, no lookup.
    pub fn of(&self, r: Resolution) -> &Counter {
        use crate::server::{CancelPoint as C, FailReason as F, ShedReason as S};
        match r {
            Resolution::Served => &self.served,
            Resolution::Shed(S::QueueFull) => &self.shed_queue_full,
            Resolution::Shed(S::DeadlineExpired) => &self.shed_deadline_expired,
            Resolution::Shed(S::InvalidRequest) => &self.shed_invalid_request,
            Resolution::Shed(S::RateLimited) => &self.shed_rate_limited,
            Resolution::Shed(S::WorkerPanic) => &self.shed_worker_panic,
            Resolution::Shed(S::Shutdown) => &self.shed_shutdown,
            Resolution::Cancelled(C::WhileQueued) => &self.cancelled_while_queued,
            Resolution::Cancelled(C::BeforeExecution) => &self.cancelled_before_execution,
            Resolution::Cancelled(C::MidDecode) => &self.cancelled_mid_decode,
            Resolution::Cancelled(C::DeadlineMidDecode) => &self.cancelled_deadline_mid_decode,
            Resolution::Failed(F::FailClosed) => &self.failed_fail_closed,
            Resolution::Failed(F::FailoverExhausted) => &self.failed_failover_exhausted,
            Resolution::Failed(F::ExecutionError) => &self.failed_execution_error,
            Resolution::Failed(F::SessionClosed) => &self.failed_session_closed,
        }
    }
}

/// Every serving-path metric, pre-registered against one [`Metrics`]
/// registry. Legacy string-keyed reads (`counter_value("requests_served")`
/// etc.) keep working because handles share cells with the name table.
pub struct ServingMetrics {
    // admission + queue
    pub rate_limited: Counter,
    /// `rejected_rate_limited` — rate-limit refusals shed with a typed
    /// [`Resolution::Shed`] on the non-blocking path. The HTTP front door
    /// re-registers the same name and bumps the same cell on 429s.
    pub rejected_rate_limited: Counter,
    pub enqueued: Counter,
    pub rejected_queue_full: Counter,
    pub shed_deadline_expired: Counter,
    pub rejected_invalid_request: Counter,
    pub queue_depth: Gauge,
    pub queue_wait_ms: Hist,
    // routing + sanitization
    pub rejected_fail_closed: Counter,
    pub local_capacity: Gauge,
    pub mist_s_r: Hist,
    pub sanitized_requests: Counter,
    pub sanitized_turns: Counter,
    pub sanitized_turns_reused: Counter,
    // execution + failover
    pub execution_failed: Counter,
    pub failovers: Counter,
    pub failover_successes: Counter,
    pub rejected_failover_exhausted: Counter,
    pub batch_groups: Counter,
    pub batch_group_size: Hist,
    pub batch_occupancy: Hist,
    pub steady_state_batch_occupancy: Gauge,
    pub step_drive_panics: Counter,
    pub queue_drain_panics: Counter,
    // resolution
    pub requests_served: Counter,
    pub requests_cancelled: Counter,
    pub cancelled_while_queued: Counter,
    pub cancelled_before_execution: Counter,
    pub cancelled_mid_decode: Counter,
    pub cancelled_deadline_mid_decode: Counter,
    pub cancelled_tokens_decoded: Hist,
    pub ticket_double_resolved: Counter,
    pub latency_ms: Hist,
    pub cost_usd: Hist,
    /// `requests_resolved{outcome,reason}` — exactly one bump per resolved
    /// request id; the consistency stress test pins Σ(children) == tickets
    /// resolved.
    pub resolved: ResolvedCells,
    // fleet churn
    pub island_crashes: Counter,
    pub island_revives: Counter,
    pub island_joins: Counter,
    pub island_leaves: Counter,
    pub islands_degraded: Counter,
    pub islands_recovered: Counter,
    // per-island labeled families (children resolved lazily per island and
    // cached so routing pays one lookup per request, resolution pays none)
    island_latency: HistogramVec,
    served_by_island: CounterVec,
    failovers_by_island: CounterVec,
    island_cells: RwLock<BTreeMap<u32, Arc<IslandCells>>>,
    failover_cells: RwLock<BTreeMap<u32, Counter>>,
}

impl ServingMetrics {
    pub fn register(m: &Metrics) -> ServingMetrics {
        let c = |name: &str, help: &str| m.register_counter(name, help);
        let g = |name: &str, help: &str| m.register_gauge(name, help);
        let h = |name: &str, help: &str| m.register_histogram(name, help);
        ServingMetrics {
            rate_limited: c("rate_limited", "requests refused by the per-user rate limiter"),
            rejected_rate_limited: c(
                "rejected_rate_limited",
                "requests shed with a typed resolution by the per-user rate limiter",
            ),
            enqueued: c("enqueued", "requests accepted into the admission queue"),
            rejected_queue_full: c("rejected_queue_full", "requests shed because the admission queue was full"),
            shed_deadline_expired: c(
                "shed_deadline_expired",
                "requests shed at drain time: deadline expired while queued",
            ),
            rejected_invalid_request: c("rejected_invalid_request", "requests rejected by submit-time validation"),
            queue_depth: g("queue_depth", "admission queue depth at the last enqueue/drain"),
            queue_wait_ms: h("queue_wait_ms", "time spent parked in the admission queue (ms)"),
            rejected_fail_closed: c(
                "rejected_fail_closed",
                "requests rejected fail-closed: no island satisfied the constraints",
            ),
            local_capacity: g("local_capacity", "aggregate local capacity R(t) at the last routing pass"),
            mist_s_r: h("mist_s_r", "MIST sensitivity score s_r after floor clamping"),
            sanitized_requests: c(
                "sanitized_requests",
                "requests whose history was sanitized for a trust-boundary crossing",
            ),
            sanitized_turns: c("sanitized_turns", "conversation turns rewritten by MIST sanitization"),
            sanitized_turns_reused: c("sanitized_turns_reused", "sanitized turns reused from the incremental cache"),
            execution_failed: c("execution_failed", "requests failed on a non-recoverable island execution error"),
            failovers: c("failovers", "failover hops: execution attempts that hit a dead island"),
            failover_successes: c("failover_successes", "requests served after at least one failover hop"),
            rejected_failover_exhausted: c(
                "rejected_failover_exhausted",
                "requests rejected after exhausting the failover retry budget",
            ),
            batch_groups: c("batch_groups", "co-routed batch groups dispatched to islands"),
            batch_group_size: h("batch_group_size", "requests per dispatched batch group"),
            batch_occupancy: h("batch_occupancy", "in-flight requests per continuous-batching step-loop round"),
            steady_state_batch_occupancy: g(
                "steady_state_batch_occupancy",
                "in-flight requests at the last step-loop round",
            ),
            step_drive_panics: c("step_drive_panics", "island step-loop driver panics (orphaned requests shed)"),
            queue_drain_panics: c("queue_drain_panics", "queue worker drain panics (batch shed)"),
            requests_served: c("requests_served", "requests served end to end"),
            requests_cancelled: c("requests_cancelled", "requests cancelled after decoding started (partial charge)"),
            cancelled_while_queued: c(
                "cancelled_while_queued",
                "caller cancels observed while the request was still queued",
            ),
            cancelled_before_execution: c(
                "cancelled_before_execution",
                "caller cancels observed after routing, before decode",
            ),
            cancelled_mid_decode: c("cancelled_mid_decode", "caller cancels observed between decode steps"),
            cancelled_deadline_mid_decode: c(
                "cancelled_deadline_mid_decode",
                "deadline expiries observed between decode steps",
            ),
            cancelled_tokens_decoded: h(
                "cancelled_tokens_decoded",
                "tokens decoded (and charged) before a mid-decode cancel",
            ),
            ticket_double_resolved: c(
                "ticket_double_resolved",
                "ticket resolutions that lost the first-wins race (must stay 0)",
            ),
            latency_ms: h("latency_ms", "end-to-end latency of served requests (ms)"),
            cost_usd: h("cost_usd", "per-request serving cost (USD)"),
            resolved: ResolvedCells::register(&m.counter_vec(
                "requests_resolved",
                "terminal request resolutions by outcome class and reason",
                &["outcome", "reason"],
            )),
            island_crashes: c("island_crashes", "announced island crashes (clean shutdown)"),
            island_revives: c("island_revives", "islands powered back on and announced"),
            island_joins: c("island_joins", "islands that joined the mesh mid-run"),
            island_leaves: c("island_leaves", "islands deprovisioned from the mesh"),
            islands_degraded: c("islands_degraded", "TIDE degrade-detector trips (island capacity collapsed)"),
            islands_recovered: c("islands_recovered", "TIDE degrade-detector recoveries"),
            island_latency: m.histogram_vec(
                "island_latency_ms",
                "end-to-end latency of served requests, by serving island (ms)",
                &["island", "tier", "privacy"],
            ),
            served_by_island: m.counter_vec(
                "served_by_island",
                "requests served, by serving island",
                &["island", "tier", "privacy"],
            ),
            failovers_by_island: m.counter_vec(
                "failovers_by_island",
                "failover hops attributed to the island that died",
                &["island"],
            ),
            island_cells: RwLock::new(BTreeMap::new()),
            failover_cells: RwLock::new(BTreeMap::new()),
        }
    }

    /// Cached per-island cells; `tier`/`privacy` become label values on
    /// first resolution (island specs are static, so first wins).
    pub fn island(&self, id: u32, tier: &str, privacy: f64) -> Arc<IslandCells> {
        if let Some(cells) = self.island_cells.read_clean().get(&id) {
            return Arc::clone(cells);
        }
        let island = format!("island-{id}");
        let privacy = format!("{privacy:.2}");
        let labels = [island.as_str(), tier, privacy.as_str()];
        let cells = Arc::new(IslandCells {
            latency_ms: self.island_latency.with(&labels),
            served: self.served_by_island.with(&labels),
        });
        let mut w = self.island_cells.write_clean();
        Arc::clone(w.entry(id).or_insert(cells))
    }

    /// Cached `failovers_by_island{island}` counter for a dead island.
    pub fn failover_from(&self, id: u32) -> Counter {
        if let Some(c) = self.failover_cells.read_clean().get(&id) {
            return c.clone();
        }
        let island = format!("island-{id}");
        let counter = self.failovers_by_island.with(&[island.as_str()]);
        let mut w = self.failover_cells.write_clean();
        w.entry(id).or_insert(counter).clone()
    }
}

/// Route label values the HTTP surface reports under. Unrecognized paths
/// collapse into `other` so hostile scanners cannot mint unbounded series.
pub const HTTP_ROUTES: [&str; 8] = ["submit", "ticket", "cancel", "stream", "trace", "metrics", "healthz", "other"];

/// Pre-registered metrics for the HTTP serving surface: per-route request
/// counters (`http_requests{route,status}`), per-route latency histograms
/// (`http_request_ms{route}`), the live-connection gauge, and the ticket
/// registry's reap counter. Cells are cached per `(route, status)` so the
/// per-request path after warm-up is two atomic bumps.
pub struct HttpMetrics {
    /// `http_active_connections` — connections currently being served.
    pub active_connections: Gauge,
    /// `rejected_rate_limited` — shared with [`ServingMetrics`]; the HTTP
    /// front-door 429 path bumps the same cell as the in-process shed path.
    pub rejected_rate_limited: Counter,
    /// `tickets_reaped` — resolved tickets dropped from the HTTP ticket
    /// registry after their TTL (or evicted resolved-first at capacity).
    pub tickets_reaped: Counter,
    requests: CounterVec,
    latency: HistogramVec,
    request_cells: RwLock<BTreeMap<(&'static str, u16), Counter>>,
    latency_cells: RwLock<BTreeMap<&'static str, Hist>>,
}

impl HttpMetrics {
    pub fn register(m: &Metrics) -> HttpMetrics {
        HttpMetrics {
            active_connections: m.register_gauge("http_active_connections", "HTTP connections currently open"),
            rejected_rate_limited: m.register_counter(
                "rejected_rate_limited",
                "requests shed with a typed resolution by the per-user rate limiter",
            ),
            tickets_reaped: m
                .register_counter("tickets_reaped", "resolved tickets reaped from the HTTP ticket registry"),
            requests: m.counter_vec("http_requests", "HTTP requests handled, by route and status", &["route", "status"]),
            latency: m.histogram_vec("http_request_ms", "HTTP request wall time, by route (ms)", &["route"]),
            request_cells: RwLock::new(BTreeMap::new()),
            latency_cells: RwLock::new(BTreeMap::new()),
        }
    }

    /// Record one handled request: bump `http_requests{route,status}` and
    /// observe `http_request_ms{route}`. `route` must be one of
    /// [`HTTP_ROUTES`] (the router guarantees this).
    pub fn observe(&self, route: &'static str, status: u16, wall_ms: f64) {
        self.request_counter(route, status).inc();
        self.route_latency(route).observe(wall_ms);
    }

    fn request_counter(&self, route: &'static str, status: u16) -> Counter {
        if let Some(c) = self.request_cells.read_clean().get(&(route, status)) {
            return c.clone();
        }
        let status_label = status.to_string();
        let counter = self.requests.with(&[route, status_label.as_str()]);
        let mut w = self.request_cells.write_clean();
        w.entry((route, status)).or_insert(counter).clone()
    }

    fn route_latency(&self, route: &'static str) -> Hist {
        if let Some(h) = self.latency_cells.read_clean().get(route) {
            return h.clone();
        }
        let hist = self.latency.with(&[route]);
        let mut w = self.latency_cells.write_clean();
        w.entry(route).or_insert(hist).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registers_against_legacy_names() {
        let m = Metrics::new();
        let s = ServingMetrics::register(&m);
        s.requests_served.inc();
        s.latency_ms.observe(12.0);
        s.queue_depth.set(3.0);
        assert_eq!(m.counter_value("requests_served"), 1);
        assert_eq!(m.histogram("latency_ms").unwrap().count(), 1);
        assert_eq!(m.gauge_value("queue_depth"), Some(3.0));
    }

    #[test]
    fn resolved_cells_cover_every_resolution() {
        let m = Metrics::new();
        let s = ServingMetrics::register(&m);
        for r in Resolution::ALL {
            s.resolved.of(r).inc();
        }
        assert_eq!(m.counter_value("requests_resolved"), Resolution::ALL.len() as u64);
        assert_eq!(m.counter_children("requests_resolved").len(), Resolution::ALL.len());
    }

    #[test]
    fn http_metrics_share_the_rate_limited_cell_and_label_routes() {
        let m = Metrics::new();
        let s = ServingMetrics::register(&m);
        let h = HttpMetrics::register(&m);
        // same family, same (empty) label set — one logical counter
        s.rejected_rate_limited.inc();
        h.rejected_rate_limited.inc();
        assert_eq!(m.counter_value("rejected_rate_limited"), 2);
        h.observe("submit", 200, 1.5);
        h.observe("submit", 200, 2.5);
        h.observe("submit", 429, 0.1);
        h.observe("healthz", 200, 0.2);
        assert_eq!(m.counter_value("http_requests"), 4);
        assert_eq!(m.counter_children("http_requests").len(), 3);
        let hists = m.histogram_children("http_request_ms");
        assert_eq!(hists.len(), 2);
        h.active_connections.set(3.0);
        assert_eq!(m.gauge_value("http_active_connections"), Some(3.0));
        h.active_connections.add(2.0);
        h.active_connections.add(-1.0);
        assert_eq!(m.gauge_value("http_active_connections"), Some(4.0), "gauge deltas accumulate");
        h.tickets_reaped.inc();
        assert_eq!(m.counter_value("tickets_reaped"), 1);
    }

    #[test]
    fn island_cells_are_cached_and_labeled() {
        let m = Metrics::new();
        let s = ServingMetrics::register(&m);
        let a = s.island(3, "personal", 0.9);
        let b = s.island(3, "personal", 0.9);
        assert!(Arc::ptr_eq(&a, &b));
        a.latency_ms.observe(5.0);
        a.served.inc();
        let children = m.histogram_children("island_latency_ms");
        assert_eq!(children.len(), 1);
        assert_eq!(children[0].0, vec!["island-3".to_string(), "personal".to_string(), "0.90".to_string()]);
        assert_eq!(children[0].1.count(), 1);
        s.failover_from(3).inc();
        s.failover_from(3).inc();
        assert_eq!(m.counter_value("failovers_by_island"), 2);
    }
}
