//! Baseline routing policies from the paper's §XI.A comparison:
//!
//! 1. **Cloud-only** — all requests to the commercial LLM API (violates
//!    privacy for sensitive data).
//! 2. **Local-only** — all requests to personal devices (fails under
//!    resource exhaustion).
//! 3. **Latency-greedy** — lowest-latency island, privacy-blind (what
//!    "Kubernetes-style" routing degrades to in Table II).
//! 4. **Privacy-only** — highest-privacy island regardless of capacity or
//!    cost (never exploits the cloud).
//! 5. **Static-policy** — the §I strawman: "if PII detected route local",
//!    pre-configured, but *degrades to cloud under resource exhaustion,
//!    silently violating privacy*.
//!
//! IslandRun itself is adapted to the same [`Policy`] interface so the eval
//! harness drives all six through identical traces and fleets (E1–E6).

use crate::agents::tide::hysteresis::Preference;
use crate::agents::waves::{Decision, IslandState, Waves};
use crate::config::Config;
use crate::types::{IslandId, Request, TrustTier};

/// A routing policy under evaluation.
pub enum PolicyDecision {
    Island(IslandId),
    Reject,
}

pub trait Policy {
    fn name(&self) -> &'static str;
    /// Decide a target island. `s_r` is MIST's sensitivity estimate;
    /// `local_capacity` is TIDE's local view.
    fn route(&mut self, request: &Request, s_r: f64, states: &[IslandState], local_capacity: f64) -> PolicyDecision;
}

fn cheapest_cloud(states: &[IslandState]) -> Option<IslandId> {
    states
        .iter()
        .filter(|s| s.island.tier == TrustTier::Cloud)
        .min_by(|a, b| a.island.request_cost(64).partial_cmp(&b.island.request_cost(64)).unwrap())
        .map(|s| s.island.id)
}

/// 1. Cloud-only.
pub struct CloudOnly;

impl Policy for CloudOnly {
    fn name(&self) -> &'static str {
        "cloud-only"
    }

    fn route(&mut self, _r: &Request, _s: f64, states: &[IslandState], _lc: f64) -> PolicyDecision {
        match cheapest_cloud(states) {
            Some(id) => PolicyDecision::Island(id),
            None => PolicyDecision::Reject,
        }
    }
}

/// 2. Local-only: round-robins across personal devices with capacity; queues
/// on the primary device when everything is saturated.
pub struct LocalOnly;

impl Policy for LocalOnly {
    fn name(&self) -> &'static str {
        "local-only"
    }

    fn route(&mut self, _r: &Request, _s: f64, states: &[IslandState], _lc: f64) -> PolicyDecision {
        let personal: Vec<&IslandState> =
            states.iter().filter(|s| s.island.tier == TrustTier::Personal).collect();
        if personal.is_empty() {
            return PolicyDecision::Reject;
        }
        let best = personal
            .iter()
            .max_by(|a, b| a.capacity.partial_cmp(&b.capacity).unwrap())
            .unwrap();
        PolicyDecision::Island(best.island.id)
    }
}

/// 3. Latency-greedy: min L_j among islands with any capacity.
pub struct LatencyGreedy;

impl Policy for LatencyGreedy {
    fn name(&self) -> &'static str {
        "latency-greedy"
    }

    fn route(&mut self, _r: &Request, _s: f64, states: &[IslandState], _lc: f64) -> PolicyDecision {
        let viable: Vec<&IslandState> =
            states.iter().filter(|s| s.island.unbounded() || s.capacity > 0.0).collect();
        match viable.iter().min_by(|a, b| a.island.latency_ms.partial_cmp(&b.island.latency_ms).unwrap()) {
            Some(s) => PolicyDecision::Island(s.island.id),
            None => PolicyDecision::Reject,
        }
    }
}

/// 4. Privacy-only: max P_j, ties by latency; ignores capacity entirely
/// (that's its failure mode: exhaustion).
pub struct PrivacyOnly;

impl Policy for PrivacyOnly {
    fn name(&self) -> &'static str {
        "privacy-only"
    }

    fn route(&mut self, _r: &Request, _s: f64, states: &[IslandState], _lc: f64) -> PolicyDecision {
        match states.iter().max_by(|a, b| {
            (a.island.privacy, -a.island.latency_ms).partial_cmp(&(b.island.privacy, -b.island.latency_ms)).unwrap()
        }) {
            Some(s) => PolicyDecision::Island(s.island.id),
            None => PolicyDecision::Reject,
        }
    }
}

/// 5. Static rule with pressure fallback: "PII → local" until local capacity
/// drops below 20%, then EVERYTHING silently goes to cloud (the paper's
/// motivating failure).
pub struct StaticPolicy;

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static-policy"
    }

    fn route(&mut self, _r: &Request, s_r: f64, states: &[IslandState], local_capacity: f64) -> PolicyDecision {
        if local_capacity < 0.2 {
            // degradation under load — the silent privacy violation
            return match cheapest_cloud(states) {
                Some(id) => PolicyDecision::Island(id),
                None => PolicyDecision::Reject,
            };
        }
        if s_r >= 0.8 {
            LocalOnly.route(_r, s_r, states, local_capacity)
        } else {
            match cheapest_cloud(states) {
                Some(id) => PolicyDecision::Island(id),
                None => PolicyDecision::Reject,
            }
        }
    }
}

/// 6. IslandRun (WAVES Algorithm 1) adapted to the Policy interface.
pub struct IslandRunPolicy {
    pub waves: Waves,
}

impl IslandRunPolicy {
    pub fn new(config: Config) -> IslandRunPolicy {
        IslandRunPolicy { waves: Waves::new(config) }
    }
}

impl Policy for IslandRunPolicy {
    fn name(&self) -> &'static str {
        "islandrun"
    }

    fn route(&mut self, request: &Request, s_r: f64, states: &[IslandState], local_capacity: f64) -> PolicyDecision {
        match self.waves.route(request, s_r, states, local_capacity, Preference::Local, f64::INFINITY) {
            Decision::Route(r) | Decision::FailsafeLocal(r) => PolicyDecision::Island(r.target),
            Decision::Reject { .. } => PolicyDecision::Reject,
        }
    }
}

/// All six policies, fresh instances (eval harness helper).
pub fn all_policies(config: &Config) -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(IslandRunPolicy::new(config.clone())),
        Box::new(CloudOnly),
        Box::new(LocalOnly),
        Box::new(LatencyGreedy),
        Box::new(PrivacyOnly),
        Box::new(StaticPolicy),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn states(cap: f64) -> Vec<IslandState> {
        preset_personal_group()
            .into_iter()
            .map(|island| {
                let c = if island.unbounded() { 1.0 } else { cap };
                IslandState { island, capacity: c, online: true, degraded: false }
            })
            .collect()
    }

    fn island_tier(states: &[IslandState], d: &PolicyDecision) -> Option<TrustTier> {
        match d {
            PolicyDecision::Island(id) => states.iter().find(|s| s.island.id == *id).map(|s| s.island.tier),
            PolicyDecision::Reject => None,
        }
    }

    #[test]
    fn cloud_only_always_cloud() {
        let st = states(1.0);
        let r = Request::new(1, "patient data");
        let d = CloudOnly.route(&r, 0.9, &st, 1.0);
        assert_eq!(island_tier(&st, &d), Some(TrustTier::Cloud));
    }

    #[test]
    fn local_only_never_leaves_personal() {
        let st = states(0.0); // fully saturated: still picks personal
        let r = Request::new(1, "q");
        let d = LocalOnly.route(&r, 0.2, &st, 0.0);
        assert_eq!(island_tier(&st, &d), Some(TrustTier::Personal));
    }

    #[test]
    fn latency_greedy_picks_fastest() {
        let st = states(1.0);
        let r = Request::new(1, "q");
        let d = LatencyGreedy.route(&r, 0.9, &st, 1.0);
        if let PolicyDecision::Island(id) = d {
            let fastest = st.iter().min_by(|a, b| a.island.latency_ms.partial_cmp(&b.island.latency_ms).unwrap()).unwrap();
            assert_eq!(id, fastest.island.id);
        } else {
            panic!("rejected");
        }
    }

    #[test]
    fn static_policy_violates_under_pressure() {
        let st = states(0.1);
        let r = Request::new(1, "patient john doe ssn 123-45-6789");
        // local capacity 0.1 < 0.2 → even a highly sensitive request goes to cloud
        let d = StaticPolicy.route(&r, 0.9, &st, 0.1);
        assert_eq!(island_tier(&st, &d), Some(TrustTier::Cloud), "the documented silent violation");
        // with capacity it behaves
        let d2 = StaticPolicy.route(&r, 0.9, &states(0.9), 0.9);
        assert_eq!(island_tier(&states(0.9), &d2), Some(TrustTier::Personal));
    }

    #[test]
    fn islandrun_policy_never_violates_even_under_pressure() {
        let mut p = IslandRunPolicy::new(Config::default());
        let st = states(0.05);
        let r = Request::new(1, "patient john doe ssn 123-45-6789")
            .with_priority(crate::types::PriorityTier::Primary);
        let d = p.route(&r, 0.9, &st, 0.05);
        match d {
            PolicyDecision::Island(id) => {
                let island = st.iter().find(|s| s.island.id == id).unwrap();
                assert!(island.island.privacy >= 0.9);
            }
            PolicyDecision::Reject => {} // fail-closed is acceptable
        }
    }

    #[test]
    fn all_policies_constructs_six() {
        let ps = all_policies(&Config::default());
        assert_eq!(ps.len(), 6);
        let names: Vec<&str> = ps.iter().map(|p| p.name()).collect();
        assert!(names.contains(&"islandrun") && names.contains(&"cloud-only"));
    }
}
