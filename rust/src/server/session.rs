//! Session store: multi-turn conversations with trust-boundary tracking.
//!
//! Each session owns its chat history `h_r`, the privacy level of the island
//! the previous turn ran on (`P_prev`, Algorithm 1 line 14) and the
//! session-scoped [`PlaceholderMap`] so the same entity keeps the same
//! placeholder across turns while different sessions get uncorrelated ids
//! (Attack-3 mitigation).

use std::collections::BTreeMap;

use crate::agents::mist::sanitize::PlaceholderMap;
use crate::types::{Role, Turn};

/// One conversation.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub user: String,
    pub history: Vec<Turn>,
    /// Privacy score of the island the previous turn executed on.
    pub prev_island_privacy: Option<f64>,
    pub placeholders: PlaceholderMap,
}

impl Session {
    pub fn new(id: u64, user: &str, mesh_seed: u64) -> Session {
        // Placeholder ids derive from (mesh seed, session id): deterministic
        // for replay, uncorrelated across sessions.
        let seed = mesh_seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Session { id, user: user.to_string(), history: Vec::new(), prev_island_privacy: None, placeholders: PlaceholderMap::new(seed) }
    }

    /// Append a completed turn pair and record where it ran.
    pub fn record_turn(&mut self, user_text: &str, assistant_text: &str, island_privacy: f64) {
        self.history.push(Turn { role: Role::User, text: user_text.to_string() });
        self.history.push(Turn { role: Role::Assistant, text: assistant_text.to_string() });
        self.prev_island_privacy = Some(island_privacy);
    }
}

/// All live sessions.
#[derive(Debug, Default)]
pub struct SessionStore {
    sessions: BTreeMap<u64, Session>,
    next_id: u64,
    mesh_seed: u64,
}

impl SessionStore {
    pub fn new(mesh_seed: u64) -> SessionStore {
        SessionStore { sessions: BTreeMap::new(), next_id: 1, mesh_seed }
    }

    pub fn open(&mut self, user: &str) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.insert(id, Session::new(id, user, self.mesh_seed));
        id
    }

    pub fn get(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    pub fn close(&mut self, id: u64) -> bool {
        self.sessions.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_record_close() {
        let mut store = SessionStore::new(42);
        let id = store.open("alice");
        assert_eq!(store.len(), 1);
        let s = store.get_mut(id).unwrap();
        s.record_turn("hello", "hi there", 1.0);
        assert_eq!(s.history.len(), 2);
        assert_eq!(s.prev_island_privacy, Some(1.0));
        assert!(store.close(id));
        assert!(store.is_empty());
    }

    #[test]
    fn session_ids_unique() {
        let mut store = SessionStore::new(1);
        let a = store.open("u");
        let b = store.open("u");
        assert_ne!(a, b);
    }

    #[test]
    fn placeholder_maps_uncorrelated_across_sessions() {
        let mut store = SessionStore::new(7);
        let a = store.open("u");
        let b = store.open("u");
        let sa = store.get_mut(a).unwrap().placeholders.sanitize("john doe", 0.4);
        let sb = store.get_mut(b).unwrap().placeholders.sanitize("john doe", 0.4);
        // same entity, different sessions → (almost surely) different ids
        assert_ne!(sa, sb);
    }

    #[test]
    fn history_tracks_trust_boundary() {
        let mut store = SessionStore::new(3);
        let id = store.open("bob");
        let s = store.get_mut(id).unwrap();
        assert_eq!(s.prev_island_privacy, None);
        s.record_turn("q1", "a1", 1.0);
        s.record_turn("q2", "a2", 0.4);
        assert_eq!(s.prev_island_privacy, Some(0.4));
        assert_eq!(s.history.len(), 4);
    }
}
