//! Session store: multi-turn conversations with trust-boundary tracking.
//!
//! Each session owns its chat history `h_r`, the privacy level of the island
//! the previous turn ran on (`P_prev`, Algorithm 1 line 14), the
//! session-scoped [`PlaceholderMap`] so the same entity keeps the same
//! placeholder across turns while different sessions get uncorrelated ids
//! (Attack-3 mitigation), and a per-privacy-level cache of the sanitized
//! history so repeat trust-boundary crossings pay O(delta turns), not
//! O(whole history) — see [`Session::plan_sanitize`].
//!
//! The store is sharded for concurrent serving: session ids are allocated
//! from an atomic counter and sessions live in `RwLock`-guarded shards keyed
//! by `id % SHARDS`, so submitters working different sessions take different
//! locks. Access goes through closures ([`SessionStore::with`] /
//! [`SessionStore::with_mut`]) rather than returned references, keeping lock
//! scopes explicit and minimal.
//!
//! # Incremental sanitization (three phases)
//!
//! Entity detection is the expensive part of sanitization; running it for
//! the whole history on every crossing made the privacy hot path
//! O(history) per request *inside* the session-shard lock (O(n²) per
//! conversation, serializing every request in the shard). The rebuilt path
//! splits the work so scanning happens on an immutable snapshot outside
//! any lock:
//!
//! 1. [`Session::plan_sanitize`] (shard **read** lock): look up the
//!    per-level cache, clone the reusable sanitized prefix and the
//!    still-original delta turns.
//! 2. [`SanitizePlan::detect`] (**no lock**): run entity detection over the
//!    delta (and, on a failover hop to a lower level, over the cached form
//!    being re-sanitized).
//! 3. [`DetectedSanitize::apply`] (shard **write** lock): splice
//!    placeholders via the session's [`PlaceholderMap`] — hash lookups and
//!    string copies only — and refresh the level cache.
//!
//! Turns are append-only and stored in their original (desanitized) form,
//! so a cached sanitized prefix never goes stale; new turns are the delta.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::agents::mist::entities::{detect, Entity};
use crate::agents::mist::sanitize::PlaceholderMap;
use crate::types::{Role, Turn};

use crate::util::sync::{LockExt, RwLockExt};

const SHARDS: usize = 16;

/// Per-level cache entries kept per session (islands expose only a handful
/// of distinct privacy levels; the least-covering entry is evicted beyond
/// this).
const MAX_CACHED_LEVELS: usize = 4;

/// Sanitized-history prefixes, keyed by the privacy level they were built
/// for. `turns[i]` is the sanitized form of `history[i]`; a cache entry
/// covers `turns.len()` leading turns of the session history.
#[derive(Debug, Default)]
pub struct SanitizedCache {
    entries: Vec<(u64, Vec<Turn>)>, // (level bits, sanitized prefix)
}

impl SanitizedCache {
    fn get(&self, level: f64) -> Option<&Vec<Turn>> {
        let bits = level.to_bits();
        self.entries.iter().find(|(l, _)| *l == bits).map(|(_, t)| t)
    }

    /// Sanitized prefix cached for exactly this level (observability/tests).
    pub fn turns_at(&self, level: f64) -> Option<&[Turn]> {
        self.get(level).map(|t| t.as_slice())
    }

    /// Levels currently cached, with how many turns each covers.
    pub fn coverage(&self) -> Vec<(f64, usize)> {
        self.entries.iter().map(|(l, t)| (f64::from_bits(*l), t.len())).collect()
    }

    fn store(&mut self, level: f64, turns: Vec<Turn>) {
        let bits = level.to_bits();
        if let Some(entry) = self.entries.iter_mut().find(|(l, _)| *l == bits) {
            // longer coverage wins: a racing request that sanitized a
            // shorter snapshot must not shrink the cache
            if turns.len() >= entry.1.len() {
                entry.1 = turns;
            }
            return;
        }
        if self.entries.len() >= MAX_CACHED_LEVELS {
            let evict = self.entries.iter().enumerate().min_by_key(|(_, (_, t))| t.len()).map(|(i, _)| i);
            if let Some(pos) = evict {
                // never trade a longer-built entry for a shorter newcomer —
                // that would force a near-cold rescan at the evicted level
                if self.entries[pos].1.len() >= turns.len() {
                    return;
                }
                self.entries.remove(pos);
            }
        }
        self.entries.push((bits, turns));
    }
}

/// One conversation.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub user: String,
    pub history: Vec<Turn>,
    /// Privacy score of the island the previous turn executed on.
    pub prev_island_privacy: Option<f64>,
    pub placeholders: PlaceholderMap,
    /// Per-privacy-level sanitized prefixes of `history`.
    pub sanitized: SanitizedCache,
}

impl Session {
    pub fn new(id: u64, user: &str, mesh_seed: u64) -> Session {
        // Placeholder ids derive from (mesh seed, session id): deterministic
        // for replay, uncorrelated across sessions.
        let seed = mesh_seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Session {
            id,
            user: user.to_string(),
            history: Vec::new(),
            prev_island_privacy: None,
            placeholders: PlaceholderMap::new(seed),
            sanitized: SanitizedCache::default(),
        }
    }

    /// Append a completed turn pair and record where it ran.
    pub fn record_turn(&mut self, user_text: &str, assistant_text: &str, island_privacy: f64) {
        self.history.push(Turn { role: Role::User, text: user_text.to_string() });
        self.history.push(Turn { role: Role::Assistant, text: assistant_text.to_string() });
        self.prev_island_privacy = Some(island_privacy);
    }

    /// Phase 1 of incremental sanitization (run under the shard READ lock):
    /// split the `snapshot` of this session's history into a reusable
    /// sanitized prefix and the delta still to transform at `level`.
    ///
    /// Cache preference, coverage first:
    /// - the longest entry at a level ≤ `level` (exact level wins ties):
    ///   reused verbatim — it replaced at least every entity `level`
    ///   requires (over-sanitization is privacy-safe by Def. 4, never
    ///   under);
    /// - else the longest entry at a level > `level` (the failover-down
    ///   case): its turns are re-sanitized at `level` from the cached
    ///   clean form — entities *between* the two levels are still
    ///   cleartext there and get placeholders now, while already-placed
    ///   placeholders are inert;
    /// - otherwise the whole snapshot is the delta (cold path).
    pub fn plan_sanitize(&self, level: f64, snapshot: &[Turn], prompt: &str) -> SanitizePlan {
        let max_len = snapshot.len();
        let mut base: Vec<Turn> = Vec::new();
        let mut resplice_base = false;
        // best verbatim candidate: max coverage among levels <= `level`,
        // ties to the highest (least over-sanitized) level
        let mut verbatim: Option<(f64, &Vec<Turn>)> = None;
        // best resplice candidate: max coverage among levels > `level`,
        // ties to the lowest (closest) level
        let mut above: Option<(f64, &Vec<Turn>)> = None;
        for (bits, turns) in &self.sanitized.entries {
            if turns.is_empty() {
                continue;
            }
            let l = f64::from_bits(*bits);
            if l <= level {
                let better = match verbatim {
                    None => true,
                    Some((best, bt)) => turns.len() > bt.len() || (turns.len() == bt.len() && l > best),
                };
                if better {
                    verbatim = Some((l, turns));
                }
            } else {
                let better = match above {
                    None => true,
                    Some((best, bt)) => turns.len() > bt.len() || (turns.len() == bt.len() && l < best),
                };
                if better {
                    above = Some((l, turns));
                }
            }
        }
        if let Some((_, turns)) = verbatim {
            base = turns[..turns.len().min(max_len)].to_vec();
        } else if let Some((_, turns)) = above {
            base = turns[..turns.len().min(max_len)].to_vec();
            resplice_base = true;
        }
        let delta = snapshot[base.len()..].to_vec();
        SanitizePlan { level, base, resplice_base, delta, prompt: prompt.to_string() }
    }
}

/// Phase-1 output of incremental sanitization: an immutable work order,
/// detached from the session so detection can run lock-free.
#[derive(Debug)]
pub struct SanitizePlan {
    level: f64,
    /// Already-sanitized prefix (from the per-level cache).
    base: Vec<Turn>,
    /// True when `base` was built for a HIGHER level and must be
    /// re-sanitized at `level` (failover to a lower-privacy island).
    resplice_base: bool,
    /// Original-text turns past the cached prefix.
    delta: Vec<Turn>,
    prompt: String,
}

impl SanitizePlan {
    /// Phase 2: entity detection over everything still to transform — the
    /// expensive scan, run OUTSIDE any session lock on immutable text.
    pub fn detect(self) -> DetectedSanitize {
        let SanitizePlan { level, base, resplice_base, delta, prompt } = self;
        let base: Vec<(Turn, Option<Vec<Entity>>)> = base
            .into_iter()
            .map(|t| {
                let ents = if resplice_base { Some(detect(&t.text)) } else { None };
                (t, ents)
            })
            .collect();
        let delta: Vec<(Turn, Vec<Entity>)> = delta
            .into_iter()
            .map(|t| {
                let ents = detect(&t.text);
                (t, ents)
            })
            .collect();
        let prompt_entities = detect(&prompt);
        DetectedSanitize { level, base, delta, prompt, prompt_entities }
    }
}

/// Phase-2 output: every span to replace is known; what remains is cheap
/// placeholder splicing against the session's [`PlaceholderMap`].
#[derive(Debug)]
pub struct DetectedSanitize {
    level: f64,
    base: Vec<(Turn, Option<Vec<Entity>>)>,
    delta: Vec<(Turn, Vec<Entity>)>,
    prompt: String,
    prompt_entities: Vec<Entity>,
}

/// The wire-ready result of one incremental sanitization pass.
#[derive(Debug)]
pub struct SanitizedWire {
    /// Sanitized history to transmit.
    pub history: Vec<Turn>,
    /// Sanitized outgoing prompt.
    pub prompt: String,
    /// Texts actually scanned + spliced this pass (delta turns, re-spliced
    /// cached turns, and the prompt) — the real per-turn work metric.
    pub transformed: usize,
    /// Turns reused verbatim from the per-level cache.
    pub reused: usize,
}

impl DetectedSanitize {
    /// Phase 3 (run under the shard WRITE lock): splice placeholders and
    /// refresh the session's per-level cache. Only map lookups and string
    /// splices happen here — the critical section no longer scales with
    /// scanning cost.
    pub fn apply(self, session: &mut Session) -> SanitizedWire {
        let DetectedSanitize { level, base, delta, prompt, prompt_entities } = self;
        let mut transformed = 0usize;
        let mut reused = 0usize;
        let mut history: Vec<Turn> = Vec::with_capacity(base.len() + delta.len());
        for (turn, ents) in base {
            match ents {
                None => {
                    reused += 1;
                    history.push(turn);
                }
                Some(es) => {
                    transformed += 1;
                    let text = session.placeholders.splice(&turn.text, &es, level);
                    history.push(Turn { role: turn.role, text });
                }
            }
        }
        for (turn, es) in delta {
            transformed += 1;
            let text = session.placeholders.splice(&turn.text, &es, level);
            history.push(Turn { role: turn.role, text });
        }
        let history_transformed = transformed;
        let prompt = session.placeholders.splice(&prompt, &prompt_entities, level);
        transformed += 1; // the prompt itself
        // Refresh the cache only when some history turn actually changed:
        // a fully-warm pass (prompt-only work) would store content already
        // reachable through the cache, paying an O(history) clone under
        // the shard write lock for nothing.
        if history_transformed > 0 {
            session.sanitized.store(level, history.clone());
        }
        SanitizedWire { history, prompt, transformed, reused }
    }
}

/// All live sessions, sharded for concurrent access.
#[derive(Debug)]
pub struct SessionStore {
    shards: Vec<RwLock<BTreeMap<u64, Session>>>,
    next_id: AtomicU64,
    mesh_seed: u64,
}

impl SessionStore {
    pub fn new(mesh_seed: u64) -> SessionStore {
        SessionStore {
            shards: (0..SHARDS).map(|_| RwLock::new(BTreeMap::new())).collect(),
            next_id: AtomicU64::new(1),
            mesh_seed,
        }
    }

    fn shard(&self, id: u64) -> &RwLock<BTreeMap<u64, Session>> {
        &self.shards[(id % SHARDS as u64) as usize]
    }

    /// Open a session for a user; ids are unique even under concurrent opens.
    pub fn open(&self, user: &str) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.shard(id).write_clean().insert(id, Session::new(id, user, self.mesh_seed));
        id
    }

    /// Run `f` against the session under a read lock.
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&Session) -> R) -> Option<R> {
        self.shard(id).read_clean().get(&id).map(f)
    }

    /// Run `f` against the session under a write lock.
    pub fn with_mut<R>(&self, id: u64, f: impl FnOnce(&mut Session) -> R) -> Option<R> {
        self.shard(id).write_clean().get_mut(&id).map(f)
    }

    /// The user who owns a session.
    pub fn user_of(&self, id: u64) -> Option<String> {
        self.with(id, |s| s.user.clone())
    }

    pub fn close(&self, id: u64) -> bool {
        self.shard(id).write_clean().remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read_clean().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_record_close() {
        let store = SessionStore::new(42);
        let id = store.open("alice");
        assert_eq!(store.len(), 1);
        store.with_mut(id, |s| s.record_turn("hello", "hi there", 1.0)).unwrap();
        store
            .with(id, |s| {
                assert_eq!(s.history.len(), 2);
                assert_eq!(s.prev_island_privacy, Some(1.0));
            })
            .unwrap();
        assert!(store.close(id));
        assert!(store.is_empty());
        assert!(store.with(id, |_| ()).is_none());
    }

    #[test]
    fn session_ids_unique() {
        let store = SessionStore::new(1);
        let a = store.open("u");
        let b = store.open("u");
        assert_ne!(a, b);
        assert_eq!(store.user_of(a).as_deref(), Some("u"));
    }

    #[test]
    fn placeholder_maps_uncorrelated_across_sessions() {
        let store = SessionStore::new(7);
        let a = store.open("u");
        let b = store.open("u");
        let sa = store.with_mut(a, |s| s.placeholders.sanitize("john doe", 0.4)).unwrap();
        let sb = store.with_mut(b, |s| s.placeholders.sanitize("john doe", 0.4)).unwrap();
        // same entity, different sessions → (almost surely) different ids
        assert_ne!(sa, sb);
    }

    #[test]
    fn history_tracks_trust_boundary() {
        let store = SessionStore::new(3);
        let id = store.open("bob");
        assert_eq!(store.with(id, |s| s.prev_island_privacy).unwrap(), None);
        store
            .with_mut(id, |s| {
                s.record_turn("q1", "a1", 1.0);
                s.record_turn("q2", "a2", 0.4);
            })
            .unwrap();
        assert_eq!(store.with(id, |s| s.prev_island_privacy).unwrap(), Some(0.4));
        assert_eq!(store.with(id, |s| s.history.len()).unwrap(), 4);
    }

    fn run_sanitize(session: &mut Session, level: f64) -> SanitizedWire {
        let snapshot = session.history.clone();
        let plan = session.plan_sanitize(level, &snapshot, "follow-up prompt");
        plan.detect().apply(session)
    }

    #[test]
    fn incremental_sanitize_only_transforms_the_delta() {
        let mut s = Session::new(1, "alice", 42);
        s.record_turn("patient john doe has diabetes", "noted for john doe", 1.0);
        s.record_turn("jane smith is in chicago", "ok", 1.0);
        // cold pass at 0.4: all 4 turns + prompt transformed
        let cold = run_sanitize(&mut s, 0.4);
        assert_eq!(cold.transformed, 5);
        assert_eq!(cold.reused, 0);
        assert_eq!(cold.history.len(), 4);
        assert!(!cold.history[0].text.contains("john"), "{:?}", cold.history[0]);
        // two more turns land; the next pass at the same level reuses the
        // cached prefix and transforms only the delta + prompt
        s.record_turn("what are common complications", "many", 0.4);
        let warm = run_sanitize(&mut s, 0.4);
        assert_eq!(warm.reused, 4);
        assert_eq!(warm.transformed, 3, "2 delta turns + prompt");
        assert_eq!(warm.history.len(), 6);
        // reused prefix is byte-identical to the cold pass
        assert_eq!(&warm.history[..4], &cold.history[..]);
    }

    #[test]
    fn stricter_cache_is_reused_verbatim_for_higher_levels() {
        let mut s = Session::new(2, "bob", 7);
        s.record_turn("patient john doe has diabetes in chicago", "ok", 1.0);
        let at_03 = run_sanitize(&mut s, 0.3);
        // a later request at a LESS strict level reuses the 0.3 form
        // verbatim (over-sanitization is privacy-safe)
        let at_07 = run_sanitize(&mut s, 0.7);
        assert_eq!(at_07.reused, 2);
        assert_eq!(at_07.transformed, 1, "prompt only");
        assert_eq!(&at_07.history[..], &at_03.history[..]);
    }

    #[test]
    fn failover_down_resplices_cached_form_and_matches_fresh() {
        let mut s = Session::new(3, "carol", 11);
        s.record_turn("patient john doe has diabetes in chicago", "noted", 1.0);
        s.record_turn("jane smith arrives tomorrow", "ok", 1.0);
        // first crossing lands on a private edge at 0.7: persons (0.8) and
        // medical (0.9) replaced; locations (0.6) and temporal (0.5) kept
        let edge = run_sanitize(&mut s, 0.7);
        assert!(edge.history[0].text.contains("chicago"), "{:?}", edge.history[0]);
        assert!(!edge.history[0].text.contains("john"));
        // failover to cloud at 0.3 re-sanitizes from the cached clean form
        let cloud = run_sanitize(&mut s, 0.3);
        assert_eq!(cloud.reused, 0, "resplice scans the cached turns");
        assert_eq!(cloud.transformed, 5, "4 respliced turns + prompt");
        assert!(!cloud.history[0].text.contains("chicago"));
        // cache coherence: same wire text as sanitizing the original
        // history fresh at 0.3 — identical placeholder kinds and positions
        // (ids are drawn in a different order, so compare id-normalized)
        let mut fresh = Session::new(3, "carol", 11);
        fresh.history = s.history.clone();
        let fresh_cloud = run_sanitize(&mut fresh, 0.3);
        let norm = |turns: &[Turn]| -> Vec<String> {
            turns.iter().map(|t| crate::util::collapse_digit_runs(&t.text)).collect()
        };
        assert_eq!(norm(&cloud.history), norm(&fresh_cloud.history));
        assert_eq!(
            crate::util::collapse_digit_runs(&cloud.prompt),
            crate::util::collapse_digit_runs(&fresh_cloud.prompt)
        );
    }

    #[test]
    fn cache_bounded_and_longest_coverage_wins() {
        let mut s = Session::new(4, "dave", 13);
        // each level sees fresh delta turns, so each pass stores an entry
        for (i, level) in [0.2, 0.3, 0.45, 0.55, 0.65].into_iter().enumerate() {
            s.record_turn(&format!("john doe in berlin, round {i}"), "ok", 1.0);
            let _ = run_sanitize(&mut s, level);
        }
        assert!(s.sanitized.coverage().len() <= MAX_CACHED_LEVELS);
        // a racing request that sanitized a SHORTER snapshot must not
        // shrink an existing entry
        let full = s.sanitized.turns_at(0.65).unwrap().to_vec();
        assert_eq!(full.len(), 10);
        s.sanitized.store(0.65, Vec::new());
        assert_eq!(s.sanitized.turns_at(0.65).unwrap(), &full[..]);
    }

    #[test]
    fn fully_warm_pass_does_not_rewrite_the_cache() {
        let mut s = Session::new(6, "fay", 19);
        s.record_turn("john doe in berlin", "ok", 1.0);
        let _ = run_sanitize(&mut s, 0.4);
        let before = s.sanitized.coverage();
        // no new turns: the next pass reuses the prefix, transforms only
        // the prompt, and must leave the cache untouched
        let warm = run_sanitize(&mut s, 0.4);
        assert_eq!(warm.reused, 2);
        assert_eq!(warm.transformed, 1);
        assert_eq!(s.sanitized.coverage(), before);
    }

    #[test]
    fn snapshot_shorter_than_cache_truncates_the_prefix() {
        let mut s = Session::new(5, "erin", 17);
        s.record_turn("john doe called", "ok", 1.0);
        s.record_turn("jane smith called", "ok", 1.0);
        let _ = run_sanitize(&mut s, 0.4); // caches 4 turns
        // a concurrent request prepared against an older, 2-turn snapshot
        let snapshot = s.history[..2].to_vec();
        let plan = s.plan_sanitize(0.4, &snapshot, "p");
        let wire = plan.detect().apply(&mut s);
        assert_eq!(wire.history.len(), 2);
        assert_eq!(wire.reused, 2);
        // and the longer cache entry survives the shorter store
        assert_eq!(s.sanitized.turns_at(0.4).unwrap().len(), 4);
    }

    #[test]
    fn concurrent_opens_yield_unique_ids() {
        use std::sync::{Arc, Mutex};
        let store = Arc::new(SessionStore::new(9));
        let ids = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let store = Arc::clone(&store);
                let ids = Arc::clone(&ids);
                std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..100 {
                        mine.push(store.open(&format!("user-{t}")));
                    }
                    ids.lock_clean().extend(mine);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all = ids.lock_clean().clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 800);
        assert_eq!(store.len(), 800);
    }
}
