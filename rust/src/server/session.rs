//! Session store: multi-turn conversations with trust-boundary tracking.
//!
//! Each session owns its chat history `h_r`, the privacy level of the island
//! the previous turn ran on (`P_prev`, Algorithm 1 line 14) and the
//! session-scoped [`PlaceholderMap`] so the same entity keeps the same
//! placeholder across turns while different sessions get uncorrelated ids
//! (Attack-3 mitigation).
//!
//! The store is sharded for concurrent serving: session ids are allocated
//! from an atomic counter and sessions live in `RwLock`-guarded shards keyed
//! by `id % SHARDS`, so submitters working different sessions take different
//! locks. Access goes through closures ([`SessionStore::with`] /
//! [`SessionStore::with_mut`]) rather than returned references, keeping lock
//! scopes explicit and minimal.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::agents::mist::sanitize::PlaceholderMap;
use crate::types::{Role, Turn};

const SHARDS: usize = 16;

/// One conversation.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub user: String,
    pub history: Vec<Turn>,
    /// Privacy score of the island the previous turn executed on.
    pub prev_island_privacy: Option<f64>,
    pub placeholders: PlaceholderMap,
}

impl Session {
    pub fn new(id: u64, user: &str, mesh_seed: u64) -> Session {
        // Placeholder ids derive from (mesh seed, session id): deterministic
        // for replay, uncorrelated across sessions.
        let seed = mesh_seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Session { id, user: user.to_string(), history: Vec::new(), prev_island_privacy: None, placeholders: PlaceholderMap::new(seed) }
    }

    /// Append a completed turn pair and record where it ran.
    pub fn record_turn(&mut self, user_text: &str, assistant_text: &str, island_privacy: f64) {
        self.history.push(Turn { role: Role::User, text: user_text.to_string() });
        self.history.push(Turn { role: Role::Assistant, text: assistant_text.to_string() });
        self.prev_island_privacy = Some(island_privacy);
    }
}

/// All live sessions, sharded for concurrent access.
#[derive(Debug)]
pub struct SessionStore {
    shards: Vec<RwLock<BTreeMap<u64, Session>>>,
    next_id: AtomicU64,
    mesh_seed: u64,
}

impl SessionStore {
    pub fn new(mesh_seed: u64) -> SessionStore {
        SessionStore {
            shards: (0..SHARDS).map(|_| RwLock::new(BTreeMap::new())).collect(),
            next_id: AtomicU64::new(1),
            mesh_seed,
        }
    }

    fn shard(&self, id: u64) -> &RwLock<BTreeMap<u64, Session>> {
        &self.shards[(id % SHARDS as u64) as usize]
    }

    /// Open a session for a user; ids are unique even under concurrent opens.
    pub fn open(&self, user: &str) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.shard(id).write().unwrap().insert(id, Session::new(id, user, self.mesh_seed));
        id
    }

    /// Run `f` against the session under a read lock.
    pub fn with<R>(&self, id: u64, f: impl FnOnce(&Session) -> R) -> Option<R> {
        self.shard(id).read().unwrap().get(&id).map(f)
    }

    /// Run `f` against the session under a write lock.
    pub fn with_mut<R>(&self, id: u64, f: impl FnOnce(&mut Session) -> R) -> Option<R> {
        self.shard(id).write().unwrap().get_mut(&id).map(f)
    }

    /// The user who owns a session.
    pub fn user_of(&self, id: u64) -> Option<String> {
        self.with(id, |s| s.user.clone())
    }

    pub fn close(&self, id: u64) -> bool {
        self.shard(id).write().unwrap().remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_record_close() {
        let store = SessionStore::new(42);
        let id = store.open("alice");
        assert_eq!(store.len(), 1);
        store.with_mut(id, |s| s.record_turn("hello", "hi there", 1.0)).unwrap();
        store
            .with(id, |s| {
                assert_eq!(s.history.len(), 2);
                assert_eq!(s.prev_island_privacy, Some(1.0));
            })
            .unwrap();
        assert!(store.close(id));
        assert!(store.is_empty());
        assert!(store.with(id, |_| ()).is_none());
    }

    #[test]
    fn session_ids_unique() {
        let store = SessionStore::new(1);
        let a = store.open("u");
        let b = store.open("u");
        assert_ne!(a, b);
        assert_eq!(store.user_of(a).as_deref(), Some("u"));
    }

    #[test]
    fn placeholder_maps_uncorrelated_across_sessions() {
        let store = SessionStore::new(7);
        let a = store.open("u");
        let b = store.open("u");
        let sa = store.with_mut(a, |s| s.placeholders.sanitize("john doe", 0.4)).unwrap();
        let sb = store.with_mut(b, |s| s.placeholders.sanitize("john doe", 0.4)).unwrap();
        // same entity, different sessions → (almost surely) different ids
        assert_ne!(sa, sb);
    }

    #[test]
    fn history_tracks_trust_boundary() {
        let store = SessionStore::new(3);
        let id = store.open("bob");
        assert_eq!(store.with(id, |s| s.prev_island_privacy).unwrap(), None);
        store
            .with_mut(id, |s| {
                s.record_turn("q1", "a1", 1.0);
                s.record_turn("q2", "a2", 0.4);
            })
            .unwrap();
        assert_eq!(store.with(id, |s| s.prev_island_privacy).unwrap(), Some(0.4));
        assert_eq!(store.with(id, |s| s.history.len()).unwrap(), 4);
    }

    #[test]
    fn concurrent_opens_yield_unique_ids() {
        use std::sync::{Arc, Mutex};
        let store = Arc::new(SessionStore::new(9));
        let ids = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let store = Arc::clone(&store);
                let ids = Arc::clone(&ids);
                std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..100 {
                        mine.push(store.open(&format!("user-{t}")));
                    }
                    ids.lock().unwrap().extend(mine);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all = ids.lock().unwrap().clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 800);
        assert_eq!(store.len(), 800);
    }
}
