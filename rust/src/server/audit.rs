//! Routing audit log (§XIV "Regulatory Compliance Verification": audit logs
//! that demonstrate compliance; the paper's zero-knowledge variant is out of
//! scope — DESIGN.md §2 records the substitution as a plain structured log).
//!
//! Every routing decision — including rejections — is appended with the
//! evidence a compliance reviewer needs: sensitivity, the constraint set
//! that was active, where the request ran, and whether sanitization was
//! applied. Exportable as JSON.
//!
//! Each entry carries the same typed [`AuditReason`] that resolves the
//! caller's ticket and labels the `requests_resolved` metric counter —
//! audit, outcome and metrics share one source of truth, so the
//! [`AuditLog::sheds`] / [`AuditLog::cancellations`] views are derived from
//! the enum rather than from string prefixes.
//!
//! Append-only and thread-safe: submitters on `Arc<Orchestrator>` append
//! under one short mutex; queries take a snapshot. The invariant the
//! concurrency stress test pins down: exactly one entry per admitted
//! submission, no matter how many threads race.

use std::sync::Mutex;

use crate::config::json::Json;
use crate::server::resolution::AuditReason;
use crate::types::IslandId;

use crate::util::sync::LockExt;

/// One audited decision.
#[derive(Clone, Debug)]
pub struct AuditEntry {
    pub request_id: u64,
    pub user: String,
    pub t_ms: f64,
    pub s_r: f64,
    /// None = rejected (fail-closed).
    pub island: Option<IslandId>,
    pub island_privacy: Option<f64>,
    pub sanitized: bool,
    /// Typed terminal state — shared verbatim with the caller's `Outcome`
    /// and the `requests_resolved{outcome,reason}` metric label.
    pub reason: AuditReason,
    /// Human-readable detail for non-served entries (why exactly, with
    /// request-specific numbers). `None` for served requests.
    pub reject_reason: Option<String>,
    /// How many times the request was re-routed after its island died
    /// between routing and execution. 0 = first-choice island served it;
    /// >0 with `island: Some` = failover success; >0 with a reject reason =
    /// retry budget exhausted. Every admitted request lands in exactly one
    /// of those buckets — the churn stress test pins this down.
    pub failovers: u32,
    /// Hex trace id joining this entry to the trace ring and event log.
    /// `None` only when tail sampling dropped the trace (or tracing is off).
    pub trace_id: Option<String>,
}

impl AuditEntry {
    /// Entry for a request that terminated before it was ever routed (shed
    /// at the admission queue, cancelled while queued, invalid, or orphaned
    /// by a panic/shutdown): it consumed a request id but there is no
    /// island and MIST never ran (`s_r` is recorded as 0.0).
    pub fn unrouted(request_id: u64, user: &str, t_ms: f64, reason: AuditReason, detail: &str) -> AuditEntry {
        AuditEntry {
            request_id,
            user: user.to_string(),
            t_ms,
            s_r: 0.0,
            island: None,
            island_privacy: None,
            sanitized: false,
            reason,
            reject_reason: Some(detail.to_string()),
            failovers: 0,
            trace_id: None,
        }
    }

    /// Attach the kept trace id (builder-style, used at every terminal site).
    pub fn with_trace(mut self, trace_id: Option<String>) -> AuditEntry {
        self.trace_id = trace_id;
        self
    }
}

/// Append-only concurrent audit log.
#[derive(Debug, Default)]
pub struct AuditLog {
    entries: Mutex<Vec<AuditEntry>>,
}

impl AuditLog {
    pub fn new() -> AuditLog {
        AuditLog::default()
    }

    pub fn record(&self, entry: AuditEntry) {
        self.entries.lock_clean().push(entry);
    }

    pub fn len(&self) -> usize {
        self.entries.lock_clean().len()
    }

    /// Is there already an entry for this request id? Used by the queue
    /// worker's panic recovery to keep "exactly one entry per consumed id":
    /// a straggler whose execution already landed on the trail must not get
    /// a second (shed) entry. Linear scan — recovery paths only.
    pub fn contains(&self, request_id: u64) -> bool {
        self.entries.lock_clean().iter().any(|e| e.request_id == request_id)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.lock_clean().is_empty()
    }

    /// Snapshot of the whole trail (clone; the log itself stays append-only).
    pub fn entries(&self) -> Vec<AuditEntry> {
        self.entries.lock_clean().clone()
    }

    /// All entries for one user (compliance review scope).
    pub fn for_user(&self, user: &str) -> Vec<AuditEntry> {
        self.entries.lock_clean().iter().filter(|e| e.user == user).cloned().collect()
    }

    /// Compliance check: were any requests with sensitivity above `s` ever
    /// executed on an island with privacy below `p`? Returns offending ids.
    pub fn violations(&self, s: f64, p: f64) -> Vec<u64> {
        self.entries
            .lock_clean()
            .iter()
            .filter(|e| e.s_r >= s && e.island_privacy.map(|ip| ip < p).unwrap_or(false))
            .map(|e| e.request_id)
            .collect()
    }

    /// Total failover re-routes recorded across the trail (cross-checked
    /// against the `failovers` metric by the churn stress test).
    pub fn total_failovers(&self) -> u64 {
        self.entries.lock_clean().iter().map(|e| e.failovers as u64).sum()
    }

    /// Entries for requests shed before reaching an island (queue-full,
    /// queued-deadline, invalid, panic/shutdown orphans) — derived from the
    /// typed reason, not a string prefix. The queue stress test pins "every
    /// shed request leaves exactly one audit entry" on this view.
    pub fn sheds(&self) -> Vec<AuditEntry> {
        self.entries.lock_clean().iter().filter(|e| e.reason.is_shed()).cloned().collect()
    }

    /// Entries for cancelled requests (caller cancel or a deadline expiring
    /// mid-decode). Typed as [`crate::server::Resolution::Cancelled`], so
    /// they stay disjoint from [`sheds`](Self::sheds): a cancelled request
    /// may have executed partially on an island and been charged for
    /// decoded tokens, while a shed never ran at all.
    pub fn cancellations(&self) -> Vec<AuditEntry> {
        self.entries.lock_clean().iter().filter(|e| e.reason.is_cancelled()).cloned().collect()
    }

    /// Export as a JSON array (regulator-facing artifact).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .lock_clean()
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("request_id", Json::num(e.request_id as f64)),
                        ("user", Json::str(&e.user)),
                        ("t_ms", Json::num(e.t_ms)),
                        ("s_r", Json::num(e.s_r)),
                        ("island", e.island.map(|i| Json::num(i.0 as f64)).unwrap_or(Json::Null)),
                        ("island_privacy", e.island_privacy.map(Json::num).unwrap_or(Json::Null)),
                        ("sanitized", Json::Bool(e.sanitized)),
                        ("outcome", Json::str(e.reason.class())),
                        ("reason", Json::str(e.reason.reason())),
                        ("reject_reason", e.reject_reason.as_deref().map(Json::str).unwrap_or(Json::Null)),
                        ("failovers", Json::num(e.failovers as f64)),
                        ("trace_id", e.trace_id.as_deref().map(Json::str).unwrap_or(Json::Null)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::resolution::{CancelPoint, FailReason, Resolution, ShedReason};

    fn entry(id: u64, s_r: f64, island: Option<(u32, f64)>) -> AuditEntry {
        AuditEntry {
            request_id: id,
            user: "alice".into(),
            t_ms: id as f64 * 10.0,
            s_r,
            island: island.map(|(i, _)| IslandId(i)),
            island_privacy: island.map(|(_, p)| p),
            sanitized: false,
            reason: if island.is_none() { Resolution::Failed(FailReason::FailClosed) } else { Resolution::Served },
            reject_reason: if island.is_none() { Some("fail-closed".into()) } else { None },
            failovers: 0,
            trace_id: Some(format!("{id:032x}")),
        }
    }

    #[test]
    fn append_and_query() {
        let log = AuditLog::new();
        log.record(entry(1, 0.9, Some((0, 1.0))));
        log.record(entry(2, 0.2, Some((5, 0.4))));
        log.record(entry(3, 0.9, None));
        assert_eq!(log.len(), 3);
        assert_eq!(log.for_user("alice").len(), 3);
        assert!(log.for_user("bob").is_empty());
    }

    #[test]
    fn violation_scan_finds_offenders() {
        let log = AuditLog::new();
        log.record(entry(1, 0.9, Some((0, 1.0)))); // fine
        log.record(entry(2, 0.9, Some((5, 0.4)))); // violation!
        log.record(entry(3, 0.9, None)); // rejected — not a violation
        assert_eq!(log.violations(0.9, 0.9), vec![2]);
        assert!(log.violations(0.95, 0.9).is_empty());
    }

    #[test]
    fn json_export_parses_back() {
        let log = AuditLog::new();
        log.record(entry(1, 0.5, Some((3, 0.8))));
        log.record(entry(2, 0.9, None));
        let j = log.to_json();
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.idx(0).get("request_id").as_i64(), Some(1));
        assert_eq!(back.idx(0).get("outcome").as_str(), Some("served"));
        assert_eq!(back.idx(1).get("island"), &Json::Null);
        assert_eq!(back.idx(1).get("outcome").as_str(), Some("failed"));
        assert_eq!(back.idx(1).get("reason").as_str(), Some("fail_closed"));
        assert_eq!(back.idx(1).get("reject_reason").as_str(), Some("fail-closed"));
        assert_eq!(back.idx(0).get("trace_id").as_str(), Some(format!("{:032x}", 1).as_str()));
        // unrouted entries default to no trace until with_trace attaches one
        let dropped = AuditEntry::unrouted(3, "alice", 1.0, entry(3, 0.0, None).reason, "x");
        assert_eq!(dropped.trace_id, None);
        assert_eq!(dropped.with_trace(Some("aa".into())).trace_id.as_deref(), Some("aa"));
    }

    #[test]
    fn shed_entries_are_scoped_by_typed_reason() {
        let log = AuditLog::new();
        log.record(entry(1, 0.5, Some((0, 1.0))));
        log.record(AuditEntry::unrouted(
            2,
            "alice",
            10.0,
            Resolution::Shed(ShedReason::QueueFull),
            "shed: admission queue full (8 queued, fail-closed)",
        ));
        log.record(entry(3, 0.9, None)); // plain fail-closed reject, not a shed
        log.record(AuditEntry::unrouted(
            4,
            "bob",
            20.0,
            Resolution::Shed(ShedReason::DeadlineExpired),
            "shed: deadline expired after 512 ms in queue",
        ));
        let sheds = log.sheds();
        assert_eq!(sheds.iter().map(|e| e.request_id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(sheds.iter().all(|e| e.island.is_none() && e.s_r == 0.0 && e.failovers == 0));
        // sheds never count as privacy violations (no island executed them)
        assert!(log.violations(0.0, 1.0).iter().all(|id| *id != 2 && *id != 4));
    }

    #[test]
    fn cancellations_are_scoped_by_typed_reason_and_disjoint_from_sheds() {
        let log = AuditLog::new();
        log.record(entry(1, 0.5, Some((0, 1.0))));
        log.record(AuditEntry::unrouted(
            2,
            "alice",
            10.0,
            Resolution::Shed(ShedReason::QueueFull),
            "shed: admission queue full (8 queued, fail-closed)",
        ));
        let mut cancelled = entry(3, 0.4, Some((1, 1.0)));
        cancelled.reason = Resolution::Cancelled(CancelPoint::DeadlineMidDecode);
        cancelled.reject_reason = Some("cancelled: deadline expired mid-decode after 24/512 tokens".into());
        log.record(cancelled);
        // a cancelled-while-queued entry is a cancellation, never a shed,
        // even though it uses the unrouted constructor
        log.record(AuditEntry::unrouted(
            5,
            "bob",
            30.0,
            Resolution::Cancelled(CancelPoint::WhileQueued),
            "cancelled: by caller after 12 ms in queue, before routing",
        ));
        assert_eq!(log.cancellations().iter().map(|e| e.request_id).collect::<Vec<_>>(), vec![3, 5]);
        assert_eq!(log.sheds().iter().map(|e| e.request_id).collect::<Vec<_>>(), vec![2]);
        // a mid-decode cancel ran on an island — the entry keeps it
        assert_eq!(log.cancellations()[0].island, Some(IslandId(1)));
    }

    #[test]
    fn concurrent_appends_all_land() {
        use std::sync::Arc;
        let log = Arc::new(AuditLog::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let log = Arc::clone(&log);
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        log.record(entry(t * 1000 + i, 0.5, Some((0, 1.0))));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.len(), 1600);
        let mut ids: Vec<u64> = log.entries().iter().map(|e| e.request_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 1600, "no entry lost or duplicated");
    }
}
