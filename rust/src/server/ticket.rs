//! Non-blocking request outcomes: a [`Ticket`] is handed back by
//! [`Orchestrator::enqueue`] the moment a request clears admission, and
//! resolves exactly once when the worker pool finishes (or sheds) the
//! request.
//!
//! The cell behind a ticket is a condvar-backed one-shot: the queue drain
//! resolves it with either a completed [`Outcome`] (served, fail-closed
//! reject, or shed) or an error message (session raced a close, fatal
//! execution error, orchestrator shut down). [`Ticket::wait`] blocks;
//! [`Ticket::try_poll`] never does — both may be called repeatedly and see
//! the same terminal value. `resolve` returns whether it won the one-shot,
//! so the queue-stress invariant "no ticket lost or double-resolved" is
//! checkable: the orchestrator counts any second resolution in the
//! `ticket_double_resolved` metric (which must stay 0).
//!
//! [`Orchestrator::enqueue`]: crate::server::Orchestrator::enqueue

use std::sync::{Arc, Condvar, Mutex};

use crate::server::orchestrator::Outcome;

/// Terminal value of a ticket: a completed outcome, or the error message of
/// a submission that fell out of the pipeline (`anyhow::Error` is not
/// `Clone`, and a ticket must serve repeated reads).
type TicketValue = Result<Outcome, String>;

/// Shared one-shot cell between a [`Ticket`] and the worker that resolves it.
#[derive(Debug, Default)]
pub(crate) struct TicketCell {
    state: Mutex<Option<TicketValue>>,
    cond: Condvar,
}

impl TicketCell {
    /// Resolve the one-shot. Returns `true` when this call installed the
    /// value, `false` when the ticket was already resolved (the new value is
    /// dropped — first resolution wins).
    pub(crate) fn resolve(&self, value: TicketValue) -> bool {
        let mut state = self.state.lock().unwrap();
        if state.is_some() {
            return false;
        }
        *state = Some(value);
        self.cond.notify_all();
        true
    }
}

/// Handle to one enqueued request's eventual [`Outcome`].
///
/// Returned by [`crate::server::Orchestrator::enqueue`]. Dropping a ticket
/// is safe — the request still runs and is still audited; only the caller's
/// view of the outcome is discarded.
#[derive(Clone, Debug)]
pub struct Ticket {
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// A fresh unresolved ticket plus the resolver side for the queue.
    pub(crate) fn new_pair() -> (Ticket, Arc<TicketCell>) {
        let cell = Arc::new(TicketCell::default());
        (Ticket { cell: Arc::clone(&cell) }, cell)
    }

    /// Block until the request reaches a terminal state and return it.
    /// Requires a running worker pool ([`crate::server::Orchestrator::start_queue`])
    /// unless the ticket was shed/rejected at enqueue time.
    pub fn wait(&self) -> anyhow::Result<Outcome> {
        let state = self.cell.state.lock().unwrap();
        let state = self.cell.cond.wait_while(state, |s| s.is_none()).unwrap();
        match state.as_ref().expect("wait_while guarantees Some") {
            Ok(outcome) => Ok(outcome.clone()),
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        }
    }

    /// Non-blocking poll: `None` while the request is still queued or
    /// executing, `Some` once terminal (repeatable).
    pub fn try_poll(&self) -> Option<anyhow::Result<Outcome>> {
        let state = self.cell.state.lock().unwrap();
        state.as_ref().map(|v| match v {
            Ok(outcome) => Ok(outcome.clone()),
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        })
    }

    /// Has the request reached a terminal state yet?
    pub fn is_resolved(&self) -> bool {
        self.cell.state.lock().unwrap().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::waves::Decision;

    fn outcome(id: u64) -> Outcome {
        Outcome {
            request_id: id,
            s_r: 0.1,
            decision: Decision::Reject { reason: "test".into() },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
        }
    }

    #[test]
    fn resolve_then_wait_and_poll() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(!ticket.is_resolved());
        assert!(ticket.try_poll().is_none());
        assert!(cell.resolve(Ok(outcome(7))));
        assert!(ticket.is_resolved());
        assert_eq!(ticket.wait().unwrap().request_id, 7);
        // repeatable reads see the same value
        assert_eq!(ticket.try_poll().unwrap().unwrap().request_id, 7);
        assert_eq!(ticket.wait().unwrap().request_id, 7);
    }

    #[test]
    fn second_resolution_loses() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(cell.resolve(Ok(outcome(1))));
        assert!(!cell.resolve(Ok(outcome(2))), "double resolution must report false");
        assert_eq!(ticket.wait().unwrap().request_id, 1, "first resolution wins");
    }

    #[test]
    fn error_resolution_surfaces_as_err() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(cell.resolve(Err("rate limited: user mallory".into())));
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("rate limited"), "{err}");
        assert!(ticket.try_poll().unwrap().is_err());
    }

    #[test]
    fn wait_blocks_until_resolved_across_threads() {
        let (ticket, cell) = Ticket::new_pair();
        let waiter = std::thread::spawn(move || ticket.wait().unwrap().request_id);
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(cell.resolve(Ok(outcome(42))));
        assert_eq!(waiter.join().unwrap(), 42);
    }
}
