//! Non-blocking request outcomes: a [`Ticket`] is handed back by
//! [`Orchestrator::enqueue`] the moment a request clears admission, and
//! resolves exactly once when the worker pool finishes (or sheds) the
//! request.
//!
//! The cell behind a ticket is a condvar-backed one-shot plus a token event
//! queue: the per-island step loop pushes incremental tokens as decode
//! steps complete, and the queue drain resolves the terminal value with
//! either a completed [`Outcome`] (served, fail-closed reject, shed, or
//! cancelled) or an error message (session raced a close, fatal execution
//! error, orchestrator shut down). Three ways to consume it:
//!
//! - [`Ticket::wait`] blocks for the terminal [`Outcome`] — the original
//!   surface, kept as a thin drain-the-stream shim so existing call sites
//!   compile unchanged,
//! - [`Ticket::try_poll`] never blocks — both may be called repeatedly and
//!   see the same terminal value,
//! - [`Ticket::stream`] yields [`TokenEvent`]s as they arrive: `First` for
//!   the time-to-first-token moment, `Token` for each later chunk, then
//!   exactly one of `Done` / `Cancelled`.
//!
//! [`Ticket::cancel`] is cooperative: it raises a flag the step loop
//! observes at the next decode-step boundary (or the drain observes at
//! admission), so a cancel frees the island's slot without un-booking
//! anything. `resolve` returns whether it won the one-shot, so the
//! queue-stress invariant "no ticket lost or double-resolved" is checkable:
//! the orchestrator counts any second resolution in the
//! `ticket_double_resolved` metric (which must stay 0).
//!
//! [`Orchestrator::enqueue`]: crate::server::Orchestrator::enqueue

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::server::orchestrator::Outcome;

use crate::util::sync::{cond_wait, cond_wait_while, LockExt};

/// Terminal value of a ticket: a completed outcome, or the error message of
/// a submission that fell out of the pipeline (`anyhow::Error` is not
/// `Clone`, and a ticket must serve repeated reads).
type TicketValue = Result<Outcome, String>;

/// One event on a ticket's token stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokenEvent {
    /// The first generated chunk — its arrival is the time-to-first-token.
    First { text: String },
    /// A subsequent generated chunk.
    Token { text: String },
    /// The request reached a successful terminal outcome.
    Done,
    /// The request was cancelled (caller cancel, mid-decode deadline
    /// expiry, shed, or pipeline error) — the stream ends here.
    Cancelled { reason: String },
}

/// Interior state guarded by the cell's mutex: the one-shot terminal value
/// plus the pending token events a streaming consumer has not read yet.
#[derive(Debug, Default)]
struct CellState {
    terminal: Option<TicketValue>,
    events: VecDeque<TokenEvent>,
    emitted_any: bool,
}

/// Shared cell between a [`Ticket`] and the worker that resolves it.
#[derive(Debug, Default)]
pub(crate) struct TicketCell {
    state: Mutex<CellState>,
    cond: Condvar,
    cancel: AtomicBool,
}

/// The stream event a terminal value maps to (for consumers that reach the
/// terminal before — or without — draining pushed tokens).
fn terminal_event(v: &TicketValue) -> TokenEvent {
    match v {
        Ok(out) if out.cancelled() => {
            TokenEvent::Cancelled { reason: format!("cancelled after {} tokens", out.tokens_generated) }
        }
        Ok(_) => TokenEvent::Done,
        Err(msg) => TokenEvent::Cancelled { reason: msg.clone() },
    }
}

impl TicketCell {
    /// Resolve the one-shot. Returns `true` when this call installed the
    /// value, `false` when the ticket was already resolved (the new value is
    /// dropped — first resolution wins). The matching terminal stream event
    /// is appended so a streaming consumer sees the end of the stream.
    pub(crate) fn resolve(&self, value: TicketValue) -> bool {
        let mut state = self.state.lock_clean();
        if state.terminal.is_some() {
            return false;
        }
        state.events.push_back(terminal_event(&value));
        state.terminal = Some(value);
        self.cond.notify_all();
        true
    }

    /// Push an incremental token chunk (step loop → streaming consumer).
    /// No-op after the terminal value landed.
    pub(crate) fn push_tokens(&self, text: &str) {
        let mut state = self.state.lock_clean();
        if state.terminal.is_some() {
            return;
        }
        let event = if state.emitted_any {
            TokenEvent::Token { text: text.to_string() }
        } else {
            TokenEvent::First { text: text.to_string() }
        };
        state.emitted_any = true;
        state.events.push_back(event);
        self.cond.notify_all();
    }

    /// Has the consumer asked for this request to be cancelled?
    pub(crate) fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }
}

/// Handle to one enqueued request's eventual [`Outcome`].
///
/// Returned by [`crate::server::Orchestrator::enqueue`]. Dropping a ticket
/// is safe — the request still runs and is still audited; only the caller's
/// view of the outcome is discarded.
#[derive(Clone, Debug)]
pub struct Ticket {
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// A fresh unresolved ticket plus the resolver side for the queue.
    pub(crate) fn new_pair() -> (Ticket, Arc<TicketCell>) {
        let cell = Arc::new(TicketCell::default());
        (Ticket { cell: Arc::clone(&cell) }, cell)
    }

    /// Block until the request reaches a terminal state and return it.
    /// Requires a running worker pool ([`crate::server::Orchestrator::start_queue`])
    /// unless the ticket was shed/rejected at enqueue time.
    ///
    /// Compatibility shim over the streaming surface: waits for the
    /// terminal value, ignoring incremental tokens (the full response is in
    /// [`Outcome::response`]).
    pub fn wait(&self) -> anyhow::Result<Outcome> {
        let state = self.cell.state.lock_clean();
        let state = cond_wait_while(&self.cell.cond, state, |s| s.terminal.is_none());
        match state.terminal.as_ref() {
            Some(Ok(outcome)) => Ok(outcome.clone()),
            Some(Err(msg)) => Err(anyhow::anyhow!("{msg}")),
            // wait_while only returns once terminal is Some; shed fail-closed
            // rather than panic if that ever regresses.
            None => Err(anyhow::anyhow!("ticket woke without a terminal state")),
        }
    }

    /// Non-blocking poll: `None` while the request is still queued or
    /// executing, `Some` once terminal (repeatable).
    pub fn try_poll(&self) -> Option<anyhow::Result<Outcome>> {
        let state = self.cell.state.lock_clean();
        state.terminal.as_ref().map(|v| match v {
            Ok(outcome) => Ok(outcome.clone()),
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        })
    }

    /// Has the request reached a terminal state yet?
    pub fn is_resolved(&self) -> bool {
        self.cell.state.lock_clean().terminal.is_some()
    }

    /// Request cancellation. Cooperative: the step loop observes the flag
    /// at the next decode-step boundary (freeing the island's slot
    /// immediately), the drain observes it at admission; either resolves
    /// the ticket with a cancelled [`Outcome`]. Requires a running worker
    /// pool to take effect; cancelling an already-terminal ticket is a
    /// no-op.
    pub fn cancel(&self) {
        self.cell.cancel.store(true, Ordering::SeqCst);
    }

    /// Blocking iterator over this request's [`TokenEvent`]s: zero or more
    /// `First`/`Token` chunks, then exactly one `Done` or `Cancelled`.
    /// Single-consumer per stream instance; a fresh `stream()` on a
    /// terminal ticket yields just the terminal event.
    pub fn stream(&self) -> TokenStream {
        TokenStream { cell: Arc::clone(&self.cell), done: false }
    }
}

/// Blocking token-event iterator — see [`Ticket::stream`].
#[derive(Debug)]
pub struct TokenStream {
    cell: Arc<TicketCell>,
    done: bool,
}

impl Iterator for TokenStream {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        if self.done {
            return None;
        }
        let mut state = self.cell.state.lock_clean();
        loop {
            if let Some(event) = state.events.pop_front() {
                if matches!(event, TokenEvent::Done | TokenEvent::Cancelled { .. }) {
                    self.done = true;
                }
                return Some(event);
            }
            if let Some(v) = state.terminal.as_ref() {
                // a previous stream instance consumed the queued terminal
                // event: synthesize it so every stream ends properly
                self.done = true;
                return Some(terminal_event(v));
            }
            state = cond_wait(&self.cell.cond, state);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::waves::Decision;
    use crate::server::resolution::{CancelPoint, FailReason, Resolution};

    fn outcome(id: u64) -> Outcome {
        Outcome {
            request_id: id,
            s_r: 0.1,
            decision: Decision::Reject { reason: "test".into() },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
            tokens_generated: 0,
            resolution: Resolution::Failed(FailReason::FailClosed),
        }
    }

    #[test]
    fn resolve_then_wait_and_poll() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(!ticket.is_resolved());
        assert!(ticket.try_poll().is_none());
        assert!(cell.resolve(Ok(outcome(7))));
        assert!(ticket.is_resolved());
        assert_eq!(ticket.wait().unwrap().request_id, 7);
        // repeatable reads see the same value
        assert_eq!(ticket.try_poll().unwrap().unwrap().request_id, 7);
        assert_eq!(ticket.wait().unwrap().request_id, 7);
    }

    #[test]
    fn second_resolution_loses() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(cell.resolve(Ok(outcome(1))));
        assert!(!cell.resolve(Ok(outcome(2))), "double resolution must report false");
        assert_eq!(ticket.wait().unwrap().request_id, 1, "first resolution wins");
    }

    #[test]
    fn error_resolution_surfaces_as_err() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(cell.resolve(Err("rate limited: user mallory".into())));
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("rate limited"), "{err}");
        assert!(ticket.try_poll().unwrap().is_err());
    }

    #[test]
    fn wait_blocks_until_resolved_across_threads() {
        let (ticket, cell) = Ticket::new_pair();
        let waiter = std::thread::spawn(move || ticket.wait().unwrap().request_id);
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(cell.resolve(Ok(outcome(42))));
        assert_eq!(waiter.join().unwrap(), 42);
    }

    #[test]
    fn stream_yields_first_then_tokens_then_done() {
        let (ticket, cell) = Ticket::new_pair();
        cell.push_tokens("hel");
        cell.push_tokens("lo");
        assert!(cell.resolve(Ok(outcome(3))));
        let events: Vec<TokenEvent> = ticket.stream().collect();
        assert_eq!(
            events,
            vec![
                TokenEvent::First { text: "hel".into() },
                TokenEvent::Token { text: "lo".into() },
                TokenEvent::Done,
            ]
        );
        // the iterator is fused after the terminal event
        assert_eq!(ticket.stream().count(), 1, "fresh stream on a terminal ticket sees just the terminal");
    }

    #[test]
    fn stream_blocks_until_events_arrive() {
        let (ticket, cell) = Ticket::new_pair();
        let consumer = std::thread::spawn(move || ticket.stream().collect::<Vec<_>>());
        std::thread::sleep(std::time::Duration::from_millis(10));
        cell.push_tokens("x");
        cell.resolve(Ok(outcome(9)));
        let events = consumer.join().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0], TokenEvent::First { text: "x".into() });
        assert_eq!(events[1], TokenEvent::Done);
    }

    #[test]
    fn cancelled_outcome_ends_the_stream_with_cancelled() {
        let (ticket, cell) = Ticket::new_pair();
        ticket.cancel();
        assert!(cell.cancel_requested());
        let mut out = outcome(5);
        out.resolution = Resolution::Cancelled(CancelPoint::MidDecode);
        out.tokens_generated = 12;
        assert!(cell.resolve(Ok(out)));
        let events: Vec<TokenEvent> = ticket.stream().collect();
        assert_eq!(events, vec![TokenEvent::Cancelled { reason: "cancelled after 12 tokens".into() }]);
        // wait() still surfaces the cancelled outcome, not an error
        let got = ticket.wait().unwrap();
        assert!(got.cancelled());
        assert_eq!(got.tokens_generated, 12);
    }

    #[test]
    fn tokens_after_terminal_are_dropped() {
        let (ticket, cell) = Ticket::new_pair();
        assert!(cell.resolve(Ok(outcome(1))));
        cell.push_tokens("late");
        assert_eq!(ticket.stream().collect::<Vec<_>>(), vec![TokenEvent::Done]);
    }
}
