//! The IslandRun orchestrator: the Fig. 2 route-then-sanitize pipeline as a
//! thread-safe façade over the agents, the session store and an execution
//! backend.
//!
//!   client → [rate limit] → MIST s_r → TIDE R(t) → WAVES Alg. 1 →
//!   [sanitize h_r on trust-boundary crossing] → island execute →
//!   [desanitize response] → client
//!
//! Concurrency model: [`Orchestrator::submit`] takes `&self`, so any number
//! of threads can drive the pipeline through `Arc<Orchestrator>`. Request
//! ids come from an atomic counter; sessions live in an `RwLock`-sharded
//! store; metrics, the cost ledger and the audit log are internally
//! synchronized; the hysteresis state machine and the per-user rate limiter
//! sit behind short mutexes (they are tiny state updates, far from the
//! heavy MIST/route work which runs lock-free).
//!
//! Batching: [`Orchestrator::submit_many`] routes a whole batch first, then
//! coalesces requests that landed on the same island through the
//! [`Batcher`] policy — on the Real backend each group becomes one
//! `execute_batch` call, filling the compiled PJRT batch variants instead
//! of dispatching row by row (Fig. 2's island-execute stage is where the
//! batcher sits).
//!
//! Backends:
//! - [`Backend::Sim`] — virtual-time [`Fleet`] (evals, examples, attacks),
//! - [`Backend::Real`] — PJRT TinyLM through [`IslandExecutor`]
//!   (quickstart / serving bench; python stays off this path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::agents::mist::sanitize::sanitize_history;
use crate::agents::mist::Mist;
use crate::agents::tide::hysteresis::Hysteresis;
use crate::agents::waves::{Decision, Routed, Waves};
use crate::config::Config;
use crate::islands::executor::IslandExecutor;
use crate::islands::{CostLedger, Fleet};
use crate::runtime::{BatchPolicy, Batcher};
use crate::server::audit::{AuditEntry, AuditLog};
use crate::server::ratelimit::RateLimiter;
use crate::server::session::SessionStore;
use crate::telemetry::Metrics;
use crate::types::{Island, PriorityTier, Request};

/// Execution backend.
pub enum Backend {
    Sim(Fleet),
    Real { executor: IslandExecutor, islands: Vec<Island> },
}

/// Result of one submitted request.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub request_id: u64,
    /// MIST sensitivity.
    pub s_r: f64,
    pub decision: Decision,
    /// End-to-end latency (virtual ms for Sim, wall ms for Real).
    pub latency_ms: f64,
    pub cost: f64,
    /// Final (desanitized) response text; sim backend synthesizes one.
    pub response: String,
    /// Whether history sanitization was applied this turn.
    pub sanitized: bool,
}

/// One item of a batched submission (see [`Orchestrator::submit_many`]).
#[derive(Clone, Debug)]
pub struct BatchItem<'a> {
    pub prompt: &'a str,
    pub priority: PriorityTier,
    pub dataset: Option<&'a str>,
}

/// A request that cleared admission + routing and awaits execution.
struct Prepared {
    id: u64,
    session_id: u64,
    user: String,
    request: Request,
    s_r: f64,
    decision: Decision,
    routed: Routed,
    sanitized: bool,
    now: f64,
}

/// The orchestrator.
pub struct Orchestrator {
    pub waves: Waves,
    pub mist: Mist,
    backend: Backend,
    hysteresis: Mutex<Hysteresis>,
    pub sessions: SessionStore,
    pub ledger: CostLedger,
    pub metrics: Metrics,
    /// §XIV compliance audit trail of every decision (incl. rejections).
    pub audit: AuditLog,
    limiter: Mutex<RateLimiter>,
    next_request_id: AtomicU64,
    budget_ceiling: f64,
    batch_policy: BatchPolicy,
    /// Wall-clock epoch for the Real backend's rate limiting.
    started: std::time::Instant,
}

impl Orchestrator {
    pub fn new(config: Config, mist: Mist, backend: Backend, seed: u64) -> Orchestrator {
        let hysteresis = Hysteresis::new(config.hysteresis_low, config.hysteresis_high);
        let limiter = RateLimiter::new(config.rate_limit_rps, config.rate_limit_rps.max(1.0));
        let budget_ceiling = config.budget_ceiling;
        Orchestrator {
            waves: Waves::new(config),
            mist,
            backend,
            hysteresis: Mutex::new(hysteresis),
            sessions: SessionStore::new(seed),
            ledger: CostLedger::new(),
            metrics: Metrics::new(),
            audit: AuditLog::new(),
            limiter: Mutex::new(limiter),
            next_request_id: AtomicU64::new(1),
            budget_ceiling,
            batch_policy: BatchPolicy::default(),
            started: std::time::Instant::now(),
        }
    }

    /// Override the island-execute batching policy (see [`Batcher`]).
    pub fn set_batch_policy(&mut self, policy: BatchPolicy) {
        self.batch_policy = policy;
    }

    /// Open a session for a user.
    pub fn open_session(&self, user: &str) -> u64 {
        self.sessions.open(user)
    }

    fn now_ms(&self) -> f64 {
        match &self.backend {
            Backend::Sim(fleet) => fleet.now(),
            // wall-clock ms since startup, so the per-user token bucket
            // actually refills on the real serving path
            Backend::Real { .. } => self.started.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Advance virtual time (sim backend).
    pub fn advance(&self, dt_ms: f64) {
        if let Backend::Sim(fleet) = &self.backend {
            fleet.advance(dt_ms);
        }
    }

    pub fn fleet(&self) -> Option<&Fleet> {
        match &self.backend {
            Backend::Sim(f) => Some(f),
            _ => None,
        }
    }

    pub fn fleet_mut(&mut self) -> Option<&mut Fleet> {
        match &mut self.backend {
            Backend::Sim(f) => Some(f),
            _ => None,
        }
    }

    /// Admission + MIST + TIDE + WAVES + sanitize for one prompt: everything
    /// before island execution. `Err` = rate limited / unknown session;
    /// `Ok(Err(outcome))` = audited fail-closed rejection;
    /// `Ok(Ok(prepared))` = routed and ready to execute.
    fn prepare(
        &self,
        session_id: u64,
        prompt: &str,
        priority: PriorityTier,
        dataset: Option<&str>,
    ) -> anyhow::Result<Result<Prepared, Outcome>> {
        // Deliberately a separate (cheap) lookup from the history fetch
        // below: admission must run before any per-request work, and the
        // history clone is attacker-sized — a flooding user should cost us
        // only this user-name read before the limiter turns them away.
        let user = self
            .sessions
            .user_of(session_id)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session_id}"))?;

        // Attack-4 mitigation: rate limit before any work
        let now = self.now_ms();
        if !self.limiter.lock().unwrap().admit(&user, now) {
            self.metrics.count("rate_limited", 1);
            anyhow::bail!("rate limited: user {user}");
        }

        let id = self.next_request_id.fetch_add(1, Ordering::SeqCst);

        // From here on the request has consumed an id and a rate-limit
        // token, so every exit — including sessions racing close() — must
        // leave an audit entry (§XIV: no vanished ids).
        let Some((history, prev_privacy)) =
            self.sessions.with(session_id, |s| (s.history.clone(), s.prev_island_privacy))
        else {
            self.audit_vanished(id, &user, now, 0.0, "session closed before routing");
            anyhow::bail!("unknown session {session_id}");
        };
        let mut request = Request::new(id, prompt).with_user(&user).with_priority(priority).with_history(history);
        request.prev_island_privacy = prev_privacy;
        if let Some(ds) = dataset {
            request = request.with_dataset(ds);
        }

        // MIST sensitivity (Alg. 1 line 1)
        let report = self.mist.analyze(&request);
        let s_r = report.score;
        request.sensitivity = Some(s_r);
        self.metrics.observe("mist_s_r", s_r);

        // TIDE capacity (Alg. 1 line 2) + hysteresis preference
        let (states, local_capacity) = match &self.backend {
            Backend::Sim(fleet) => (fleet.states(), fleet.local_capacity()),
            Backend::Real { islands, .. } => (
                islands
                    .iter()
                    .map(|i| crate::agents::waves::IslandState { island: i.clone(), capacity: 1.0 })
                    .collect(),
                1.0,
            ),
        };
        let pref = self.hysteresis.lock().unwrap().observe(local_capacity);
        self.metrics.gauge("local_capacity", local_capacity);

        // WAVES decision (Alg. 1)
        let budget_left = self.ledger.remaining(&user, self.budget_ceiling);
        let decision = self.waves.route(&request, s_r, &states, local_capacity, pref, budget_left);

        let routed = match decision.routed() {
            None => {
                self.metrics.count("rejected_fail_closed", 1);
                let reason = match &decision {
                    Decision::Reject { reason } => Some(reason.clone()),
                    _ => None,
                };
                self.audit.record(AuditEntry {
                    request_id: id,
                    user,
                    t_ms: now,
                    s_r,
                    island: None,
                    island_privacy: None,
                    sanitized: false,
                    reject_reason: reason,
                });
                return Ok(Err(Outcome {
                    request_id: id,
                    s_r,
                    decision,
                    latency_ms: 0.0,
                    cost: 0.0,
                    response: String::new(),
                    sanitized: false,
                }));
            }
            Some(r) => r.clone(),
        };

        // Sanitize on trust-boundary crossing (Alg. 1 lines 14-17)
        let mut sanitized = false;
        if routed.sanitize {
            let Some((clean_history, clean_prompt)) = self.sessions.with_mut(session_id, |s| {
                let h = sanitize_history(&request.history, routed.target_privacy, &mut s.placeholders);
                // the outgoing prompt is sanitized at the same level
                let p = s.placeholders.sanitize(&request.prompt, routed.target_privacy);
                (h, p)
            }) else {
                self.audit_vanished(id, &user, now, s_r, "session closed before sanitization");
                anyhow::bail!("session {session_id} closed mid-request");
            };
            request.history = clean_history;
            request.prompt = clean_prompt;
            sanitized = true;
            self.metrics.count("sanitized_turns", 1);
        }

        Ok(Ok(Prepared { id, session_id, user, request, s_r, decision, routed, sanitized, now }))
    }

    /// Audit trail entry for a request that consumed an id but fell out of
    /// the pipeline before execution (e.g. its session raced a `close()`).
    fn audit_vanished(&self, id: u64, user: &str, now: f64, s_r: f64, reason: &str) {
        self.audit.record(AuditEntry {
            request_id: id,
            user: user.to_string(),
            t_ms: now,
            s_r,
            island: None,
            island_privacy: None,
            sanitized: false,
            reject_reason: Some(reason.to_string()),
        });
    }

    /// Audit trail entry for a request that was admitted and routed but
    /// failed at execution — without this, failed executions would consume
    /// request ids yet vanish from the §XIV compliance trail.
    fn audit_execution_failure(&self, p: &Prepared, err: &anyhow::Error) {
        self.metrics.count("execution_failed", 1);
        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: Some(p.routed.target),
            island_privacy: Some(p.routed.target_privacy),
            sanitized: p.sanitized,
            reject_reason: Some(format!("execution failed: {err}")),
        });
    }

    /// Post-execution bookkeeping shared by the single and batched paths.
    /// Does NOT append the conversation turn — callers do, so the batched
    /// path can record turns in submission order.
    fn finish(&self, p: Prepared, latency_ms: f64, cost: f64, raw_response: String) -> Outcome {
        // Desanitize the response before the user sees it (backward pass)
        let response = if p.sanitized {
            self.sessions.with(p.session_id, |s| s.placeholders.desanitize(&raw_response)).unwrap_or(raw_response)
        } else {
            raw_response
        };

        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: Some(p.routed.target),
            island_privacy: Some(p.routed.target_privacy),
            sanitized: p.sanitized,
            reject_reason: None,
        });
        self.ledger.charge(&p.user, cost);
        self.metrics.count("requests_served", 1);
        self.metrics.observe("latency_ms", latency_ms);
        self.metrics.observe("cost_usd", cost.max(1e-9));

        Outcome {
            request_id: p.id,
            s_r: p.s_r,
            decision: p.decision,
            latency_ms,
            cost,
            response,
            sanitized: p.sanitized,
        }
    }

    fn island_spec(&self, p: &Prepared) -> anyhow::Result<Option<Island>> {
        match &self.backend {
            Backend::Sim(_) => Ok(None),
            Backend::Real { islands, .. } => Ok(Some(
                islands
                    .iter()
                    .find(|i| i.id == p.routed.target)
                    .ok_or_else(|| anyhow::anyhow!("island {} missing", p.routed.target))?
                    .clone(),
            )),
        }
    }

    /// Submit one prompt within a session (Fig. 2 pipeline). Returns Err
    /// for rate-limited submissions, Ok(Outcome) otherwise — including
    /// fail-closed rejections, which are Outcomes with a Reject decision.
    pub fn submit(
        &self,
        session_id: u64,
        prompt: &str,
        priority: PriorityTier,
        dataset: Option<&str>,
    ) -> anyhow::Result<Outcome> {
        let prepared = match self.prepare(session_id, prompt, priority, dataset)? {
            Err(rejected) => return Ok(rejected),
            Ok(p) => p,
        };

        // Execute
        let exec: anyhow::Result<(f64, f64, String)> = match &self.backend {
            Backend::Sim(fleet) => match fleet.execute(prepared.routed.target, &prepared.request) {
                None => Err(anyhow::anyhow!("island {} missing", prepared.routed.target)),
                Some(rep) => {
                    let ack =
                        format!("[sim:{}] ack {} tokens", prepared.routed.target, prepared.request.max_new_tokens);
                    Ok((rep.latency_ms, rep.cost, ack))
                }
            },
            Backend::Real { executor, .. } => (|| {
                let island = self.island_spec(&prepared)?.expect("real backend has specs");
                let resp = executor.execute(&island, &prepared.request)?;
                Ok((resp.compute_ms + resp.network_ms, resp.cost, resp.text))
            })(),
        };
        let (latency_ms, cost, raw_response) = match exec {
            Ok(x) => x,
            Err(e) => {
                self.audit_execution_failure(&prepared, &e);
                return Err(e);
            }
        };

        let target_privacy = prepared.routed.target_privacy;
        let outcome = self.finish(prepared, latency_ms, cost, raw_response);
        // record the turn against the island it actually ran on
        let _ = self.sessions.with_mut(session_id, |s| s.record_turn(prompt, &outcome.response, target_privacy));
        Ok(outcome)
    }

    /// Submit a batch of prompts for one session. Each item is admitted,
    /// scored and routed like a [`submit`] call racing the rest of the
    /// batch: routing and sanitization see the pre-batch session snapshot
    /// (items do not observe each other's turns), while conversation turns
    /// are appended in input order once the whole batch has executed.
    /// Items co-routed to the same island are coalesced through the
    /// [`Batcher`]'s `max_batch` cap and executed together — on the Real
    /// backend one `execute_batch` call per group fills the compiled PJRT
    /// batch variants. (`max_wait` governs streaming accumulation when a
    /// caller owns a long-lived `Batcher`; this synchronous path always
    /// flushes immediately.) Per-item results preserve input order.
    ///
    /// [`submit`]: Orchestrator::submit
    /// [`Batcher`]: crate::runtime::Batcher
    pub fn submit_many(&self, session_id: u64, items: &[BatchItem<'_>]) -> Vec<anyhow::Result<Outcome>> {
        let mut results: Vec<Option<anyhow::Result<Outcome>>> = (0..items.len()).map(|_| None).collect();
        let mut ready: Vec<(usize, Prepared)> = Vec::new();

        for (idx, item) in items.iter().enumerate() {
            match self.prepare(session_id, item.prompt, item.priority, item.dataset) {
                Err(e) => results[idx] = Some(Err(e)),
                Ok(Err(rejected)) => results[idx] = Some(Ok(rejected)),
                Ok(Ok(prepared)) => ready.push((idx, prepared)),
            }
        }

        // Coalesce co-routed requests per target island, FIFO, chunked by
        // the batching policy.
        let mut by_island: Vec<(crate::types::IslandId, Batcher<(usize, Prepared)>)> = Vec::new();
        for (idx, prepared) in ready {
            let target = prepared.routed.target;
            let pos = match by_island.iter().position(|(id, _)| *id == target) {
                Some(p) => p,
                None => {
                    by_island.push((target, Batcher::new(self.batch_policy)));
                    by_island.len() - 1
                }
            };
            by_island[pos].1.push((idx, prepared));
        }

        for (_, mut batcher) in by_island {
            while !batcher.is_empty() {
                let group = batcher.take_batch();
                self.metrics.observe("batch_group_size", group.len() as f64);
                match &self.backend {
                    Backend::Sim(fleet) => {
                        for (idx, prepared) in group {
                            let result = match fleet.execute(prepared.routed.target, &prepared.request) {
                                None => {
                                    let e = anyhow::anyhow!("island {} missing", prepared.routed.target);
                                    self.audit_execution_failure(&prepared, &e);
                                    Err(e)
                                }
                                Some(rep) => {
                                    let ack = format!(
                                        "[sim:{}] ack {} tokens",
                                        prepared.routed.target, prepared.request.max_new_tokens
                                    );
                                    Ok(self.finish(prepared, rep.latency_ms, rep.cost, ack))
                                }
                            };
                            results[idx] = Some(result);
                        }
                    }
                    Backend::Real { executor, .. } => {
                        let island = match self.island_spec(&group[0].1) {
                            Ok(spec) => spec.expect("real backend has specs"),
                            Err(e) => {
                                for (idx, prepared) in group {
                                    let err = anyhow::anyhow!("{e}");
                                    self.audit_execution_failure(&prepared, &err);
                                    results[idx] = Some(Err(err));
                                }
                                continue;
                            }
                        };
                        let requests: Vec<Request> = group.iter().map(|(_, p)| p.request.clone()).collect();
                        match executor.execute_batch(&island, &requests) {
                            Ok(responses) => {
                                for ((idx, prepared), resp) in group.into_iter().zip(responses) {
                                    let latency = resp.compute_ms + resp.network_ms;
                                    results[idx] = Some(Ok(self.finish(prepared, latency, resp.cost, resp.text)));
                                }
                            }
                            Err(e) => {
                                let msg = e.to_string();
                                for (idx, prepared) in group {
                                    let err = anyhow::anyhow!("batch execute failed: {msg}");
                                    self.audit_execution_failure(&prepared, &err);
                                    results[idx] = Some(Err(err));
                                }
                            }
                        }
                    }
                }
            }
        }

        // Append conversation turns in input order (executed items only),
        // so the stored history reads as the user submitted it even though
        // island groups completed in arbitrary order.
        for (idx, item) in items.iter().enumerate() {
            if let Some(Ok(out)) = &results[idx] {
                if let Some(r) = out.decision.routed() {
                    let _ = self
                        .sessions
                        .with_mut(session_id, |s| s.record_turn(item.prompt, &out.response, r.target_privacy));
                }
            }
        }

        results.into_iter().map(|r| r.expect("every item decided")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn sim_orchestrator() -> Orchestrator {
        let fleet = Fleet::new(preset_personal_group(), 11);
        Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 42)
    }

    #[test]
    fn sensitive_prompt_stays_personal() {
        let o = sim_orchestrator();
        let s = o.open_session("alice");
        let out = o.submit(s, "patient john doe ssn 123-45-6789 diagnosed with diabetes", PriorityTier::Primary, None).unwrap();
        assert!(out.s_r >= 0.9);
        let target = out.decision.target().unwrap();
        let islands = preset_personal_group();
        assert_eq!(islands.iter().find(|i| i.id == target).unwrap().privacy, 1.0);
        assert_eq!(out.cost, 0.0);
        assert!(!out.sanitized, "intra-personal must bypass MIST sanitization");
    }

    #[test]
    fn boundary_crossing_sanitizes_and_desanitizes() {
        let o = sim_orchestrator();
        let s = o.open_session("alice");
        // turn 1: sensitive, runs locally
        o.submit(s, "patient john doe has diabetes", PriorityTier::Primary, None).unwrap();
        // saturate local islands so the next burstable turn offloads
        for island in o.fleet().unwrap().islands.iter() {
            if !island.spec.unbounded() {
                island.set_external_load(0.99);
            }
        }
        let out = o.submit(s, "what are common complications", PriorityTier::Burstable, None).unwrap();
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == out.decision.target().unwrap()).unwrap();
        assert!(target.privacy < 1.0, "should offload, got {}", target.name);
        assert!(out.sanitized, "crossing 1.0 -> {} must sanitize history", target.privacy);
        // stored history must keep the ORIGINAL user text (desanitized view)
        let has = o.sessions.with(s, |sess| sess.history.iter().any(|t| t.text.contains("complications"))).unwrap();
        assert!(has);
    }

    #[test]
    fn rejection_is_fail_closed_not_error() {
        let mut o = sim_orchestrator();
        // remove all personal islands: sensitive requests unroutable
        o.fleet_mut().unwrap().islands.retain(|i| i.spec.privacy < 0.9);
        let s = o.open_session("bob");
        let out = o.submit(s, "patient john doe ssn 123-45-6789", PriorityTier::Primary, None).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.metrics.counter_value("rejected_fail_closed"), 1);
    }

    #[test]
    fn rate_limit_blocks_floods() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 2.0;
        let fleet = Fleet::new(preset_personal_group(), 1);
        let o = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 1);
        let s = o.open_session("mallory");
        let mut blocked = 0;
        for _ in 0..10 {
            if o.submit(s, "hello", PriorityTier::Burstable, None).is_err() {
                blocked += 1;
            }
        }
        assert!(blocked >= 7, "blocked={blocked}");
        assert!(o.metrics.counter_value("rate_limited") >= 7);
    }

    #[test]
    fn ledger_tracks_cloud_spend() {
        let o = sim_orchestrator();
        let s = o.open_session("carol");
        // saturate local → burstable goes to cloud and pays
        for island in o.fleet().unwrap().islands.iter() {
            if !island.spec.unbounded() {
                island.set_external_load(0.99);
            }
        }
        let out = o.submit(s, "what is the capital of france", PriorityTier::Burstable, None).unwrap();
        assert!(out.cost > 0.0);
        assert!(o.ledger.spent("carol") > 0.0);
    }

    #[test]
    fn audit_log_records_every_decision() {
        let mut o = sim_orchestrator();
        let s = o.open_session("auditor");
        o.submit(s, "hello world", PriorityTier::Secondary, None).unwrap();
        o.submit(s, "patient john doe ssn 123-45-6789", PriorityTier::Primary, None).unwrap();
        assert_eq!(o.audit.len(), 2);
        // compliance scan over the trail: no entry with s_r>=0.9 ran below P=0.9
        assert!(o.audit.violations(0.9, 0.9).is_empty());
        // rejections are audited too
        o.fleet_mut().unwrap().islands.retain(|i| i.spec.privacy < 0.9);
        let out = o.submit(s, "patient jane smith mrn 12345", PriorityTier::Primary, None).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.audit.len(), 3);
        assert!(o.audit.entries().last().unwrap().reject_reason.is_some());
    }

    #[test]
    fn metrics_populated() {
        let o = sim_orchestrator();
        let s = o.open_session("dave");
        o.submit(s, "hello world", PriorityTier::Secondary, None).unwrap();
        assert_eq!(o.metrics.counter_value("requests_served"), 1);
        assert!(o.metrics.histogram("latency_ms").unwrap().count() == 1);
    }

    #[test]
    fn concurrent_submit_through_arc() {
        use std::sync::Arc;
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 5);
        let o = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 5));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let o = Arc::clone(&o);
                std::thread::spawn(move || {
                    let s = o.open_session(&format!("user-{t}"));
                    let mut ids = Vec::new();
                    for _ in 0..25 {
                        let out = o.submit(s, "hello world", PriorityTier::Secondary, None).unwrap();
                        ids.push(out.request_id);
                        o.advance(50.0);
                    }
                    ids
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100, "request ids must be unique across threads");
        assert_eq!(o.audit.len(), 100);
    }

    #[test]
    fn submit_many_matches_submit_semantics_and_coalesces() {
        let o = sim_orchestrator();
        let s = o.open_session("batcher");
        let items: Vec<BatchItem<'_>> = vec![
            BatchItem { prompt: "hello world", priority: PriorityTier::Secondary, dataset: None },
            BatchItem { prompt: "patient john doe ssn 123-45-6789", priority: PriorityTier::Primary, dataset: None },
            BatchItem { prompt: "explain how rust ownership works", priority: PriorityTier::Secondary, dataset: None },
        ];
        let results = o.submit_many(s, &items);
        assert_eq!(results.len(), 3);
        for r in &results {
            let out = r.as_ref().unwrap();
            assert!(out.decision.target().is_some());
        }
        // every admitted item is audited exactly once
        assert_eq!(o.audit.len(), 3);
        // the PHI item must have stayed on a P=1.0 island
        let islands = preset_personal_group();
        let phi_target = results[1].as_ref().unwrap().decision.target().unwrap();
        assert_eq!(islands.iter().find(|i| i.id == phi_target).unwrap().privacy, 1.0);
        // grouping metric recorded
        assert!(o.metrics.histogram("batch_group_size").unwrap().count() >= 1);
    }
}
