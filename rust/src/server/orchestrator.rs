//! The IslandRun orchestrator: the Fig. 2 route-then-sanitize pipeline as a
//! single façade over the agents, the session store and an execution
//! backend.
//!
//!   client → [rate limit] → MIST s_r → TIDE R(t) → WAVES Alg. 1 →
//!   [sanitize h_r on trust-boundary crossing] → island execute →
//!   [desanitize response] → client
//!
//! Backends:
//! - [`Backend::Sim`] — virtual-time [`Fleet`] (evals, examples, attacks),
//! - [`Backend::Real`] — PJRT TinyLM through [`IslandExecutor`]
//!   (quickstart / serving bench; python stays off this path).

use crate::agents::mist::sanitize::sanitize_history;
use crate::agents::mist::Mist;
use crate::agents::tide::hysteresis::Hysteresis;
use crate::agents::waves::{Decision, Waves};
use crate::config::Config;
use crate::islands::executor::IslandExecutor;
use crate::islands::{CostLedger, Fleet};
use crate::server::audit::{AuditEntry, AuditLog};
use crate::server::ratelimit::RateLimiter;
use crate::server::session::SessionStore;
use crate::telemetry::Metrics;
use crate::types::{Island, PriorityTier, Request};

/// Execution backend.
pub enum Backend {
    Sim(Fleet),
    Real { executor: IslandExecutor, islands: Vec<Island> },
}

/// Result of one submitted request.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub request_id: u64,
    /// MIST sensitivity.
    pub s_r: f64,
    pub decision: Decision,
    /// End-to-end latency (virtual ms for Sim, wall ms for Real).
    pub latency_ms: f64,
    pub cost: f64,
    /// Final (desanitized) response text; sim backend synthesizes one.
    pub response: String,
    /// Whether history sanitization was applied this turn.
    pub sanitized: bool,
}

/// The orchestrator.
pub struct Orchestrator {
    pub waves: Waves,
    pub mist: Mist,
    backend: Backend,
    hysteresis: Hysteresis,
    pub sessions: SessionStore,
    pub ledger: CostLedger,
    pub metrics: Metrics,
    /// §XIV compliance audit trail of every decision (incl. rejections).
    pub audit: AuditLog,
    limiter: RateLimiter,
    next_request_id: u64,
    budget_ceiling: f64,
}

impl Orchestrator {
    pub fn new(config: Config, mist: Mist, backend: Backend, seed: u64) -> Orchestrator {
        let hysteresis = Hysteresis::new(config.hysteresis_low, config.hysteresis_high);
        let limiter = RateLimiter::new(config.rate_limit_rps, config.rate_limit_rps.max(1.0));
        let budget_ceiling = config.budget_ceiling;
        Orchestrator {
            waves: Waves::new(config),
            mist,
            backend,
            hysteresis,
            sessions: SessionStore::new(seed),
            ledger: CostLedger::new(),
            metrics: Metrics::new(),
            audit: AuditLog::new(),
            limiter,
            next_request_id: 1,
            budget_ceiling,
        }
    }

    /// Open a session for a user.
    pub fn open_session(&mut self, user: &str) -> u64 {
        self.sessions.open(user)
    }

    fn now_ms(&self) -> f64 {
        match &self.backend {
            Backend::Sim(fleet) => fleet.now(),
            Backend::Real { .. } => 0.0, // real path rate-limits on wall time upstream
        }
    }

    /// Advance virtual time (sim backend).
    pub fn advance(&mut self, dt_ms: f64) {
        if let Backend::Sim(fleet) = &mut self.backend {
            fleet.advance(dt_ms);
        }
    }

    pub fn fleet(&self) -> Option<&Fleet> {
        match &self.backend {
            Backend::Sim(f) => Some(f),
            _ => None,
        }
    }

    pub fn fleet_mut(&mut self) -> Option<&mut Fleet> {
        match &mut self.backend {
            Backend::Sim(f) => Some(f),
            _ => None,
        }
    }

    /// Submit one prompt within a session (Fig. 2 pipeline). Returns Err
    /// for rate-limited submissions, Ok(Outcome) otherwise — including
    /// fail-closed rejections, which are Outcomes with a Reject decision.
    pub fn submit(
        &mut self,
        session_id: u64,
        prompt: &str,
        priority: PriorityTier,
        dataset: Option<&str>,
    ) -> anyhow::Result<Outcome> {
        let user = self
            .sessions
            .get(session_id)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session_id}"))?
            .user
            .clone();

        // Attack-4 mitigation: rate limit before any work
        let now = self.now_ms();
        if !self.limiter.admit(&user, now) {
            self.metrics.count("rate_limited", 1);
            anyhow::bail!("rate limited: user {user}");
        }

        let id = self.next_request_id;
        self.next_request_id += 1;

        let (history, prev_privacy) = {
            let s = self.sessions.get(session_id).unwrap();
            (s.history.clone(), s.prev_island_privacy)
        };
        let mut request = Request::new(id, prompt).with_user(&user).with_priority(priority).with_history(history);
        request.prev_island_privacy = prev_privacy;
        if let Some(ds) = dataset {
            request = request.with_dataset(ds);
        }

        // MIST sensitivity (Alg. 1 line 1)
        let report = self.mist.analyze(&request);
        let s_r = report.score;
        request.sensitivity = Some(s_r);
        self.metrics.observe("mist_s_r", s_r);

        // TIDE capacity (Alg. 1 line 2) + hysteresis preference
        let (states, local_capacity) = match &self.backend {
            Backend::Sim(fleet) => (fleet.states(), fleet.local_capacity()),
            Backend::Real { islands, .. } => (
                islands
                    .iter()
                    .map(|i| crate::agents::waves::IslandState { island: i.clone(), capacity: 1.0 })
                    .collect(),
                1.0,
            ),
        };
        let pref = self.hysteresis.observe(local_capacity);
        let _ = pref; // recorded below
        self.metrics.gauge("local_capacity", local_capacity);

        // WAVES decision (Alg. 1)
        let budget_left = self.ledger.remaining(&user, self.budget_ceiling);
        let decision = self.waves.route(&request, s_r, &states, local_capacity, self.hysteresis.state(), budget_left);

        let routed = match decision.routed() {
            None => {
                self.metrics.count("rejected_fail_closed", 1);
                let reason = match &decision {
                    Decision::Reject { reason } => Some(reason.clone()),
                    _ => None,
                };
                self.audit.record(AuditEntry {
                    request_id: id,
                    user: user.clone(),
                    t_ms: now,
                    s_r,
                    island: None,
                    island_privacy: None,
                    sanitized: false,
                    reject_reason: reason,
                });
                return Ok(Outcome {
                    request_id: id,
                    s_r,
                    decision,
                    latency_ms: 0.0,
                    cost: 0.0,
                    response: String::new(),
                    sanitized: false,
                });
            }
            Some(r) => r.clone(),
        };

        // Sanitize on trust-boundary crossing (Alg. 1 lines 14-17)
        let mut sanitized = false;
        if routed.sanitize {
            let session = self.sessions.get_mut(session_id).unwrap();
            request.history = sanitize_history(&request.history, routed.target_privacy, &mut session.placeholders);
            // the outgoing prompt is sanitized at the same level
            request.prompt = session.placeholders.sanitize(&request.prompt, routed.target_privacy);
            sanitized = true;
            self.metrics.count("sanitized_turns", 1);
        }

        // Execute
        let (latency_ms, cost, raw_response) = match &mut self.backend {
            Backend::Sim(fleet) => {
                let rep = fleet
                    .execute(routed.target, &request)
                    .ok_or_else(|| anyhow::anyhow!("island {} missing", routed.target))?;
                (rep.latency_ms, rep.cost, format!("[sim:{}] ack {} tokens", routed.target, request.max_new_tokens))
            }
            Backend::Real { executor, islands } => {
                let island = islands
                    .iter()
                    .find(|i| i.id == routed.target)
                    .ok_or_else(|| anyhow::anyhow!("island {} missing", routed.target))?;
                let resp = executor.execute(island, &request)?;
                (resp.compute_ms + resp.network_ms, resp.cost, resp.text)
            }
        };

        // Desanitize the response before the user sees it (backward pass)
        let response = if sanitized {
            self.sessions.get(session_id).unwrap().placeholders.desanitize(&raw_response)
        } else {
            raw_response
        };

        self.audit.record(AuditEntry {
            request_id: id,
            user: user.clone(),
            t_ms: now,
            s_r,
            island: Some(routed.target),
            island_privacy: Some(routed.target_privacy),
            sanitized,
            reject_reason: None,
        });
        self.ledger.charge(&user, cost);
        self.metrics.count("requests_served", 1);
        self.metrics.observe("latency_ms", latency_ms);
        self.metrics.observe("cost_usd", cost.max(1e-9));

        // record the turn against the island it actually ran on
        self.sessions.get_mut(session_id).unwrap().record_turn(prompt, &response, routed.target_privacy);

        Ok(Outcome { request_id: id, s_r, decision, latency_ms, cost, response, sanitized })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;

    fn sim_orchestrator() -> Orchestrator {
        let fleet = Fleet::new(preset_personal_group(), 11);
        Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 42)
    }

    #[test]
    fn sensitive_prompt_stays_personal() {
        let mut o = sim_orchestrator();
        let s = o.open_session("alice");
        let out = o.submit(s, "patient john doe ssn 123-45-6789 diagnosed with diabetes", PriorityTier::Primary, None).unwrap();
        assert!(out.s_r >= 0.9);
        let target = out.decision.target().unwrap();
        let islands = preset_personal_group();
        assert_eq!(islands.iter().find(|i| i.id == target).unwrap().privacy, 1.0);
        assert_eq!(out.cost, 0.0);
        assert!(!out.sanitized, "intra-personal must bypass MIST sanitization");
    }

    #[test]
    fn boundary_crossing_sanitizes_and_desanitizes() {
        let mut o = sim_orchestrator();
        let s = o.open_session("alice");
        // turn 1: sensitive, runs locally
        o.submit(s, "patient john doe has diabetes", PriorityTier::Primary, None).unwrap();
        // saturate local islands so the next burstable turn offloads
        {
            let fleet = o.fleet_mut().unwrap();
            for island in fleet.islands.iter_mut() {
                if !island.spec.unbounded() {
                    island.external_load = 0.99;
                }
            }
        }
        let out = o.submit(s, "what are common complications", PriorityTier::Burstable, None).unwrap();
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == out.decision.target().unwrap()).unwrap();
        assert!(target.privacy < 1.0, "should offload, got {}", target.name);
        assert!(out.sanitized, "crossing 1.0 -> {} must sanitize history", target.privacy);
        // stored history must keep the ORIGINAL user text (desanitized view)
        let hist = &o.sessions.get(s).unwrap().history;
        assert!(hist.iter().any(|t| t.text.contains("complications")));
    }

    #[test]
    fn rejection_is_fail_closed_not_error() {
        let mut o = sim_orchestrator();
        // remove all personal islands: sensitive requests unroutable
        {
            let fleet = o.fleet_mut().unwrap();
            fleet.islands.retain(|i| i.spec.privacy < 0.9);
        }
        let s = o.open_session("bob");
        let out = o.submit(s, "patient john doe ssn 123-45-6789", PriorityTier::Primary, None).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.metrics.counter_value("rejected_fail_closed"), 1);
    }

    #[test]
    fn rate_limit_blocks_floods() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 2.0;
        let fleet = Fleet::new(preset_personal_group(), 1);
        let mut o = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 1);
        let s = o.open_session("mallory");
        let mut blocked = 0;
        for _ in 0..10 {
            if o.submit(s, "hello", PriorityTier::Burstable, None).is_err() {
                blocked += 1;
            }
        }
        assert!(blocked >= 7, "blocked={blocked}");
        assert!(o.metrics.counter_value("rate_limited") >= 7);
    }

    #[test]
    fn ledger_tracks_cloud_spend() {
        let mut o = sim_orchestrator();
        let s = o.open_session("carol");
        // saturate local → burstable goes to cloud and pays
        {
            let fleet = o.fleet_mut().unwrap();
            for island in fleet.islands.iter_mut() {
                if !island.spec.unbounded() {
                    island.external_load = 0.99;
                }
            }
        }
        let out = o.submit(s, "what is the capital of france", PriorityTier::Burstable, None).unwrap();
        assert!(out.cost > 0.0);
        assert!(o.ledger.spent("carol") > 0.0);
    }

    #[test]
    fn audit_log_records_every_decision() {
        let mut o = sim_orchestrator();
        let s = o.open_session("auditor");
        o.submit(s, "hello world", PriorityTier::Secondary, None).unwrap();
        o.submit(s, "patient john doe ssn 123-45-6789", PriorityTier::Primary, None).unwrap();
        assert_eq!(o.audit.len(), 2);
        // compliance scan over the trail: no entry with s_r>=0.9 ran below P=0.9
        assert!(o.audit.violations(0.9, 0.9).is_empty());
        // rejections are audited too
        o.fleet_mut().unwrap().islands.retain(|i| i.spec.privacy < 0.9);
        let out = o.submit(s, "patient jane smith mrn 12345", PriorityTier::Primary, None).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.audit.len(), 3);
        assert!(o.audit.entries().last().unwrap().reject_reason.is_some());
    }

    #[test]
    fn metrics_populated() {
        let mut o = sim_orchestrator();
        let s = o.open_session("dave");
        o.submit(s, "hello world", PriorityTier::Secondary, None).unwrap();
        assert_eq!(o.metrics.counter_value("requests_served"), 1);
        assert!(o.metrics.histogram("latency_ms").unwrap().count() == 1);
    }
}
