//! The IslandRun orchestrator: the Fig. 2 route-then-sanitize pipeline as a
//! thread-safe façade over the agents, the session store and an execution
//! backend.
//!
//!   client → [rate limit] → MIST s_r → TIDE R(t) → WAVES Alg. 1 →
//!   [sanitize h_r on trust-boundary crossing] → island execute →
//!   [desanitize response] → client
//!
//! Request lifecycle (the serving surface):
//!
//!   enqueue → admit → [queue] → route → batch → execute → resolve
//!
//! The primary entry point is the non-blocking path:
//! [`Orchestrator::enqueue`] takes a typed [`SubmitRequest`] (every
//! routing-relevant knob — priority, deadline, sensitivity floor,
//! jurisdiction floor, model pin, dataset), admits it (rate limit), and
//! parks it in a bounded priority+deadline-ordered admission queue,
//! returning a [`Ticket`] immediately. A configurable worker pool
//! ([`Orchestrator::start_queue`], `Config::serve_workers`) drains the
//! queue in batches so co-routed requests coalesce *across sessions and
//! submitters*; each ticket resolves exactly once (`Ticket::wait` /
//! `Ticket::try_poll`). A full queue sheds the incoming request fail-closed
//! (`rejected_queue_full`), and requests whose deadline expired while
//! queued are shed at drain time (`shed_deadline_expired`) — both audited.
//!
//! The blocking [`Orchestrator::submit_request`] /
//! [`Orchestrator::submit_many_requests`] calls delegate to the same
//! pipeline; all entry points take `&self`, so any number of threads can
//! drive the orchestrator through `Arc<Orchestrator>`. Request ids come
//! from an atomic counter; sessions live in an `RwLock`-sharded store;
//! metrics, the cost ledger and the audit log are internally synchronized;
//! the hysteresis state machine and the per-user rate limiter sit behind
//! short mutexes.
//!
//! Telemetry: every per-request metric bump goes through the pre-registered
//! typed handles in [`ServingMetrics`] — atomic adds on cached cells, zero
//! name lookups on the hot path — and every resolved request id leaves one
//! typed [`Resolution`] in three places that can never disagree: the
//! [`Outcome`], the audit entry, and the `requests_resolved{outcome,reason}`
//! counter. Each resolution also appends one structured [`RequestEvent`]
//! (lifecycle timestamps, island, tier, failovers, sanitization counts) to
//! the bounded [`Orchestrator::analytics`] ring.
//!
//! Batching: both the queue drain and `submit_many_requests` route first, then group
//! co-routed requests per island by the live [`BatchPolicy`] — because the
//! queue drain batches whatever is parked, coalescing happens across
//! sessions (the fleet-scale batching story, not per-call-scale). What a
//! group *is* depends on [`BatchMode`]:
//!
//! - **Continuous** (default, Sim backend): requests join a per-island step
//!   loop that interleaves [`Fleet::decode_step`] calls across the
//!   in-flight batch at decode-step granularity, admitting newly routed
//!   requests between steps instead of waiting for the batch to finish.
//!   Tokens stream to the ticket as steps complete, and both caller cancels
//!   ([`Ticket::cancel`]) and deadlines expiring mid-generation stop the
//!   decode at the next step boundary — freeing the slot immediately, with
//!   the ledger charged only for tokens actually decoded.
//! - **Coalesce** (Real backend; opt-in on Sim): run-to-completion chunks —
//!   on the Real backend each chunk becomes one `execute_batch` call,
//!   filling the compiled PJRT batch variants instead of dispatching row by
//!   row (Fig. 2's island-execute stage is where the batcher sits).
//!
//! Backends:
//! - [`Backend::Sim`] — virtual-time [`Fleet`] (evals, examples, attacks),
//! - [`Backend::Real`] — PJRT TinyLM through [`IslandExecutor`]
//!   (quickstart / serving bench; python stays off this path).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::agents::lighthouse::Lighthouse;
use crate::agents::mist::Mist;
use crate::agents::tide::hysteresis::Hysteresis;
use crate::agents::tide::monitor::DegradeDetector;
use crate::agents::waves::{Decision, IslandState, Routed, Waves};
use crate::config::json::Json;
use crate::config::Config;
use crate::islands::executor::{self, IslandExecutor};
use crate::islands::{CostLedger, DecodeHandle, Fleet};
use crate::runtime::{chunk_by_policy, BatchMode, BatchPolicy, StepLanes};
use crate::server::audit::{AuditEntry, AuditLog};
use crate::server::queue::{AdmissionQueue, QueueItem, SubmitRequest};
use crate::server::ratelimit::RateLimiter;
use crate::server::resolution::{CancelPoint, FailReason, Resolution, ShedReason};
use crate::server::session::SessionStore;
use crate::server::ticket::{Ticket, TicketCell};
use crate::telemetry::serving::IslandCells;
use crate::telemetry::{EventLog, Metrics, RequestEvent, ServingMetrics, TraceConfig, TraceContext, TraceSink};
use crate::types::{Island, IslandId, Request};
use crate::util::AtomicF64;

use crate::util::sync::{LockExt, RwLockExt};

/// Execution backend.
pub enum Backend {
    Sim(Fleet),
    Real { executor: IslandExecutor, islands: Vec<Island> },
}

/// Result of one submitted request.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub request_id: u64,
    /// MIST sensitivity.
    pub s_r: f64,
    pub decision: Decision,
    /// End-to-end latency (virtual ms for Sim, wall ms for Real).
    pub latency_ms: f64,
    pub cost: f64,
    /// Final (desanitized) response text; sim backend synthesizes one.
    pub response: String,
    /// Whether history sanitization was applied this turn.
    pub sanitized: bool,
    /// Tokens actually decoded for this request. Equals the full token
    /// budget for served requests; smaller for cancelled ones (the ledger
    /// charges exactly these); 0 for rejects and sheds.
    pub tokens_generated: usize,
    /// How the request terminated: served, shed, cancelled, or failed —
    /// the same typed [`Resolution`] the audit entry and the
    /// `requests_resolved{outcome,reason}` counter carry. For cancelled
    /// requests, `cost`/`tokens_generated` reflect any partial decode that
    /// was charged.
    pub resolution: Resolution,
}

impl Outcome {
    /// The request was cancelled — by the caller ([`Ticket::cancel`]) or by
    /// its deadline expiring mid-decode — after consuming a request id.
    /// Accessor shim over [`Outcome::resolution`] for callers of the old
    /// `cancelled: bool` field.
    pub fn cancelled(&self) -> bool {
        self.resolution.is_cancelled()
    }
}

/// Point-in-time public view of one island: the narrow read surface that
/// replaced leaking the whole `Fleet` out of the orchestrator (fleet
/// internals can now evolve without breaking callers).
#[derive(Clone, Debug)]
pub struct IslandSnapshot {
    /// Static registration record.
    pub spec: Island,
    /// Power/reachability state (ground truth on the Sim backend; the
    /// LIGHTHOUSE liveness view on Real).
    pub online: bool,
    /// Available capacity R_j(t) in [0,1] at snapshot time. Sim backend
    /// only: real islands do not report capacity through this accessor
    /// (TIDE owns that signal), so the Real backend returns a constant 1.0.
    pub capacity: f64,
    /// Total requests this island has executed. Sim backend telemetry
    /// only; always 0 on the Real backend.
    pub executed: u64,
    /// Remaining battery fraction for battery-powered islands (the declared
    /// registration value on the Real backend).
    pub battery: Option<f64>,
}

/// A request that cleared admission + routing and awaits execution.
struct Prepared {
    id: u64,
    session_id: u64,
    user: String,
    request: Request,
    s_r: f64,
    decision: Decision,
    routed: Routed,
    sanitized: bool,
    /// Privacy level the history/prompt were last sanitized for (`None` =
    /// never sanitized). A failover hop to a *lower*-privacy island must
    /// re-sanitize at the new level — over-sanitization is safe, under- is
    /// a Def. 4 violation.
    sanitized_at: Option<f64>,
    now: f64,
    /// Island-down execution failures observed so far (each one is a
    /// failover hop attempt; lands in the audit entry and must equal the
    /// per-request contribution to the `failovers` metric).
    failovers: u32,
    /// Trust-tier label of the currently routed island (re-resolved on
    /// failover re-routes, like `cells`).
    tier: &'static str,
    /// Cached per-island metric cells for the routed target, so resolution
    /// bumps the labeled `island_latency_ms`/`served_by_island` series
    /// without any map lookup.
    cells: Arc<IslandCells>,
    /// Conversation turns rewritten by sanitization for this request,
    /// summed across failover re-sanitizations (analytics event field).
    sanitized_turns: u64,
    /// When the request entered the admission queue (`NaN` on the blocking
    /// path, which never queues).
    enqueued_ms: f64,
    /// When routing completed (== `now`).
    routed_ms: f64,
    /// When prefill started on the serving island (`NaN` until execution).
    prefill_ms: f64,
    /// When the first decoded tokens reached the ticket (`NaN` on
    /// non-streaming paths).
    first_token_ms: f64,
    /// Request-scoped trace handle (threaded by value from the submit
    /// surface — never a thread-local). Child spans for every pipeline
    /// stage land here; exactly one terminal site closes the root span.
    trace: TraceContext,
}

/// Terminal state of the failure-aware execution loop.
enum ExecEnd {
    /// `(latency_ms, cost, raw_response, tokens_generated)` from the island
    /// that served it.
    Done(f64, f64, String, usize),
    /// Every attempt hit a dead island and the retry budget ran out (or no
    /// online island remained). Audited as an exhausted-retries reject.
    Exhausted { reason: String },
    /// A non-island-down execution error: re-routing cannot fix it.
    Fatal(anyhow::Error),
    /// Fatal, but the failure was already audited at its source (e.g. the
    /// session raced a close() during a failover re-sanitization) — the
    /// caller must NOT add a second entry for this request id.
    FatalAudited(anyhow::Error),
}

/// Why a single execution attempt failed.
enum AttemptErr {
    /// The routed island is down / gone / unreachable — re-routable.
    IslandDown(String),
    /// Anything else — not re-routable.
    Fatal(anyhow::Error),
}

/// A routed request parked in an island's step-loop lane, waiting to join
/// the in-flight continuous batch (see [`StepLanes`]).
struct StepJob {
    key: QueuedKey,
    prepared: Prepared,
}

/// One in-flight request of an island's continuous batch: its queue
/// bookkeeping plus the live decode cursor.
struct Active {
    job: StepJob,
    handle: DecodeHandle,
    /// Island-clock time when prefill completed and decode began (start of
    /// the request's coalesced `decode` trace span).
    decode_start_ms: f64,
    /// Decode steps that actually produced tokens — exported as the
    /// `chunks` attribute on the coalesced `decode` span.
    decode_chunks: u32,
}

/// Outcome of one decode-step attempt on an in-flight request.
enum StepVerdict {
    /// Decoded a chunk; more tokens remain.
    Running,
    /// The token budget is fully decoded — finish and resolve.
    Done,
    /// The caller cancelled the ticket; stop at this step boundary.
    CancelRequested,
    /// The absolute deadline passed mid-decode; stop at this step boundary.
    DeadlineExpired,
    /// The island died mid-decode — hand the request to the failover path.
    IslandGone,
}

/// The orchestrator.
pub struct Orchestrator {
    pub waves: Waves,
    pub mist: Mist,
    backend: Backend,
    /// LIGHTHOUSE embedded on the serving path: every submit routes only
    /// over islands this liveness view reports online and attested.
    pub lighthouse: Lighthouse,
    hysteresis: Mutex<Hysteresis>,
    pub sessions: SessionStore,
    pub ledger: CostLedger,
    pub metrics: Metrics,
    /// Pre-registered typed handles into `metrics` for every serving-path
    /// series: the hot path bumps these cached atomic cells directly
    /// instead of resolving names per request.
    serving: ServingMetrics,
    /// Per-request analytics: one structured [`RequestEvent`] per resolved
    /// request id, in a bounded ring with JSONL export
    /// ([`EventLog::to_jsonl`]).
    pub analytics: EventLog,
    /// §XIV compliance audit trail of every decision (incl. rejections).
    /// Behind an `Arc` so queue workers can still audit sheds for batches
    /// they popped even if the orchestrator is dropped mid-drain (no id may
    /// vanish from the trail, even at shutdown).
    pub audit: Arc<AuditLog>,
    /// Completed request traces: bounded ring behind the tail-sampling
    /// policy ([`TraceSink`]), read by the trace exporters and the HTTP
    /// `GET /v1/traces/:id` surface.
    pub traces: Arc<TraceSink>,
    limiter: Mutex<RateLimiter>,
    next_request_id: AtomicU64,
    budget_ceiling: f64,
    /// Island-execute batching policy; interior-mutable so `Arc` holders
    /// can retune batching live ([`Orchestrator::set_batch_policy`]).
    batch_policy: RwLock<BatchPolicy>,
    /// Bounded admission queue behind [`Orchestrator::enqueue`]; shared
    /// with the worker pool, which holds the `Arc` (plus a `Weak` to the
    /// orchestrator so workers never keep it alive).
    queue: Arc<AdmissionQueue>,
    /// Worker threads [`Orchestrator::start_queue`] spawns to drain it.
    serve_workers: usize,
    workers_started: AtomicBool,
    /// Failover re-routes allowed per request before exhausted-retries.
    retry_budget: u32,
    /// Per-island continuous-batching lanes: the hand-off between queue
    /// drains (which route) and the single per-island driver (which
    /// interleaves decode steps). Only used in [`BatchMode::Continuous`] on
    /// the Sim backend.
    step_lanes: StepLanes<IslandId, StepJob>,
    /// TIDE degrade detectors, one per island, sampled at heartbeat cadence.
    degrade: Mutex<BTreeMap<IslandId, DegradeDetector>>,
    degrade_zero_samples: u32,
    /// Virtual time of the last heartbeat relay / liveness tick.
    last_liveness_sync: AtomicF64,
    heartbeat_period_ms: f64,
    /// Wall-clock epoch for the Real backend's rate limiting.
    started: std::time::Instant,
}

impl Orchestrator {
    pub fn new(config: Config, mist: Mist, backend: Backend, seed: u64) -> Orchestrator {
        let hysteresis = Hysteresis::new(config.hysteresis_low, config.hysteresis_high);
        let limiter = RateLimiter::new(config.rate_limit_rps, config.rate_limit_rps.max(1.0));
        let budget_ceiling = config.budget_ceiling;
        let retry_budget = config.failover_retry_budget;
        let degrade_zero_samples = config.degrade_zero_samples;
        let heartbeat_period_ms = config.heartbeat_period_ms as f64;
        let queue = Arc::new(AdmissionQueue::new(config.queue_capacity));
        let serve_workers = config.serve_workers.max(1);
        let traces = TraceSink::new(
            TraceConfig {
                enabled: config.trace_enabled,
                head_rate: config.trace_head_rate,
                ring_capacity: config.trace_ring_capacity,
            },
            seed ^ 0x5452_4143_45u64,
        );
        let lighthouse = Lighthouse::new(seed ^ 0x11A5_7110_5E0u64, heartbeat_period_ms, config.heartbeat_miss_limit);
        // register the initial fleet: every backend island is attested and
        // announced online at t=0 (churn helpers keep the view in sync)
        let initial: Vec<Island> = match &backend {
            Backend::Sim(fleet) => fleet.specs(),
            Backend::Real { islands, .. } => islands.clone(),
        };
        for island in initial {
            let _ = lighthouse.register_owned(island, 0.0);
        }
        let metrics = Metrics::new();
        let serving = ServingMetrics::register(&metrics);
        Orchestrator {
            waves: Waves::new(config),
            mist,
            backend,
            lighthouse,
            hysteresis: Mutex::new(hysteresis),
            sessions: SessionStore::new(seed),
            ledger: CostLedger::new(),
            metrics,
            serving,
            analytics: EventLog::default(),
            audit: Arc::new(AuditLog::new()),
            traces,
            limiter: Mutex::new(limiter),
            next_request_id: AtomicU64::new(1),
            budget_ceiling,
            batch_policy: RwLock::new(BatchPolicy::default()),
            queue,
            serve_workers,
            workers_started: AtomicBool::new(false),
            retry_budget,
            step_lanes: StepLanes::new(),
            degrade: Mutex::new(BTreeMap::new()),
            degrade_zero_samples,
            last_liveness_sync: AtomicF64::new(f64::NEG_INFINITY),
            heartbeat_period_ms,
            started: std::time::Instant::now(),
        }
    }

    /// Retune the island-execute batching policy live (interior-mutable, so
    /// `Arc<Orchestrator>` holders can adjust `max_batch`/`max_wait` while
    /// submitters and queue workers are running; the next coalescing pass
    /// picks it up).
    pub fn set_batch_policy(&self, policy: BatchPolicy) {
        *self.batch_policy.write_clean() = policy;
    }

    /// The batching policy currently applied by the coalescing paths.
    pub fn batch_policy(&self) -> BatchPolicy {
        *self.batch_policy.read_clean()
    }

    /// Open a session for a user.
    pub fn open_session(&self, user: &str) -> u64 {
        self.sessions.open(user)
    }

    /// Serving-clock milliseconds: virtual time on the Sim backend, wall
    /// time since startup on Real. Public so transport-side span recording
    /// (the HTTP SSE relay) shares the pipeline's clock.
    pub fn now_ms(&self) -> f64 {
        match &self.backend {
            Backend::Sim(fleet) => fleet.now(),
            // wall-clock ms since startup, so the per-user token bucket
            // actually refills on the real serving path
            Backend::Real { .. } => self.started.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Advance virtual time (sim backend).
    pub fn advance(&self, dt_ms: f64) {
        if let Backend::Sim(fleet) = &self.backend {
            fleet.advance(dt_ms);
        }
    }

    /// The simulated fleet, when this orchestrator is Sim-backed. Private:
    /// callers observe islands through the narrow accessors below
    /// ([`island_ids`](Orchestrator::island_ids),
    /// [`island_snapshot`](Orchestrator::island_snapshot)) so fleet
    /// internals can evolve without public API breaks.
    fn sim_fleet(&self) -> Option<&Fleet> {
        match &self.backend {
            Backend::Sim(f) => Some(f),
            _ => None,
        }
    }

    /// Is this orchestrator backed by the virtual-time simulator? (Churn
    /// scaffolding — crash/load knobs — only exists there.)
    pub fn sim_backed(&self) -> bool {
        matches!(self.backend, Backend::Sim(_))
    }

    /// Ids of every island currently in the mesh (either backend).
    pub fn island_ids(&self) -> Vec<IslandId> {
        match &self.backend {
            Backend::Sim(f) => f.specs().iter().map(|i| i.id).collect(),
            Backend::Real { islands, .. } => islands.iter().map(|i| i.id).collect(),
        }
    }

    /// Point-in-time view of one island; `None` when no island with this id
    /// is in the mesh (it left, or never joined).
    pub fn island_snapshot(&self, id: IslandId) -> Option<IslandSnapshot> {
        match &self.backend {
            Backend::Sim(f) => f.get(id).map(|island| IslandSnapshot {
                spec: island.spec.clone(),
                online: island.is_online(),
                capacity: island.capacity(f.now()),
                executed: island.executed(),
                battery: island.battery(),
            }),
            Backend::Real { islands, .. } => islands.iter().find(|i| i.id == id).map(|i| IslandSnapshot {
                spec: i.clone(),
                online: self.lighthouse.is_online(id),
                capacity: 1.0,
                executed: 0,
                battery: i.battery,
            }),
        }
    }

    /// Liveness-only view of one island — the cheap membership/online probe
    /// for hot loops (the churn driver polls this every step; the full
    /// [`island_snapshot`](Orchestrator::island_snapshot) clones the spec).
    /// `None` when no island with this id is in the mesh.
    pub fn island_online(&self, id: IslandId) -> Option<bool> {
        match &self.backend {
            Backend::Sim(f) => f.get(id).map(|island| island.is_online()),
            Backend::Real { islands, .. } => islands.iter().find(|i| i.id == id).map(|_| self.lighthouse.is_online(id)),
        }
    }

    /// Set an island's external utilization knob in [0,1) (Sim backend load
    /// programs / test scaffolding). Returns false off-sim or for unknown ids.
    pub fn set_island_load(&self, id: IslandId, load: f64) -> bool {
        match self.sim_fleet().and_then(|f| f.get(id)) {
            Some(island) => {
                island.set_external_load(load);
                true
            }
            None => false,
        }
    }

    /// Drop every island whose spec fails the predicate (Sim backend test
    /// scaffolding; mirrors what a mass deprovisioning would do).
    pub fn retain_islands(&self, pred: impl Fn(&Island) -> bool) {
        if let Some(fleet) = self.sim_fleet() {
            fleet.retain(pred);
        }
    }

    /// Saturate every bounded island with an external load (Sim backend
    /// load scaffolding: pushes offloadable tiers toward the unbounded
    /// cloud; tests and examples use it to force trust-boundary crossings).
    pub fn saturate_bounded_islands(&self, load: f64) {
        if let Some(fleet) = self.sim_fleet() {
            for island in fleet.islands() {
                if !island.spec.unbounded() {
                    island.set_external_load(load);
                }
            }
        }
    }

    // -- dynamic fleet membership (churn drivers: tests, load generator) ---

    /// Announced crash: the island powers off AND the liveness view learns
    /// immediately (clean shutdown). For a *silent* crash — detected only by
    /// missed heartbeats or a failed execution — use
    /// [`silent_crash_island`](Orchestrator::silent_crash_island). Sim
    /// backend only.
    pub fn crash_island(&self, id: IslandId) -> bool {
        match self.sim_fleet() {
            Some(fleet) if fleet.crash(id) => {
                self.lighthouse.mark_offline(id);
                self.serving.island_crashes.inc();
                true
            }
            _ => false,
        }
    }

    /// Silent crash: the island powers off but the liveness view is NOT
    /// told — the death must be *discovered* (heartbeat timeout, or a failed
    /// execution that triggers the failover path). Sim backend only; churn
    /// drivers and the failover bench use this to exercise detection.
    pub fn silent_crash_island(&self, id: IslandId) -> bool {
        self.sim_fleet().map(|fleet| fleet.crash(id)).unwrap_or(false)
    }

    /// Power a crashed island back on and announce it (wake from sleep).
    pub fn revive_island(&self, id: IslandId) -> bool {
        match self.sim_fleet() {
            Some(fleet) if fleet.revive(id) => {
                self.lighthouse.beat(id, fleet.now());
                self.lighthouse.set_degraded(id, false);
                self.degrade.lock_clean().remove(&id);
                self.serving.island_revives.inc();
                true
            }
            _ => false,
        }
    }

    /// A new island joins the mesh mid-run: added to the fleet and
    /// registered + attested with LIGHTHOUSE (dynamic discovery).
    pub fn join_island(&self, island: Island) -> bool {
        match self.sim_fleet() {
            Some(fleet) if fleet.join(island.clone()) => {
                // re-joins after a leave are fresh registrations
                let _ = self.lighthouse.deregister(island.id);
                let _ = self.lighthouse.register_owned(island, fleet.now());
                self.serving.island_joins.inc();
                true
            }
            _ => false,
        }
    }

    /// An island leaves the mesh entirely (deprovisioned).
    pub fn leave_island(&self, id: IslandId) -> Option<Island> {
        let fleet = self.sim_fleet()?;
        let island = fleet.leave(id)?;
        let _ = self.lighthouse.deregister(id);
        self.degrade.lock_clean().remove(&id);
        self.serving.island_leaves.inc();
        Some(island)
    }

    /// Heartbeat-cadence gate: true for exactly one caller per elapsed
    /// period (CAS on the last-sync timestamp), so concurrent submitters
    /// cannot double-relay beats or feed the degrade detectors extra
    /// samples within one period.
    fn liveness_due(&self, now: f64) -> bool {
        let last = self.last_liveness_sync.load();
        if last != f64::NEG_INFINITY && now - last < self.heartbeat_period_ms {
            return false;
        }
        self.last_liveness_sync.compare_exchange(last, now)
    }

    /// Relay fleet liveness into LIGHTHOUSE at heartbeat cadence: online
    /// islands beat, the tracker ticks (silently crashed islands time out
    /// after the miss limit), and TIDE's degrade detectors fold each
    /// island's Eq. 3 capacity into the same view.
    fn sync_liveness(&self, now: f64, states: &[IslandState]) {
        if !self.liveness_due(now) {
            return;
        }
        self.lighthouse.beat_many(states.iter().filter(|s| s.online).map(|s| s.island.id), now);
        self.lighthouse.tick(now);
        let mut detectors = self.degrade.lock_clean();
        for s in states {
            let det = detectors.entry(s.island.id).or_insert_with(|| DegradeDetector::new(self.degrade_zero_samples));
            let was = det.is_degraded();
            let is = det.observe(s.capacity);
            if is != was {
                self.lighthouse.set_degraded(s.island.id, is);
                if is {
                    self.serving.islands_degraded.inc();
                } else {
                    self.serving.islands_recovered.inc();
                }
            }
        }
    }

    /// The routing-time view of the fleet: per-island capacity from the
    /// backend, liveness + degrade signals from LIGHTHOUSE. `submit` and
    /// the failover path both route over this — a request is never routed
    /// to an island the liveness view knows is offline, and degraded
    /// islands are deprioritized.
    fn routing_view(&self) -> (Vec<IslandState>, f64) {
        match &self.backend {
            Backend::Sim(fleet) => {
                let now = fleet.now();
                let mut states = fleet.states();
                self.sync_liveness(now, &states);
                for s in states.iter_mut() {
                    // the sim's power flag is ground truth the liveness view
                    // discovers over time: routing trusts LIGHTHOUSE, so a
                    // silent crash is invisible until detected (heartbeat
                    // timeout or a failed execution marks it offline).
                    s.online = self.lighthouse.is_online(s.island.id);
                    s.degraded = self.lighthouse.is_degraded(s.island.id);
                }
                (states, fleet.local_capacity())
            }
            Backend::Real { islands, .. } => {
                // real islands have no sim power flag: they re-announce at
                // heartbeat cadence, so an island marked offline by a failed
                // execution (link dead after retries) is retried after one
                // period — a circuit-breaker half-open, not a permanent ban.
                let now = self.now_ms();
                if self.liveness_due(now) {
                    self.lighthouse.beat_many(islands.iter().map(|i| i.id), now);
                    self.lighthouse.tick(now);
                }
                (
                    islands
                        .iter()
                        .map(|i| IslandState {
                            island: i.clone(),
                            capacity: 1.0,
                            online: self.lighthouse.is_online(i.id),
                            degraded: self.lighthouse.is_degraded(i.id),
                        })
                        .collect(),
                    1.0,
                )
            }
        }
    }

    /// Admission gate: session lookup + rate limit, before any per-request
    /// work. Deliberately separate from the history fetch in
    /// [`prepare_admitted`](Orchestrator::prepare_admitted): the history
    /// clone is attacker-sized, so a flooding user costs only this
    /// user-name read before the limiter turns them away (Attack 4). Runs
    /// at enqueue time on the queue path, so floods are refused at the
    /// front door, not after occupying queue slots.
    fn admit(&self, session_id: u64) -> anyhow::Result<String> {
        self.admit_typed(session_id).map_err(|e| match e {
            AdmitErr::UnknownSession(id) => anyhow::anyhow!("unknown session {id}"),
            AdmitErr::RateLimited { user } => anyhow::anyhow!("rate limited: user {user}"),
        })
    }

    /// Typed admission verdict for callers that must distinguish the two
    /// refusals: the queue path sheds rate-limited floods with a typed
    /// resolution (so the serving surface can answer 429 with evidence)
    /// while unknown sessions stay plain errors — no user to attribute an
    /// audit entry to.
    fn admit_typed(&self, session_id: u64) -> Result<String, AdmitErr> {
        let Some(user) = self.sessions.user_of(session_id) else {
            return Err(AdmitErr::UnknownSession(session_id));
        };
        let now = self.now_ms();
        if !self.limiter.lock_clean().admit(&user, now) {
            self.serving.rate_limited.inc();
            return Err(AdmitErr::RateLimited { user });
        }
        Ok(user)
    }

    /// Record one terminal resolution: exactly one
    /// `requests_resolved{outcome,reason}` bump and one analytics event per
    /// consumed request id, at the site that constructed the final
    /// outcome/audit entry.
    fn record_resolution(&self, res: Resolution, ev: RequestEvent) {
        self.serving.resolved.of(res).inc();
        self.analytics.push(ev);
    }

    /// Analytics event for a request that resolved without routing evidence
    /// (sheds, fail-closed rejects, queue-time cancels, shutdown).
    fn unrouted_event(
        &self,
        res: Resolution,
        id: u64,
        user: &str,
        s_r: f64,
        enqueued_ms: f64,
        failovers: u32,
        trace_id: Option<String>,
    ) -> RequestEvent {
        RequestEvent {
            request_id: id,
            user: user.to_string(),
            outcome: res.class(),
            reason: res.reason(),
            island: None,
            tier: None,
            privacy: None,
            s_r,
            failovers,
            sanitized: false,
            sanitized_turns: 0,
            enqueued_ms,
            routed_ms: f64::NAN,
            prefill_ms: f64::NAN,
            first_token_ms: f64::NAN,
            resolved_ms: self.now_ms(),
            tokens_generated: 0,
            latency_ms: f64::NAN,
            cost_usd: 0.0,
            trace_id,
        }
    }

    /// Analytics event for a request that was routed ([`Prepared`]):
    /// carries the island/tier/privacy labels and the lifecycle timestamps
    /// accumulated so far. `routed` gates the island evidence — exhausted
    /// failovers resolve with no island, like their audit entry.
    fn prepared_event(
        &self,
        p: &Prepared,
        res: Resolution,
        routed: bool,
        tokens: usize,
        latency_ms: f64,
        cost: f64,
        trace_id: Option<String>,
    ) -> RequestEvent {
        RequestEvent {
            request_id: p.id,
            user: p.user.clone(),
            outcome: res.class(),
            reason: res.reason(),
            island: if routed { Some(p.routed.target.to_string()) } else { None },
            tier: if routed { Some(p.tier) } else { None },
            privacy: if routed { Some(p.routed.target_privacy) } else { None },
            s_r: p.s_r,
            failovers: p.failovers,
            sanitized: p.sanitized,
            sanitized_turns: p.sanitized_turns,
            enqueued_ms: p.enqueued_ms,
            routed_ms: p.routed_ms,
            prefill_ms: p.prefill_ms,
            first_token_ms: p.first_token_ms,
            resolved_ms: self.now_ms(),
            tokens_generated: tokens as u32,
            latency_ms,
            cost_usd: cost,
            trace_id,
        }
    }

    /// Admission + MIST + TIDE + WAVES + sanitize for one submission:
    /// everything before island execution. `Err` = rate limited / unknown
    /// session; `Ok(Err(outcome))` = audited fail-closed rejection;
    /// `Ok(Ok(prepared))` = routed and ready to execute.
    fn prepare(&self, session_id: u64, sr: &SubmitRequest) -> anyhow::Result<Result<Prepared, Outcome>> {
        let user = self.admit(session_id)?;
        let id = self.next_request_id.fetch_add(1, Ordering::SeqCst);
        if let Err(why) = sr.validate() {
            return Ok(Err(self.reject_invalid(id, &user, &why, &sr.trace)));
        }
        // the blocking path never queues: no enqueue timestamp
        self.prepare_admitted(id, session_id, user, sr, f64::NAN)
    }

    /// Audited fail-closed rejection for a degenerate [`SubmitRequest`]
    /// (`SubmitRequest::validate`): the request consumed an id at admission,
    /// so it sheds like any other — one audit entry, zero cost — instead of
    /// entering the pipeline with a budget no island could ever satisfy.
    fn reject_invalid(&self, id: u64, user: &str, why: &str, trace: &TraceContext) -> Outcome {
        let res = Resolution::Shed(ShedReason::InvalidRequest);
        self.serving.rejected_invalid_request.inc();
        let reason = format!("shed: invalid request: {why}");
        let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry::unrouted(id, user, self.now_ms(), res, &reason).with_trace(trace_id.clone()));
        self.record_resolution(res, self.unrouted_event(res, id, user, 0.0, f64::NAN, 0, trace_id));
        Outcome {
            request_id: id,
            s_r: 0.0,
            decision: Decision::Reject { reason },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
            tokens_generated: 0,
            resolution: res,
        }
    }

    /// MIST + TIDE + WAVES + sanitize for a request that already cleared
    /// admission and consumed a request id (the queue drain enters here with
    /// the id allocated at enqueue time). From here on every exit —
    /// including sessions racing close() — must leave an audit entry
    /// (§XIV: no vanished ids).
    fn prepare_admitted(
        &self,
        id: u64,
        session_id: u64,
        user: String,
        sr: &SubmitRequest,
        enqueued_ms: f64,
    ) -> anyhow::Result<Result<Prepared, Outcome>> {
        let now = self.now_ms();
        let trace = sr.trace.clone();
        let Some((history, prev_privacy)) =
            self.sessions.with(session_id, |s| (s.history.clone(), s.prev_island_privacy))
        else {
            self.audit_vanished(id, &user, now, 0.0, "session closed before routing", 0, &trace);
            anyhow::bail!("unknown session {session_id}");
        };
        let mut request =
            Request::new(id, &sr.prompt).with_user(&user).with_priority(sr.priority).with_history(history);
        request.prev_island_privacy = prev_privacy;
        request.deadline_ms = sr.deadline_ms;
        request.max_new_tokens = sr.max_new_tokens;
        request.required_dataset = sr.dataset.clone();
        request.required_model = sr.model.clone();
        request.min_jurisdiction = sr.min_jurisdiction;

        // MIST sensitivity (Alg. 1 line 1). A caller-declared floor can
        // only *raise* s_r — tightening the privacy constraint is allowed
        // through the public surface, relaxing it below MIST's score is not.
        let report = self.mist.analyze(&request);
        let s_r = report.score.max(sr.sensitivity_floor.unwrap_or(0.0)).clamp(0.0, 1.0);
        request.sensitivity = Some(s_r);
        self.serving.mist_s_r.observe(s_r);

        // TIDE capacity (Alg. 1 line 2) + LIGHTHOUSE liveness + hysteresis
        let (states, local_capacity) = self.routing_view();
        let pref = self.hysteresis.lock_clean().observe(local_capacity);
        self.serving.local_capacity.set(local_capacity);

        // WAVES decision (Alg. 1)
        let budget_left = self.ledger.remaining(&user, self.budget_ceiling);
        let decision = self.waves.route(&request, s_r, &states, local_capacity, pref, budget_left);

        let routed = match decision.routed() {
            None => {
                let res = Resolution::Failed(FailReason::FailClosed);
                self.serving.rejected_fail_closed.inc();
                let reason = match &decision {
                    Decision::Reject { reason } => Some(reason.clone()),
                    _ => None,
                };
                let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
                self.audit.record(AuditEntry {
                    request_id: id,
                    user: user.clone(),
                    t_ms: now,
                    s_r,
                    island: None,
                    island_privacy: None,
                    sanitized: false,
                    reason: res,
                    reject_reason: reason,
                    failovers: 0,
                    trace_id: trace_id.clone(),
                });
                self.record_resolution(res, self.unrouted_event(res, id, &user, s_r, enqueued_ms, 0, trace_id));
                return Ok(Err(Outcome {
                    request_id: id,
                    s_r,
                    decision,
                    latency_ms: 0.0,
                    cost: 0.0,
                    response: String::new(),
                    sanitized: false,
                    tokens_generated: 0,
                    resolution: res,
                }));
            }
            Some(r) => r.clone(),
        };

        // resolve the routed island's tier label + cached metric cells once
        // at routing time — resolution-time bumps are then pure atomics
        let (tier, cells) = self.island_telemetry(&states, &routed);
        trace.add_span(
            "route",
            now,
            self.now_ms(),
            vec![
                ("candidates", Json::num(states.len() as f64)),
                ("island", Json::str(&routed.target.to_string())),
                ("tier", Json::str(tier)),
            ],
        );

        // Sanitize on trust-boundary crossing (Alg. 1 lines 14-17)
        let mut prepared = Prepared {
            id,
            session_id,
            user,
            request,
            s_r,
            decision,
            routed,
            sanitized: false,
            sanitized_at: None,
            now,
            failovers: 0,
            tier,
            cells,
            sanitized_turns: 0,
            enqueued_ms,
            routed_ms: now,
            prefill_ms: f64::NAN,
            first_token_ms: f64::NAN,
            trace,
        };
        self.sanitize_for_target(&mut prepared)?;
        Ok(Ok(prepared))
    }

    /// Tier label + cached per-island metric cells for a routing target.
    fn island_telemetry(&self, states: &[IslandState], routed: &Routed) -> (&'static str, Arc<IslandCells>) {
        let tier = states
            .iter()
            .find(|s| s.island.id == routed.target)
            .map(|s| s.island.tier.name())
            .unwrap_or("unknown");
        (tier, self.serving.island(routed.target.0, tier, routed.target_privacy))
    }

    /// Sanitize the request history + outgoing prompt for the currently
    /// routed target (Alg. 1 lines 14-17). Runs at prepare time, and again
    /// on failover re-routes: a hop to a *higher*-privacy island keeps the
    /// already-sanitized form (over-sanitization is privacy-safe), but a
    /// hop to a *lower*-privacy island than the one sanitized for must
    /// re-sanitize at the new level — entities between the two levels were
    /// left in cleartext by the first pass.
    ///
    /// The pass is INCREMENTAL and mostly lock-free: phase 1 reads the
    /// session's per-level sanitized-history cache under the shard read
    /// lock, phase 2 runs entity detection on the immutable snapshot with
    /// no lock held (only the delta turns appended since the last request
    /// at this — or a stricter — level are scanned; a failover hop to a
    /// lower level rescans the cached clean form, not the raw history),
    /// and phase 3 holds the write lock just for `PlaceholderMap` splices
    /// and the cache refresh. Detection cost therefore scales with the
    /// delta, and the shard critical section no longer serializes scans.
    fn sanitize_for_target(&self, p: &mut Prepared) -> anyhow::Result<()> {
        if !p.routed.sanitize {
            return Ok(());
        }
        let target_privacy = p.routed.target_privacy;
        if let Some(level) = p.sanitized_at {
            if target_privacy >= level {
                return Ok(());
            }
        }
        let sanitize_start = self.now_ms();
        // phase 1: capture the plan (cache prefix + delta) — shard read lock
        let Some(plan) = self
            .sessions
            .with(p.session_id, |s| s.plan_sanitize(target_privacy, &p.request.history, &p.request.prompt))
        else {
            self.audit_vanished(p.id, &p.user, p.now, p.s_r, "session closed before sanitization", p.failovers, &p.trace);
            anyhow::bail!("session {} closed mid-request", p.session_id);
        };
        // phase 2: entity detection on the immutable snapshot — NO lock
        let detected = plan.detect();
        // phase 3: placeholder splice + cache refresh — shard write lock
        let Some(wire) = self.sessions.with_mut(p.session_id, |s| detected.apply(s)) else {
            self.audit_vanished(p.id, &p.user, p.now, p.s_r, "session closed before sanitization", p.failovers, &p.trace);
            anyhow::bail!("session {} closed mid-request", p.session_id);
        };
        p.request.history = wire.history;
        p.request.prompt = wire.prompt;
        if !p.sanitized {
            // one per request that sanitized, however many failover hops
            self.serving.sanitized_requests.inc();
        }
        // real per-turn work: texts scanned + spliced this pass (delta
        // turns, respliced cached turns, the prompt) vs turns served
        // straight from the per-level cache
        self.serving.sanitized_turns.add(wire.transformed as u64);
        p.sanitized_turns += wire.transformed as u64;
        if wire.reused > 0 {
            self.serving.sanitized_turns_reused.add(wire.reused as u64);
        }
        p.sanitized = true;
        p.sanitized_at = Some(target_privacy);
        p.trace.add_span(
            "sanitize",
            sanitize_start,
            self.now_ms(),
            vec![
                ("transformed", Json::num(wire.transformed as f64)),
                ("reused", Json::num(wire.reused as f64)),
            ],
        );
        Ok(())
    }

    /// Audit trail entry for a request that consumed an id but fell out of
    /// the pipeline before execution (e.g. its session raced a `close()`).
    /// `failovers` carries any hops already counted in the `failovers`
    /// metric, keeping Σ audit.failovers == the metric even on this path.
    fn audit_vanished(&self, id: u64, user: &str, now: f64, s_r: f64, reason: &str, failovers: u32, trace: &TraceContext) {
        let res = Resolution::Failed(FailReason::SessionClosed);
        let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: id,
            user: user.to_string(),
            t_ms: now,
            s_r,
            island: None,
            island_privacy: None,
            sanitized: false,
            reason: res,
            reject_reason: Some(reason.to_string()),
            failovers,
            trace_id: trace_id.clone(),
        });
        self.record_resolution(res, self.unrouted_event(res, id, user, s_r, f64::NAN, failovers, trace_id));
    }

    /// Audit trail entry for a request that was admitted and routed but
    /// failed at execution — without this, failed executions would consume
    /// request ids yet vanish from the §XIV compliance trail.
    fn audit_execution_failure(&self, p: &Prepared, err: &anyhow::Error) {
        let res = Resolution::Failed(FailReason::ExecutionError);
        self.serving.execution_failed.inc();
        let trace_id = p.trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: Some(p.routed.target),
            island_privacy: Some(p.routed.target_privacy),
            sanitized: p.sanitized,
            reason: res,
            reject_reason: Some(format!("execution failed: {err}")),
            failovers: p.failovers,
            trace_id: trace_id.clone(),
        });
        self.record_resolution(res, self.prepared_event(p, res, true, 0, f64::NAN, 0.0, trace_id));
    }

    /// Audit + metrics + fail-closed Outcome for a request whose failover
    /// retry budget ran out: the request is *rejected*, never silently
    /// lost — exactly one audit entry, zero cost charged.
    fn finish_exhausted(&self, p: Prepared, reason: String) -> Outcome {
        let res = Resolution::Failed(FailReason::FailoverExhausted);
        self.serving.rejected_failover_exhausted.inc();
        let trace_id = p.trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: None,
            island_privacy: None,
            sanitized: p.sanitized,
            reason: res,
            reject_reason: Some(reason.clone()),
            failovers: p.failovers,
            trace_id: trace_id.clone(),
        });
        // no island in the event either: every candidate it touched died
        self.record_resolution(res, self.prepared_event(&p, res, false, 0, f64::NAN, 0.0, trace_id));
        Outcome {
            request_id: p.id,
            s_r: p.s_r,
            decision: Decision::Reject { reason },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: p.sanitized,
            tokens_generated: 0,
            resolution: res,
        }
    }

    /// Post-execution bookkeeping shared by the single and batched paths.
    /// Does NOT append the conversation turn — callers do, so the batched
    /// path can record turns in submission order.
    fn finish(
        &self,
        p: Prepared,
        latency_ms: f64,
        cost: f64,
        raw_response: String,
        tokens_generated: usize,
    ) -> Outcome {
        // Desanitize the response before the user sees it (backward pass)
        let response = if p.sanitized {
            self.sessions.with(p.session_id, |s| s.placeholders.desanitize(&raw_response)).unwrap_or(raw_response)
        } else {
            raw_response
        };

        let res = Resolution::Served;
        // close the root span where the island's clock says the response
        // landed, so summed child spans reconcile with end-to-end latency
        // even when the global virtual clock lags the decode cursor
        let trace_end = {
            let n = self.now_ms();
            if p.prefill_ms.is_finite() && latency_ms.is_finite() { n.max(p.prefill_ms + latency_ms) } else { n }
        };
        let trace_id = p.trace.end_request_span(trace_end, res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: Some(p.routed.target),
            island_privacy: Some(p.routed.target_privacy),
            sanitized: p.sanitized,
            reason: res,
            reject_reason: None,
            failovers: p.failovers,
            trace_id: trace_id.clone(),
        });
        if p.failovers > 0 {
            self.serving.failover_successes.inc();
        }
        self.ledger.charge(&p.user, cost);
        self.serving.requests_served.inc();
        self.serving.latency_ms.observe(latency_ms);
        self.serving.cost_usd.observe(cost.max(1e-9));
        // per-island labeled series through the cells cached at route time
        p.cells.served.inc();
        p.cells.latency_ms.observe(latency_ms);
        self.record_resolution(res, self.prepared_event(&p, res, true, tokens_generated, latency_ms, cost, trace_id));

        Outcome {
            request_id: p.id,
            s_r: p.s_r,
            decision: p.decision,
            latency_ms,
            cost,
            response,
            sanitized: p.sanitized,
            tokens_generated,
            resolution: res,
        }
    }

    /// One execution attempt on the currently routed island. Island-down
    /// failures (crashed / left / unreachable) are separated from fatal
    /// errors so the caller can fail over.
    fn execute_once(&self, p: &Prepared) -> Result<(f64, f64, String, usize), AttemptErr> {
        match &self.backend {
            Backend::Sim(fleet) => match fleet.execute(p.routed.target, &p.request) {
                Ok(rep) => {
                    let ack = format!("[sim:{}] ack {} tokens", p.routed.target, p.request.max_new_tokens);
                    Ok((rep.latency_ms, rep.cost, ack, p.request.max_new_tokens))
                }
                Err(e) => Err(AttemptErr::IslandDown(e.to_string())),
            },
            Backend::Real { executor: island_executor, islands } => {
                let Some(island) = islands.iter().find(|i| i.id == p.routed.target).cloned() else {
                    return Err(AttemptErr::IslandDown(format!("island {} missing", p.routed.target)));
                };
                match island_executor.execute(&island, &p.request) {
                    Ok(resp) => Ok((resp.compute_ms + resp.network_ms, resp.cost, resp.text, resp.tokens_generated)),
                    Err(e) if executor::is_island_down(&e) => Err(AttemptErr::IslandDown(e.to_string())),
                    Err(e) => Err(AttemptErr::Fatal(e)),
                }
            }
        }
    }

    /// Failure-aware execution (the tentpole of dynamic membership): when
    /// the routed island died between routing and execute, mark it offline
    /// in the liveness view and re-route to the next Pareto candidate, up
    /// to the configured retry budget. Each hop is recorded in per-island
    /// failover metrics and lands in the request's single audit entry.
    fn execute_with_failover(&self, p: &mut Prepared) -> ExecEnd {
        if p.prefill_ms.is_nan() {
            p.prefill_ms = self.now_ms();
        }
        loop {
            let down_reason = match self.execute_once(p) {
                Ok((latency, cost, text, tokens)) => {
                    // run-to-completion execution: prefill and decode are one
                    // island-side interval, exported as a single-chunk span
                    p.trace.add_span("prefill", p.prefill_ms, p.prefill_ms, vec![]);
                    p.trace.add_span(
                        "decode",
                        p.prefill_ms,
                        p.prefill_ms + latency.max(0.0),
                        vec![("chunks", Json::num(1.0)), ("tokens", Json::num(tokens as f64))],
                    );
                    return ExecEnd::Done(latency, cost, text, tokens);
                }
                Err(AttemptErr::Fatal(e)) => return ExecEnd::Fatal(e),
                Err(AttemptErr::IslandDown(reason)) => reason,
            };
            // the liveness view learns from the failed execution at once.
            // Every island-down attempt counts in BOTH the metric and the
            // request's audit field, so Σ audit.failovers == the `failovers`
            // counter holds even for budget-exhausted requests.
            let dead = p.routed.target;
            self.lighthouse.mark_offline(dead);
            self.serving.failovers.inc();
            self.serving.failover_from(dead.0).inc();
            p.failovers += 1;
            let hop_at = self.now_ms();
            p.trace.add_span(
                "failover_hop",
                hop_at,
                hop_at,
                vec![("from", Json::str(&dead.to_string())), ("hop", Json::num(p.failovers as f64))],
            );
            if p.failovers > self.retry_budget {
                return ExecEnd::Exhausted {
                    reason: format!(
                        "retry budget exhausted after {} failed attempts (last: {down_reason})",
                        p.failovers
                    ),
                };
            }
            // re-route over the surviving fleet
            let (states, local_capacity) = self.routing_view();
            let pref = self.hysteresis.lock_clean().observe(local_capacity);
            let budget_left = self.ledger.remaining(&p.user, self.budget_ceiling);
            let decision = self.waves.route(&p.request, p.s_r, &states, local_capacity, pref, budget_left);
            match decision.routed() {
                Some(r) => {
                    p.routed = r.clone();
                    p.decision = decision.clone();
                    // the hop changed the serving island: re-resolve the
                    // tier label + cached metric cells alongside it
                    let (tier, cells) = self.island_telemetry(&states, &p.routed);
                    p.tier = tier;
                    p.cells = cells;
                    // a failover hop may cross a trust boundary the first
                    // island did not — sanitize before retrying.
                    // sanitize_for_target audits its own failure, so this
                    // request id must not get a second entry downstream.
                    if let Err(e) = self.sanitize_for_target(p) {
                        return ExecEnd::FatalAudited(e);
                    }
                }
                None => {
                    let why = match &decision {
                        Decision::Reject { reason } => reason.clone(),
                        _ => "no candidate".to_string(),
                    };
                    return ExecEnd::Exhausted {
                        reason: format!("failover re-route failed after {} attempts: {why}", p.failovers),
                    };
                }
            }
        }
    }

    /// Execute a prepared request through the failure-aware path and settle
    /// its accounting (no conversation-turn recording — callers own that).
    fn run_prepared(&self, mut p: Prepared) -> anyhow::Result<Outcome> {
        match self.execute_with_failover(&mut p) {
            ExecEnd::Done(latency_ms, cost, raw, tokens) => Ok(self.finish(p, latency_ms, cost, raw, tokens)),
            ExecEnd::Exhausted { reason } => Ok(self.finish_exhausted(p, reason)),
            ExecEnd::Fatal(e) => {
                self.audit_execution_failure(&p, &e);
                Err(e)
            }
            ExecEnd::FatalAudited(e) => Err(e),
        }
    }

    /// Submit one typed request within a session and block until it
    /// completes (Fig. 2 pipeline, caller's thread). Returns Err for
    /// rate-limited submissions, Ok(Outcome) otherwise — including
    /// fail-closed rejections, which are Outcomes with a Reject decision
    /// (routing rejects and exhausted failover retries alike). For a
    /// non-blocking submission with queue-level scheduling and
    /// cross-session batching, use [`Orchestrator::enqueue`].
    pub fn submit_request(&self, session_id: u64, sr: SubmitRequest) -> anyhow::Result<Outcome> {
        let prepared = match self.prepare(session_id, &sr)? {
            Err(rejected) => return Ok(rejected),
            Ok(p) => p,
        };

        let outcome = self.run_prepared(prepared)?;
        // record the turn against the island it actually ran on (failover
        // hops update the decision, so this is the final island)
        if let Some(r) = outcome.decision.routed() {
            let _ =
                self.sessions.with_mut(session_id, |s| s.record_turn(&sr.prompt, &outcome.response, r.target_privacy));
        }
        Ok(outcome)
    }

    /// Submit a batch of typed requests for one session. Each item is
    /// admitted, scored and routed like a [`submit_request`] call racing
    /// the rest of the batch: routing and sanitization see the pre-batch
    /// session snapshot (items do not observe each other's turns), while
    /// conversation turns are appended in input order once the whole batch
    /// has executed. Items co-routed to the same island are coalesced
    /// through the live [`BatchPolicy`] and executed together — on the Real
    /// backend one `execute_batch` call per group fills the compiled PJRT
    /// batch variants. Per-item results preserve input order.
    ///
    /// [`submit_request`]: Orchestrator::submit_request
    pub fn submit_many_requests(&self, session_id: u64, items: Vec<SubmitRequest>) -> Vec<anyhow::Result<Outcome>> {
        let mut results: Vec<Option<anyhow::Result<Outcome>>> = (0..items.len()).map(|_| None).collect();
        let mut ready: Vec<(usize, Prepared)> = Vec::new();

        for (idx, sr) in items.iter().enumerate() {
            match self.prepare(session_id, sr) {
                Err(e) => results[idx] = Some(Err(e)),
                Ok(Err(rejected)) => results[idx] = Some(Ok(rejected)),
                Ok(Ok(prepared)) => ready.push((idx, prepared)),
            }
        }

        for (idx, result) in self.execute_coalesced(ready) {
            results[idx] = Some(result);
        }

        // Append conversation turns in input order (executed items only),
        // so the stored history reads as the user submitted it even though
        // island groups completed in arbitrary order.
        for (idx, sr) in items.iter().enumerate() {
            if let Some(Ok(out)) = &results[idx] {
                if let Some(r) = out.decision.routed() {
                    let _ = self
                        .sessions
                        .with_mut(session_id, |s| s.record_turn(&sr.prompt, &out.response, r.target_privacy));
                }
            }
        }

        // Every item must have been decided by the coalesced execution;
        // convert a hole to a typed error (fail-closed) instead of
        // panicking the submitter if that invariant ever regresses.
        results
            .into_iter()
            .map(|r| r.unwrap_or_else(|| Err(anyhow::anyhow!("request left undecided by batch execution"))))
            .collect()
    }

    /// The shared coalescing executor behind [`submit_many_requests`] and
    /// the admission-queue drain: group routed requests per target island
    /// (whoever submitted them — this is where cross-session batching
    /// happens on the queue path), chunk each group by the live batching
    /// policy, and execute chunk by chunk. Each input's opaque key `K`
    /// travels with it so callers can map results back (a results index, a
    /// ticket, ...). Returns one entry per input, in no particular order.
    ///
    /// [`submit_many_requests`]: Orchestrator::submit_many_requests
    fn execute_coalesced<K>(&self, ready: Vec<(K, Prepared)>) -> Vec<(K, anyhow::Result<Outcome>)> {
        let policy = self.batch_policy();
        let mut by_island: Vec<(IslandId, Vec<(K, Prepared)>)> = Vec::new();
        for (key, prepared) in ready {
            let target = prepared.routed.target;
            match by_island.iter_mut().find(|(id, _)| *id == target) {
                Some((_, group)) => group.push((key, prepared)),
                None => by_island.push((target, vec![(key, prepared)])),
            }
        }

        let mut done: Vec<(K, anyhow::Result<Outcome>)> = Vec::new();
        for (island_id, group) in by_island {
            for chunk in chunk_by_policy(group, policy) {
                self.serving.batch_groups.inc();
                self.serving.batch_group_size.observe(chunk.len() as f64);
                match &self.backend {
                    Backend::Sim(_) => {
                        // the sim executes per request; co-routed grouping
                        // only exercises the batching policy. Each item gets
                        // the full failure-aware path, so a group routed to
                        // an island that crashed mid-batch fails over
                        // per-item instead of erroring out wholesale.
                        for (key, prepared) in chunk {
                            done.push((key, self.run_prepared(prepared)));
                        }
                    }
                    Backend::Real { executor: island_executor, islands } => {
                        let spec = islands.iter().find(|i| i.id == island_id).cloned();
                        let responses = match spec {
                            // island gone from the mesh: per-item failover
                            None => None,
                            Some(island) => {
                                let requests: Vec<Request> = chunk.iter().map(|(_, p)| p.request.clone()).collect();
                                match island_executor.execute_batch(&island, &requests) {
                                    Ok(responses) => Some(responses),
                                    // batch-level failure (island gone or
                                    // link dead): per-item failover
                                    Err(e) if executor::is_island_down(&e) => None,
                                    Err(e) => {
                                        // fatal for the whole chunk
                                        let msg = e.to_string();
                                        for (key, prepared) in chunk {
                                            let err = anyhow::anyhow!("batch execute failed: {msg}");
                                            self.audit_execution_failure(&prepared, &err);
                                            done.push((key, Err(err)));
                                        }
                                        continue;
                                    }
                                }
                            }
                        };
                        match responses {
                            Some(responses) => {
                                for ((key, prepared), resp) in chunk.into_iter().zip(responses) {
                                    let latency = resp.compute_ms + resp.network_ms;
                                    let tokens = resp.tokens_generated;
                                    let out = self.finish(prepared, latency, resp.cost, resp.text, tokens);
                                    done.push((key, Ok(out)));
                                }
                            }
                            None => {
                                for (key, prepared) in chunk {
                                    done.push((key, self.run_prepared(prepared)));
                                }
                            }
                        }
                    }
                }
            }
        }
        done
    }

    // -- continuous (decode-step) batching: the queue drain's Sim-backend
    // -- execution path in BatchMode::Continuous --------------------------

    /// Hand routed requests to their islands' step loops. Jobs are admitted
    /// to every lane *first* (so no island's work waits on another island's
    /// drive loop), then this thread drives whichever lanes have no active
    /// driver. Lanes with a driver already running pick the new jobs up at
    /// that driver's next step boundary — this is where a newly routed
    /// request joins an in-flight batch mid-decode.
    fn execute_stepped(&self, ready: Vec<(QueuedKey, Prepared)>) {
        let mut by_island: Vec<(IslandId, Vec<StepJob>)> = Vec::new();
        for (key, prepared) in ready {
            let target = prepared.routed.target;
            let job = StepJob { key, prepared };
            match by_island.iter_mut().find(|(id, _)| *id == target) {
                Some((_, group)) => group.push(job),
                None => by_island.push((target, vec![job])),
            }
        }
        let mut islands: Vec<IslandId> = Vec::with_capacity(by_island.len());
        for (island, group) in by_island {
            self.serving.batch_groups.inc();
            self.serving.batch_group_size.observe(group.len() as f64);
            self.step_lanes.admit(island, group);
            islands.push(island);
        }
        for island in islands {
            if self.step_lanes.try_drive(island) {
                self.drive_island(island);
            }
        }
    }

    /// Run one island's step loop as its (sole) driver, with panic
    /// containment: a panicking step loop fails its in-flight and pending
    /// tickets with an error — audited, never silently lost — and releases
    /// the lane so the island stays usable.
    fn drive_island(&self, island: IslandId) {
        let mut active: Vec<Active> = Vec::new();
        let drove = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.drive_island_inner(island, &mut active)
        }));
        if drove.is_err() {
            self.serving.step_drive_panics.inc();
            let res = Resolution::Shed(ShedReason::WorkerPanic);
            let now = self.now_ms();
            let orphans = active.drain(..).map(|a| a.job).chain(self.step_lanes.fail_pending(island));
            for job in orphans {
                // resolve directly (not resolve_ticket): a job whose ticket
                // already resolved before the panic is not a double
                // resolution, just a straggler check. The contains() guard
                // keeps "exactly one audit entry per consumed id".
                if job.key.ticket.resolve(Err("internal error: island step loop panicked".to_string()))
                    && !self.audit.contains(job.prepared.id)
                {
                    let trace_id = job.prepared.trace.end_request_span(now, res.class(), res.reason());
                    let entry = AuditEntry::unrouted(
                        job.prepared.id,
                        &job.prepared.user,
                        now,
                        res,
                        "shed: island step loop panicked",
                    )
                    .with_trace(trace_id.clone());
                    self.audit.record(entry);
                    self.record_resolution(
                        res,
                        self.prepared_event(&job.prepared, res, true, 0, f64::NAN, 0.0, trace_id),
                    );
                }
            }
        }
    }

    /// The per-island step loop (vLLM-style continuous batching, virtual
    /// time): each round tops the in-flight batch up from the lane inbox
    /// (up to `max_batch`), then advances every in-flight request by one
    /// decode chunk. Requests finish, cancel, or expire *individually* at
    /// step boundaries — a slot freed mid-round is refilled on the next
    /// round without waiting for the rest of the batch.
    fn drive_island_inner(&self, island: IslandId, active: &mut Vec<Active>) {
        let Some(fleet) = self.sim_fleet() else {
            // continuous stepping is Sim-only; anything admitted here runs
            // through the one-shot failure-aware path instead
            for job in self.step_lanes.fail_pending(island) {
                self.settle_queued(job.key, self.run_prepared(job.prepared));
            }
            return;
        };
        loop {
            let policy = self.batch_policy();
            let room = policy.max_batch.saturating_sub(active.len());
            for job in self.step_lanes.take(island, room) {
                self.begin_decode(fleet, job, active);
            }
            if active.is_empty() {
                if self.step_lanes.try_exit(island) {
                    return;
                }
                continue; // jobs arrived while winding down — keep driving
            }
            self.serving.batch_occupancy.observe(active.len() as f64);
            self.serving.steady_state_batch_occupancy.set(active.len() as f64);
            let chunk = policy.decode_chunk.max(1);
            let mut idx = 0;
            while idx < active.len() {
                match self.step_one(fleet, &mut active[idx], chunk) {
                    StepVerdict::Running => idx += 1,
                    verdict => {
                        // Vec::remove (not swap_remove): conclusions stay in
                        // admission order, so co-finishing requests audit in
                        // the order the queue released them
                        let finished = active.remove(idx);
                        self.conclude_active(island, finished, verdict);
                    }
                }
            }
        }
    }

    /// Prefill one admitted job and add it to the in-flight batch. A cancel
    /// that arrived while the job sat in the lane resolves here without
    /// touching the island; a prefill failure (island died after routing)
    /// falls back to the one-shot failure-aware path, which re-routes.
    fn begin_decode(&self, fleet: &Fleet, job: StepJob, active: &mut Vec<Active>) {
        if job.key.ticket.cancel_requested() {
            self.cancel_before_execution(job);
            return;
        }
        let StepJob { key, mut prepared } = job;
        prepared.prefill_ms = self.now_ms();
        match fleet.prefill(prepared.routed.target, &prepared.request) {
            Ok(handle) => {
                // decode starts where the island's clock says prefill ended
                let decode_start_ms = handle.cursor_ms();
                prepared.trace.add_span("prefill", prepared.prefill_ms, decode_start_ms, vec![]);
                active.push(Active { job: StepJob { key, prepared }, handle, decode_start_ms, decode_chunks: 0 });
            }
            Err(_) => self.settle_queued(key, self.run_prepared(prepared)),
        }
    }

    /// Resolve a job cancelled after routing but before any island work:
    /// audited with the real MIST score and routing evidence, zero cost.
    fn cancel_before_execution(&self, job: StepJob) {
        let StepJob { key, prepared } = job;
        let res = Resolution::Cancelled(CancelPoint::BeforeExecution);
        self.serving.cancelled_before_execution.inc();
        let reason = "cancelled: by caller before execution".to_string();
        let trace_id = prepared.trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: prepared.id,
            user: prepared.user.clone(),
            t_ms: prepared.now,
            s_r: prepared.s_r,
            island: None,
            island_privacy: None,
            sanitized: prepared.sanitized,
            reason: res,
            reject_reason: Some(reason.clone()),
            failovers: prepared.failovers,
            trace_id: trace_id.clone(),
        });
        self.record_resolution(res, self.prepared_event(&prepared, res, false, 0, f64::NAN, 0.0, trace_id));
        let outcome = Outcome {
            request_id: prepared.id,
            s_r: prepared.s_r,
            decision: Decision::Reject { reason },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: prepared.sanitized,
            tokens_generated: 0,
            resolution: res,
        };
        self.settle_queued(key, Ok(outcome));
    }

    /// Advance one in-flight request by up to `chunk` decode tokens,
    /// checking the cooperative cancel flag and the absolute deadline at
    /// the step boundary first — this is what makes mid-decode cancellation
    /// prompt: a cancel or an expired deadline frees the slot after the
    /// current chunk, not after the full token budget.
    fn step_one(&self, fleet: &Fleet, a: &mut Active, chunk: usize) -> StepVerdict {
        if a.job.key.ticket.cancel_requested() {
            return StepVerdict::CancelRequested;
        }
        // d_r is the remaining budget measured from routing time (`now`), so
        // their sum is the request's absolute deadline in virtual time
        let deadline_at = a.job.prepared.now + a.job.prepared.request.deadline_ms;
        if a.handle.cursor_ms() > deadline_at {
            return StepVerdict::DeadlineExpired;
        }
        match fleet.decode_step(&mut a.handle, chunk) {
            Err(_) => StepVerdict::IslandGone,
            Ok(n) => {
                if n > 0 {
                    a.decode_chunks += 1;
                    if a.job.prepared.first_token_ms.is_nan() {
                        // virtual decode cursor: when the first chunk's
                        // tokens became available on the island's clock
                        a.job.prepared.first_token_ms = a.handle.cursor_ms();
                    }
                    let to = a.handle.tokens_decoded();
                    a.job.key.ticket.push_tokens(&format!("[sim:{} t{}..{}]", a.handle.island(), to - n, to));
                }
                if a.handle.is_complete() {
                    StepVerdict::Done
                } else {
                    StepVerdict::Running
                }
            }
        }
    }

    /// Settle a request leaving the in-flight batch (any reason but
    /// `Running`).
    fn conclude_active(&self, island: IslandId, finished: Active, verdict: StepVerdict) {
        let Active { job, handle, decode_start_ms, decode_chunks } = finished;
        let StepJob { key, prepared } = job;
        let budget = prepared.request.max_new_tokens;
        // one coalesced decode span per batch membership, chunk count as an
        // attribute — a span per chunk would drown the trace viewer
        prepared.trace.add_span(
            "decode",
            decode_start_ms,
            handle.cursor_ms(),
            vec![
                ("chunks", Json::num(decode_chunks as f64)),
                ("tokens", Json::num(handle.tokens_decoded() as f64)),
            ],
        );
        match verdict {
            StepVerdict::Running => unreachable!("running requests stay in the batch"),
            StepVerdict::Done => {
                let report = handle.report();
                let response = format!("[sim:{}] ack {} tokens", island, handle.tokens_decoded());
                let out = self.finish(prepared, report.latency_ms, report.cost, response, handle.tokens_decoded());
                self.settle_queued(key, Ok(out));
            }
            StepVerdict::CancelRequested => {
                self.serving.cancelled_mid_decode.inc();
                let reason = format!("cancelled: by caller after {}/{} tokens", handle.tokens_decoded(), budget);
                let out = self.finish_cancelled(prepared, &handle, reason, CancelPoint::MidDecode);
                self.settle_queued(key, Ok(out));
            }
            StepVerdict::DeadlineExpired => {
                self.serving.cancelled_deadline_mid_decode.inc();
                let reason = format!(
                    "cancelled: deadline expired mid-decode after {}/{} tokens",
                    handle.tokens_decoded(),
                    budget
                );
                let out = self.finish_cancelled(prepared, &handle, reason, CancelPoint::DeadlineMidDecode);
                self.settle_queued(key, Ok(out));
            }
            StepVerdict::IslandGone => {
                // partial work on an island that died mid-decode is never
                // charged (same as a failed one-shot attempt); the
                // failure-aware path marks it offline and re-routes
                self.settle_queued(key, self.run_prepared(prepared));
            }
        }
    }

    /// Post-cancellation bookkeeping: the mirror of [`finish`] for a decode
    /// stopped early. The audit entry keeps the island and routing evidence
    /// under a `cancelled:` reason (disjoint from `shed:` — this request
    /// *ran*, partially), and the ledger is charged exactly the prefill +
    /// decoded-token cost the handle accumulated — never the full budget.
    ///
    /// [`finish`]: Orchestrator::finish
    fn finish_cancelled(&self, p: Prepared, handle: &DecodeHandle, reason: String, point: CancelPoint) -> Outcome {
        let res = Resolution::Cancelled(point);
        let report = handle.report();
        let trace_end = self.now_ms().max(handle.cursor_ms());
        let trace_id = p.trace.end_request_span(trace_end, res.class(), res.reason());
        self.audit.record(AuditEntry {
            request_id: p.id,
            user: p.user.clone(),
            t_ms: p.now,
            s_r: p.s_r,
            island: Some(p.routed.target),
            island_privacy: Some(p.routed.target_privacy),
            sanitized: p.sanitized,
            reason: res,
            reject_reason: Some(reason),
            failovers: p.failovers,
            trace_id: trace_id.clone(),
        });
        self.ledger.charge(&p.user, report.cost);
        self.serving.requests_cancelled.inc();
        self.serving.cancelled_tokens_decoded.observe(handle.tokens_decoded() as f64);
        self.record_resolution(
            res,
            self.prepared_event(&p, res, true, handle.tokens_decoded(), report.latency_ms, report.cost, trace_id),
        );
        Outcome {
            request_id: p.id,
            s_r: p.s_r,
            decision: p.decision,
            latency_ms: report.latency_ms,
            cost: report.cost,
            response: format!("[sim:{}] cancelled after {} tokens", p.routed.target, handle.tokens_decoded()),
            sanitized: p.sanitized,
            tokens_generated: handle.tokens_decoded(),
            resolution: res,
        }
    }
}

/// Why [`Orchestrator::admit_typed`] refused a submission.
enum AdmitErr {
    /// No session with this id — nothing to attribute the request to.
    UnknownSession(u64),
    /// The per-user token bucket refused the request (Attack 4).
    RateLimited { user: String },
}

/// What the queue drain needs, besides the [`Prepared`] request, to resolve
/// one queued submission: its ticket, and the original (pre-sanitization)
/// prompt + session for conversation-turn recording.
struct QueuedKey {
    ticket: Arc<TicketCell>,
    session_id: u64,
    prompt: String,
}

// --- the non-blocking request lifecycle: enqueue → admit → [queue] →
// --- route → batch → execute → resolve
impl Orchestrator {
    /// Enqueue a typed request and return a [`Ticket`] immediately (the
    /// non-blocking serving surface). Admission (session lookup + rate
    /// limit) runs here, so floods are refused at the front door; admitted
    /// requests park in the bounded priority+deadline-ordered queue until
    /// the worker pool ([`Orchestrator::start_queue`]) drains them. A full
    /// queue sheds the incoming request fail-closed — audited, metered
    /// (`rejected_queue_full`), and the ticket resolves at once with the
    /// reject outcome. Tickets are never lost: every enqueue resolves
    /// exactly once (served, rejected, shed, or an error).
    pub fn enqueue(&self, session_id: u64, mut submit: SubmitRequest) -> Ticket {
        let (ticket, cell) = Ticket::new_pair();
        let admitted_at = self.now_ms();
        // the root span opens at the front door — or is adopted from the
        // HTTP submit handler, which starts it before parsing the body — so
        // even a rate-limited shed leaves a complete (always-kept) trace
        let trace = TraceSink::adopt_or_start(&self.traces, &submit.trace, admitted_at);
        let user = match self.admit_typed(session_id) {
            Ok(user) => user,
            Err(AdmitErr::UnknownSession(sid)) => {
                // unknown session: refused before consuming a request id,
                // mirroring the blocking path's Err return — there is no
                // user to audit the refusal against
                trace.end_request_span(self.now_ms(), "failed", "unknown_session");
                self.resolve_ticket(&cell, Err(anyhow::anyhow!("unknown session {sid}")));
                return ticket;
            }
            Err(AdmitErr::RateLimited { user }) => {
                // rate-limited floods shed with a typed resolution: the
                // serving surface needs a `Shed(RateLimited)` outcome (and
                // one audit entry) to answer 429 with evidence, not a
                // stringly error
                trace.set_user(&user);
                self.shed_rate_limited(&cell, &user, &trace);
                return ticket;
            }
        };
        trace.set_user(&user);
        let id = self.next_request_id.fetch_add(1, Ordering::SeqCst);
        if let Err(why) = submit.validate() {
            // degenerate budgets shed fail-closed at the front door: a
            // zero-token or zero-deadline request could never be served,
            // only occupy a queue slot until the drain discovered it
            let rejected = self.reject_invalid(id, &user, &why, &trace);
            self.resolve_ticket(&cell, Ok(rejected));
            return ticket;
        }
        let now = self.now_ms();
        trace.add_span("admission", admitted_at, now, vec![]);
        submit.trace = trace;
        match self.queue.push(id, session_id, user, submit, now, Arc::clone(&cell)) {
            Ok(depth) => {
                // counted only for requests that actually entered the queue,
                // so `enqueued` minus resolutions tracks in-flight depth
                self.serving.enqueued.inc();
                self.serving.queue_depth.set(depth as f64);
            }
            Err(item) => self.shed_queue_full(item),
        }
        ticket
    }

    /// Requests currently parked in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Spawn the worker pool (`Config::serve_workers` threads) that drains
    /// the admission queue. Idempotent: the pool starts once per
    /// orchestrator; later calls return 0. Takes the `Arc` by value (clone
    /// one in: `Arc::clone(&orch).start_queue()`) because workers hold only
    /// a `Weak` reference downgraded from it — dropping the last external
    /// `Arc` shuts the queue down, resolves any still-parked tickets with
    /// an error, and the workers exit. Requests enqueued before
    /// `start_queue` stay parked until it is called (the queue-stress tests
    /// use this to force deep queues).
    pub fn start_queue(self: Arc<Self>) -> usize {
        if self.workers_started.swap(true, Ordering::SeqCst) {
            return 0;
        }
        for w in 0..self.serve_workers {
            let weak = Arc::downgrade(&self);
            let queue = Arc::clone(&self.queue);
            let audit = Arc::clone(&self.audit);
            std::thread::Builder::new()
                .name(format!("islandrun-serve-{w}"))
                .spawn(move || queue_worker(weak, queue, audit))
                // islandlint: allow(serving-path-panic) -- start_queue runs once at boot; a worker
                // pool that cannot spawn would hang every enqueued ticket forever, so fail fast.
                .expect("spawn serve worker");
        }
        self.serve_workers
    }

    /// Drain one popped batch: resolve cancelled-while-queued items, shed
    /// expired ones, prepare + route the rest, then execute — through the
    /// per-island step loops ([`BatchMode::Continuous`], Sim backend) or the
    /// coalescing run-to-completion path — and resolve every ticket exactly
    /// once. Either way, co-routed requests batch across sessions (this is
    /// the fleet-scale batching point).
    fn drain_batch(&self, batch: Vec<QueueItem>) {
        let now = self.now_ms();
        self.serving.queue_depth.set(self.queue.len() as f64);
        let mut ready: Vec<(QueuedKey, Prepared)> = Vec::new();
        for item in batch {
            let QueueItem { id, session_id, user, mut submit, enqueued_ms, deadline_at_ms, ticket, .. } = item;
            // every drained request gets a queue-wait span, including the
            // ones about to shed — the wait is exactly what killed them
            submit.trace.add_span("queue_wait", enqueued_ms, now, vec![("depth", Json::num(self.queue.len() as f64))]);
            if ticket.cancel_requested() {
                // cancelled before any routing work: cheapest exit
                self.cancel_while_queued(id, &user, &ticket, now - enqueued_ms, &submit.trace);
                continue;
            }
            if now > deadline_at_ms {
                self.shed_expired(id, &user, &ticket, now - enqueued_ms, &submit.trace);
                continue;
            }
            self.serving.queue_wait_ms.observe((now - enqueued_ms).max(0.0));
            // route on the REMAINING latency budget, not the original d_r:
            // time already burned in the queue is gone, and the deadline
            // feasibility filter must not pick an island that can only meet
            // the full budget (soft overall — the failsafe still queues).
            submit.deadline_ms = deadline_at_ms - now;
            match self.prepare_admitted(id, session_id, user, &submit, enqueued_ms) {
                Err(e) => self.resolve_ticket(&ticket, Err(e)),
                Ok(Err(rejected)) => self.resolve_ticket(&ticket, Ok(rejected)),
                Ok(Ok(prepared)) => ready.push((QueuedKey { ticket, session_id, prompt: submit.prompt }, prepared)),
            }
        }
        if self.batch_policy().mode == BatchMode::Continuous && self.sim_backed() {
            self.execute_stepped(ready);
        } else {
            for (key, result) in self.execute_coalesced(ready) {
                self.settle_queued(key, result);
            }
        }
    }

    /// Record the conversation turn (served, non-cancelled requests only —
    /// a partial decode is not a completed turn) and resolve the ticket.
    /// The single settlement point for every queued request that reached
    /// execution, on both batching paths.
    fn settle_queued(&self, key: QueuedKey, result: anyhow::Result<Outcome>) {
        if let Ok(out) = &result {
            if !out.cancelled() {
                if let Some(r) = out.decision.routed() {
                    let _ = self
                        .sessions
                        .with_mut(key.session_id, |s| s.record_turn(&key.prompt, &out.response, r.target_privacy));
                }
            }
        }
        self.resolve_ticket(&key.ticket, result);
    }

    /// Resolve a ticket cancelled while still parked in the admission
    /// queue: never routed, never executed — zero cost, one audit entry
    /// (under the `cancelled:` reason prefix, like every cancel).
    fn cancel_while_queued(&self, id: u64, user: &str, ticket: &TicketCell, waited_ms: f64, trace: &TraceContext) {
        let res = Resolution::Cancelled(CancelPoint::WhileQueued);
        self.serving.cancelled_while_queued.inc();
        let reason = format!("cancelled: by caller after {waited_ms:.0} ms in queue, before routing");
        let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
        // shaped like a shed entry (no island, s_r unscored) but carrying a
        // Cancelled reason, so AuditLog::sheds() stays load-shedding-only
        self.audit.record(AuditEntry::unrouted(id, user, self.now_ms(), res, &reason).with_trace(trace_id.clone()));
        let enqueued = self.now_ms() - waited_ms;
        self.record_resolution(res, self.unrouted_event(res, id, user, 0.0, enqueued, 0, trace_id));
        let outcome = Outcome {
            request_id: id,
            s_r: 0.0,
            decision: Decision::Reject { reason },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
            tokens_generated: 0,
            resolution: res,
        };
        self.resolve_ticket(ticket, Ok(outcome));
    }

    /// Resolve a ticket, folding `anyhow::Error` into the cloneable message
    /// form and counting any double resolution (the queue-stress invariant:
    /// `ticket_double_resolved` must stay 0).
    fn resolve_ticket(&self, cell: &TicketCell, result: anyhow::Result<Outcome>) {
        let value = result.map_err(|e| e.to_string());
        if !cell.resolve(value) {
            self.serving.ticket_double_resolved.inc();
        }
    }

    /// Shed an admitted request that found the queue full: fail-closed
    /// reject with exactly one audit entry, zero cost, and an immediately
    /// resolved ticket.
    fn shed_queue_full(&self, item: QueueItem) {
        let res = Resolution::Shed(ShedReason::QueueFull);
        self.serving.rejected_queue_full.inc();
        let reason = format!("shed: admission queue full ({} queued, fail-closed)", self.queue.capacity());
        let trace_id = item.submit.trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry::unrouted(item.id, &item.user, self.now_ms(), res, &reason).with_trace(trace_id.clone()));
        self.record_resolution(res, self.unrouted_event(res, item.id, &item.user, 0.0, item.enqueued_ms, 0, trace_id));
        self.resolve_shed(&item.ticket, item.id, reason, res);
    }

    /// Shed a request whose deadline `d_r` expired while it waited in the
    /// queue: by Def. 2 the answer is already useless, so the drain rejects
    /// it instead of burning island capacity on it.
    fn shed_expired(&self, id: u64, user: &str, ticket: &TicketCell, waited_ms: f64, trace: &TraceContext) {
        let res = Resolution::Shed(ShedReason::DeadlineExpired);
        self.serving.shed_deadline_expired.inc();
        let reason = format!("shed: deadline expired after {waited_ms:.0} ms in queue");
        let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry::unrouted(id, user, self.now_ms(), res, &reason).with_trace(trace_id.clone()));
        let enqueued = self.now_ms() - waited_ms;
        self.record_resolution(res, self.unrouted_event(res, id, user, 0.0, enqueued, 0, trace_id));
        self.resolve_shed(ticket, id, reason, res);
    }

    /// Shed a rate-limited submission on the queue path: consumes a request
    /// id and resolves the ticket with a `Shed(RateLimited)` outcome — one
    /// audit entry, one `requests_resolved` bump, zero cost — so the
    /// refusal is as observable as any other shed.
    fn shed_rate_limited(&self, ticket: &TicketCell, user: &str, trace: &TraceContext) {
        let id = self.next_request_id.fetch_add(1, Ordering::SeqCst);
        let res = Resolution::Shed(ShedReason::RateLimited);
        self.serving.rejected_rate_limited.inc();
        let reason = format!("shed: rate limited: user {user}");
        let trace_id = trace.end_request_span(self.now_ms(), res.class(), res.reason());
        self.audit.record(AuditEntry::unrouted(id, user, self.now_ms(), res, &reason).with_trace(trace_id.clone()));
        self.record_resolution(res, self.unrouted_event(res, id, user, 0.0, f64::NAN, 0, trace_id));
        self.resolve_shed(ticket, id, reason, res);
    }

    /// Consume a request id for a submission that failed to parse or
    /// validate at a serving boundary, before a [`SubmitRequest`] existed
    /// (the HTTP surface rejects malformed bodies fail-closed). One audit
    /// entry and one typed `Shed(InvalidRequest)` resolution, exactly like
    /// an in-process invalid submit.
    pub fn reject_at_front_door(&self, user: &str, why: &str, trace: &TraceContext) -> Outcome {
        let id = self.next_request_id.fetch_add(1, Ordering::SeqCst);
        self.reject_invalid(id, user, why, trace)
    }

    fn resolve_shed(&self, ticket: &TicketCell, id: u64, reason: String, res: Resolution) {
        let outcome = Outcome {
            request_id: id,
            s_r: 0.0,
            decision: Decision::Reject { reason },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
            tokens_generated: 0,
            resolution: res,
        };
        self.resolve_ticket(ticket, Ok(outcome));
    }
}

/// Worker-pool loop. Holds the queue `Arc` and the audit-log `Arc` but only
/// a `Weak` orchestrator: the pool must never keep the orchestrator alive,
/// or `Drop` (which closes the queue) could never run and the workers would
/// block forever. Each iteration upgrades briefly to read the live batch
/// policy, releases the `Arc` *before* blocking on the queue, then
/// re-upgrades to drain. Drains run under `catch_unwind` so a panicking
/// batch (poisoned lock, a bug in an agent) fails its own tickets with an
/// error instead of leaking them — the worker survives, and every straggler
/// this loop resolves is also audited, preserving "one entry per consumed
/// id" on both the panic and the shutdown path.
fn queue_worker(orch: Weak<Orchestrator>, queue: Arc<AdmissionQueue>, audit: Arc<AuditLog>) {
    loop {
        let policy = match orch.upgrade() {
            Some(o) => o.batch_policy(),
            None => return,
        }; // Arc released here — never hold it across the blocking pop
        let Some(batch) = queue.pop_batch(policy.max_batch, policy.max_wait) else {
            return; // queue closed and drained: shutdown
        };
        let Some(o) = orch.upgrade() else {
            // orchestrator dropped between pop and drain: its Drop already
            // handled everything still queued; these popped items are ours
            // to fail — resolved AND audited (never drained, so none of
            // their ids can already be on the trail), so no id vanishes
            for item in &batch {
                if item.ticket.resolve(Err("orchestrator shut down before the request was served".into()))
                    && !audit.contains(item.id)
                {
                    let res = Resolution::Shed(ShedReason::Shutdown);
                    // the trace sink is owned by the dropped orchestrator:
                    // end_request_span fails soft through its Weak handle
                    let trace_id = item.submit.trace.end_request_span(item.enqueued_ms, res.class(), res.reason());
                    let entry = AuditEntry::unrouted(
                        item.id,
                        &item.user,
                        item.enqueued_ms,
                        res,
                        "shed: orchestrator shut down",
                    )
                    .with_trace(trace_id);
                    audit.record(entry);
                }
            }
            return;
        };
        let stragglers: Vec<(u64, String, Arc<TicketCell>, TraceContext)> =
            batch.iter().map(|i| (i.id, i.user.clone(), Arc::clone(&i.ticket), i.submit.trace.clone())).collect();
        let drained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| o.drain_batch(batch)));
        if drained.is_err() {
            // drain_batch resolves (and audits) as it goes; first-one-wins
            // resolution identifies the stragglers of the panicked batch.
            // A straggler whose execution already reached the audit trail
            // (panic between finish() and its ticket resolution) must NOT
            // get a second entry — the contains() check keeps the §XIV
            // "exactly one entry per consumed id" invariant through panics.
            o.serving.queue_drain_panics.inc();
            let res = Resolution::Shed(ShedReason::WorkerPanic);
            let now = o.now_ms();
            for (id, user, cell, trace) in &stragglers {
                if cell.resolve(Err("internal error: queue drain panicked".into())) && !o.audit.contains(*id) {
                    let trace_id = trace.end_request_span(now, res.class(), res.reason());
                    o.audit.record(
                        AuditEntry::unrouted(*id, user, now, res, "shed: queue drain panicked")
                            .with_trace(trace_id.clone()),
                    );
                    o.record_resolution(res, o.unrouted_event(res, *id, user, 0.0, f64::NAN, 0, trace_id));
                }
            }
        }
        drop(o);
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        // Close the queue: wakes every worker (they exit on the None pop)
        // and hands back whatever was still parked — those requests
        // consumed ids, so they are audited and their tickets resolved
        // rather than silently lost.
        let leftovers = self.queue.close();
        if leftovers.is_empty() {
            return;
        }
        let now = self.now_ms();
        let res = Resolution::Shed(ShedReason::Shutdown);
        for item in leftovers {
            let trace_id = item.submit.trace.end_request_span(now, res.class(), res.reason());
            self.audit.record(
                AuditEntry::unrouted(item.id, &item.user, now, res, "shed: orchestrator shut down while queued")
                    .with_trace(trace_id.clone()),
            );
            if item.ticket.resolve(Err("orchestrator shut down before the request was served".to_string())) {
                self.record_resolution(
                    res,
                    self.unrouted_event(res, item.id, &item.user, 0.0, item.enqueued_ms, 0, trace_id),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset_personal_group;
    use crate::types::PriorityTier;

    fn sim_orchestrator() -> Orchestrator {
        let fleet = Fleet::new(preset_personal_group(), 11);
        Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 42)
    }

    /// Blocking positional-form submit, now spelled through the typed
    /// [`SubmitRequest`] surface (the old `submit` shim is gone).
    fn submit(o: &Orchestrator, session: u64, prompt: &str, priority: PriorityTier) -> anyhow::Result<Outcome> {
        o.submit_request(session, SubmitRequest::new(prompt).priority(priority))
    }

    #[test]
    fn sensitive_prompt_stays_personal() {
        let o = sim_orchestrator();
        let s = o.open_session("alice");
        let out =
            submit(&o, s, "patient john doe ssn 123-45-6789 diagnosed with diabetes", PriorityTier::Primary).unwrap();
        assert!(out.s_r >= 0.9);
        let target = out.decision.target().unwrap();
        let islands = preset_personal_group();
        assert_eq!(islands.iter().find(|i| i.id == target).unwrap().privacy, 1.0);
        assert_eq!(out.cost, 0.0);
        assert!(!out.sanitized, "intra-personal must bypass MIST sanitization");
    }

    #[test]
    fn boundary_crossing_sanitizes_and_desanitizes() {
        let o = sim_orchestrator();
        let s = o.open_session("alice");
        // turn 1: sensitive, runs locally
        submit(&o, s, "patient john doe has diabetes", PriorityTier::Primary).unwrap();
        // saturate local islands so the next burstable turn offloads
        o.saturate_bounded_islands(0.99);
        let out = submit(&o, s, "what are common complications", PriorityTier::Burstable).unwrap();
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| i.id == out.decision.target().unwrap()).unwrap();
        assert!(target.privacy < 1.0, "should offload, got {}", target.name);
        assert!(out.sanitized, "crossing 1.0 -> {} must sanitize history", target.privacy);
        // stored history must keep the ORIGINAL user text (desanitized view)
        let has = o.sessions.with(s, |sess| sess.history.iter().any(|t| t.text.contains("complications"))).unwrap();
        assert!(has);
    }

    #[test]
    fn rejection_is_fail_closed_not_error() {
        let o = sim_orchestrator();
        // remove all personal islands: sensitive requests unroutable
        o.retain_islands(|i| i.privacy < 0.9);
        let s = o.open_session("bob");
        let out = submit(&o, s, "patient john doe ssn 123-45-6789", PriorityTier::Primary).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.metrics.counter_value("rejected_fail_closed"), 1);
    }

    #[test]
    fn rate_limit_blocks_floods() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 2.0;
        let fleet = Fleet::new(preset_personal_group(), 1);
        let o = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 1);
        let s = o.open_session("mallory");
        let mut blocked = 0;
        for _ in 0..10 {
            if submit(&o, s, "hello", PriorityTier::Burstable).is_err() {
                blocked += 1;
            }
        }
        assert!(blocked >= 7, "blocked={blocked}");
        assert!(o.metrics.counter_value("rate_limited") >= 7);
    }

    #[test]
    fn enqueue_sheds_rate_limited_floods_with_typed_resolution() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 0.001; // burst of 1, effectively no refill
        let fleet = Fleet::new(preset_personal_group(), 3);
        let o = std::sync::Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 3));
        std::sync::Arc::clone(&o).start_queue();
        let s = o.open_session("mallory");
        let first = o.enqueue(s, SubmitRequest::new("hello"));
        let flood = o.enqueue(s, SubmitRequest::new("hello again"));
        let out = flood.wait().expect("rate-limited enqueue resolves a typed outcome, not Err");
        assert_eq!(out.resolution, Resolution::Shed(ShedReason::RateLimited));
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.metrics.counter_value("rejected_rate_limited"), 1);
        // the shed consumed an id and left exactly one audit entry for it
        assert!(o.audit.contains(out.request_id));
        assert_eq!(o.audit.entries().iter().filter(|e| e.request_id == out.request_id).count(), 1);
        let shed: u64 = o
            .metrics
            .counter_children("requests_resolved")
            .into_iter()
            .filter(|(labels, _)| labels[0] == "shed" && labels[1] == "rate_limited")
            .map(|(_, v)| v)
            .sum();
        assert_eq!(shed, 1);
        first.wait().expect("admitted request still serves");
    }

    #[test]
    fn ledger_tracks_cloud_spend() {
        let o = sim_orchestrator();
        let s = o.open_session("carol");
        // saturate local → burstable goes to cloud and pays
        o.saturate_bounded_islands(0.99);
        let out = submit(&o, s, "what is the capital of france", PriorityTier::Burstable).unwrap();
        assert!(out.cost > 0.0);
        assert!(o.ledger.spent("carol") > 0.0);
    }

    #[test]
    fn audit_log_records_every_decision() {
        let o = sim_orchestrator();
        let s = o.open_session("auditor");
        submit(&o, s, "hello world", PriorityTier::Secondary).unwrap();
        submit(&o, s, "patient john doe ssn 123-45-6789", PriorityTier::Primary).unwrap();
        assert_eq!(o.audit.len(), 2);
        // compliance scan over the trail: no entry with s_r>=0.9 ran below P=0.9
        assert!(o.audit.violations(0.9, 0.9).is_empty());
        // rejections are audited too
        o.retain_islands(|i| i.privacy < 0.9);
        let out = submit(&o, s, "patient jane smith mrn 12345", PriorityTier::Primary).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }));
        assert_eq!(o.audit.len(), 3);
        assert!(o.audit.entries().last().unwrap().reject_reason.is_some());
    }

    #[test]
    fn metrics_populated() {
        let o = sim_orchestrator();
        let s = o.open_session("dave");
        submit(&o, s, "hello world", PriorityTier::Secondary).unwrap();
        assert_eq!(o.metrics.counter_value("requests_served"), 1);
        assert!(o.metrics.histogram("latency_ms").unwrap().count() == 1);
    }

    #[test]
    fn resolutions_drive_labeled_counters_and_analytics() {
        let o = sim_orchestrator();
        let s = o.open_session("observer");
        let out = submit(&o, s, "hello world", PriorityTier::Secondary).unwrap();
        assert_eq!(out.resolution, Resolution::Served);
        // typed resolution, audit reason and labeled counter agree
        assert_eq!(o.audit.entries()[0].reason, Resolution::Served);
        let served: u64 = o
            .metrics
            .counter_children("requests_resolved")
            .into_iter()
            .filter(|(labels, _)| labels[0] == "served")
            .map(|(_, v)| v)
            .sum();
        assert_eq!(served, 1);
        // one analytics event per resolved id, with routing evidence
        let events = o.analytics.snapshot();
        assert_eq!(events.len(), 1);
        let ev = &events[0];
        assert_eq!(ev.outcome, "served");
        assert_eq!(ev.reason, "ok");
        assert!(ev.island.is_some());
        assert!(ev.tier.is_some());
        assert!(ev.routed_ms.is_finite());
        assert!(ev.enqueued_ms.is_nan(), "blocking path never queues");
        // per-island labeled series recorded under the serving island
        assert_eq!(o.metrics.counter_value("served_by_island"), 1);
        assert_eq!(o.metrics.histogram("island_latency_ms").unwrap().count(), 1);
        let labels = &o.metrics.histogram_children("island_latency_ms")[0].0;
        assert_eq!(labels.len(), 3, "island/tier/privacy labels: {labels:?}");
        assert!(labels[0].starts_with("island-"), "{labels:?}");
    }

    #[test]
    fn concurrent_submit_through_arc() {
        use std::sync::Arc;
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 5);
        let o = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 5));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let o = Arc::clone(&o);
                std::thread::spawn(move || {
                    let s = o.open_session(&format!("user-{t}"));
                    let mut ids = Vec::new();
                    for _ in 0..25 {
                        let out = submit(&o, s, "hello world", PriorityTier::Secondary).unwrap();
                        ids.push(out.request_id);
                        o.advance(50.0);
                    }
                    ids
                })
            })
            .collect();
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100, "request ids must be unique across threads");
        assert_eq!(o.audit.len(), 100);
    }

    #[test]
    fn churn_helpers_update_fleet_and_liveness_together() {
        let o = sim_orchestrator();
        assert!(o.crash_island(IslandId(1)));
        assert!(!o.lighthouse.is_online(IslandId(1)));
        assert!(!o.island_snapshot(IslandId(1)).unwrap().online);
        assert!(o.revive_island(IslandId(1)));
        assert!(o.lighthouse.is_online(IslandId(1)));
        let left = o.leave_island(IslandId(2)).expect("island 2 leaves");
        assert!(o.island_snapshot(IslandId(2)).is_none());
        assert!(!o.lighthouse.is_online(IslandId(2)));
        assert!(o.join_island(left));
        assert!(o.island_snapshot(IslandId(2)).is_some());
        assert!(o.lighthouse.is_online(IslandId(2)));
        assert!(!o.crash_island(IslandId(99)), "unknown island");
        assert_eq!(o.metrics.counter_value("island_crashes"), 1);
        assert_eq!(o.metrics.counter_value("island_joins"), 1);
    }

    #[test]
    fn announced_crash_is_never_routed() {
        let o = sim_orchestrator();
        let s = o.open_session("erin");
        o.crash_island(IslandId(0));
        for _ in 0..20 {
            let out = submit(&o, s, "hello world", PriorityTier::Secondary).unwrap();
            assert_ne!(out.decision.target(), Some(IslandId(0)), "routed to a crashed island");
            o.advance(100.0);
        }
        assert!(!o.audit.entries().iter().any(|e| e.island == Some(IslandId(0))));
        // after revival it is a candidate again
        o.revive_island(IslandId(0));
        assert!(o.lighthouse.is_online(IslandId(0)));
    }

    #[test]
    fn silent_crash_fails_over_to_surviving_island_and_audits() {
        let mut cfg = Config::default();
        cfg.failover_retry_budget = 8;
        cfg.rate_limit_rps = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 9);
        let o = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 9);
        // all privacy-eligible islands are saturated (capacity 0, so routing
        // lands in the failsafe) and all but one die *silently* — the
        // liveness view has no idea until executions start failing
        let personal: Vec<IslandId> = o
            .island_ids()
            .into_iter()
            .filter(|id| o.island_snapshot(*id).unwrap().spec.privacy >= 0.95)
            .collect();
        assert!(personal.len() >= 2, "preset needs >= 2 personal islands");
        let survivor = personal[0];
        for id in &personal {
            o.set_island_load(*id, 1.0);
            if *id != survivor {
                o.silent_crash_island(*id);
            }
        }
        let s = o.open_session("alice");
        let out = submit(&o, s, "patient john doe ssn 123-45-6789", PriorityTier::Primary).unwrap();
        assert_eq!(out.decision.target(), Some(survivor), "{:?}", out.decision);
        // exactly one audit entry carrying the failover trail
        assert_eq!(o.audit.len(), 1);
        let entry = o.audit.entries().pop().unwrap();
        assert_eq!(entry.island, Some(survivor));
        assert!(entry.failovers >= 1, "expected failovers recorded, got {entry:?}");
        assert!(o.metrics.counter_value("failovers") >= 1);
        assert_eq!(o.metrics.counter_value("failover_successes"), 1);
        // the dead islands were marked offline in the liveness view
        assert!(personal.iter().filter(|id| **id != survivor).any(|id| !o.lighthouse.is_online(*id)));
    }

    #[test]
    fn exhausted_retries_reject_with_single_audit_entry() {
        let mut cfg = Config::default();
        cfg.failover_retry_budget = 1;
        cfg.rate_limit_rps = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 10);
        let o = Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 10);
        // every privacy-eligible island dies silently
        for id in o.island_ids() {
            if o.island_snapshot(id).unwrap().spec.privacy >= 0.95 {
                o.silent_crash_island(id);
            }
        }
        let s = o.open_session("bob");
        let out = submit(&o, s, "patient jane roe ssn 987-65-4321", PriorityTier::Primary).unwrap();
        assert!(matches!(out.decision, Decision::Reject { .. }), "{:?}", out.decision);
        assert_eq!(out.cost, 0.0);
        assert_eq!(o.ledger.total(), 0.0, "no charge for a request that never ran");
        assert_eq!(o.audit.len(), 1, "exactly one audit entry for the exhausted request");
        let entry = o.audit.entries().pop().unwrap();
        assert!(entry.island.is_none());
        let reason = entry.reject_reason.as_deref().unwrap_or("");
        assert!(reason.contains("retry budget") || reason.contains("failover"), "{reason}");
        assert!(entry.failovers >= 1, "{entry:?}");
        assert_eq!(o.metrics.counter_value("rejected_failover_exhausted"), 1);
    }

    #[test]
    fn submit_many_matches_submit_semantics_and_coalesces() {
        let o = sim_orchestrator();
        let s = o.open_session("batcher");
        let items = vec![
            SubmitRequest::new("hello world").priority(PriorityTier::Secondary),
            SubmitRequest::new("patient john doe ssn 123-45-6789").priority(PriorityTier::Primary),
            SubmitRequest::new("explain how rust ownership works").priority(PriorityTier::Secondary),
        ];
        let results = o.submit_many_requests(s, items);
        assert_eq!(results.len(), 3);
        for r in &results {
            let out = r.as_ref().unwrap();
            assert!(out.decision.target().is_some());
        }
        // every admitted item is audited exactly once
        assert_eq!(o.audit.len(), 3);
        // the PHI item must have stayed on a P=1.0 island
        let islands = preset_personal_group();
        let phi_target = results[1].as_ref().unwrap().decision.target().unwrap();
        assert_eq!(islands.iter().find(|i| i.id == phi_target).unwrap().privacy, 1.0);
        // grouping metric recorded
        assert!(o.metrics.histogram("batch_group_size").unwrap().count() >= 1);
    }

    #[test]
    fn enqueue_ticket_end_to_end() {
        let o = Arc::new(sim_orchestrator());
        assert_eq!(Arc::clone(&o).start_queue(), Config::default().serve_workers);
        assert_eq!(Arc::clone(&o).start_queue(), 0, "worker pool starts once");
        let s = o.open_session("queueing");
        let t1 = o.enqueue(s, SubmitRequest::new("hello world"));
        let t2 = o.enqueue(s, SubmitRequest::new("patient john doe ssn 123-45-6789").priority(PriorityTier::Primary));
        let out1 = t1.wait().unwrap();
        let out2 = t2.wait().unwrap();
        assert!(out1.decision.target().is_some());
        // the PHI request kept the privacy constraint through the queue path
        let islands = preset_personal_group();
        let phi = islands.iter().find(|i| Some(i.id) == out2.decision.target()).unwrap();
        assert_eq!(phi.privacy, 1.0);
        assert_ne!(out1.request_id, out2.request_id);
        // terminal reads are repeatable
        assert_eq!(t1.try_poll().unwrap().unwrap().request_id, out1.request_id);
        assert_eq!(o.metrics.counter_value("enqueued"), 2);
        assert_eq!(o.audit.len(), 2);
        assert_eq!(o.metrics.counter_value("ticket_double_resolved"), 0);
    }

    #[test]
    fn enqueue_unknown_session_resolves_err_without_consuming_an_id() {
        let o = Arc::new(sim_orchestrator());
        let ticket = o.enqueue(999, SubmitRequest::new("hello"));
        assert!(ticket.is_resolved(), "admission failures resolve immediately");
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("unknown session"), "{err}");
        assert_eq!(o.audit.len(), 0, "refused submissions consume no id and leave no entry");
        assert_eq!(o.metrics.counter_value("enqueued"), 0);
    }

    #[test]
    fn queue_full_sheds_fail_closed_with_one_audit_entry_each() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        cfg.queue_capacity = 4;
        cfg.serve_workers = 1;
        let fleet = Fleet::new(preset_personal_group(), 12);
        let o = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 12));
        let s = o.open_session("flooder");
        // workers not started yet: the 5th..10th enqueues find the queue full
        let tickets: Vec<Ticket> = (0..10).map(|_| o.enqueue(s, SubmitRequest::new("hello world"))).collect();
        assert_eq!(o.metrics.counter_value("rejected_queue_full"), 6);
        assert_eq!(o.queue_depth(), 4);
        let shed_now: usize = tickets.iter().filter(|t| t.is_resolved()).count();
        assert_eq!(shed_now, 6, "sheds resolve immediately");
        Arc::clone(&o).start_queue();
        let outcomes: Vec<Outcome> = tickets.iter().map(|t| t.wait().unwrap()).collect();
        let sheds: Vec<&Outcome> = outcomes.iter().filter(|out| out.decision.target().is_none()).collect();
        assert_eq!(sheds.len(), 6);
        for shed in &sheds {
            assert_eq!(shed.cost, 0.0);
            match &shed.decision {
                Decision::Reject { reason } => assert!(reason.contains("queue full"), "{reason}"),
                other => panic!("expected shed reject, got {other:?}"),
            }
        }
        // exactly one audit entry per request — served AND shed
        assert_eq!(o.audit.len(), 10);
        assert_eq!(o.audit.sheds().len(), 6);
        assert_eq!(o.metrics.counter_value("ticket_double_resolved"), 0);
    }

    #[test]
    fn expired_deadline_is_shed_at_drain_time() {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 13);
        let o = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 13));
        let s = o.open_session("latecomer");
        let tickets: Vec<Ticket> =
            (0..3).map(|_| o.enqueue(s, SubmitRequest::new("hello world").deadline_ms(50.0))).collect();
        // virtual time races past every deadline while the requests queue
        o.advance(10_000.0);
        Arc::clone(&o).start_queue();
        for t in &tickets {
            let out = t.wait().unwrap();
            match &out.decision {
                Decision::Reject { reason } => assert!(reason.contains("deadline expired"), "{reason}"),
                other => panic!("expected deadline shed, got {other:?}"),
            }
            assert_eq!(out.cost, 0.0);
        }
        assert_eq!(o.metrics.counter_value("shed_deadline_expired"), 3);
        assert_eq!(o.audit.sheds().len(), 3);
        assert_eq!(o.audit.len(), 3);
    }

    #[test]
    fn set_batch_policy_is_live_through_arc() {
        let o = Arc::new(sim_orchestrator());
        let wait = std::time::Duration::from_millis(1);
        o.set_batch_policy(BatchPolicy { max_batch: 2, max_wait: wait, ..BatchPolicy::default() });
        assert_eq!(o.batch_policy().max_batch, 2);
        let s = o.open_session("retuner");
        let items: Vec<SubmitRequest> =
            (0..5).map(|_| SubmitRequest::new("hello world").priority(PriorityTier::Secondary)).collect();
        let results = o.submit_many_requests(s, items);
        assert!(results.iter().all(|r| r.is_ok()));
        // no coalesced group may exceed the retuned cap
        let h = o.metrics.histogram("batch_group_size").unwrap();
        assert!(h.max() <= 2.0, "group of {} exceeded max_batch=2", h.max());
    }

    #[test]
    fn invalid_budgets_shed_at_the_front_door() {
        let o = Arc::new(sim_orchestrator());
        let s = o.open_session("validator");
        // queue path: rejected before occupying a queue slot
        let t = o.enqueue(s, SubmitRequest::new("hello").max_new_tokens(0));
        assert!(t.is_resolved(), "invalid requests resolve immediately");
        let out = t.wait().unwrap();
        match &out.decision {
            Decision::Reject { reason } => assert!(reason.contains("max_new_tokens"), "{reason}"),
            other => panic!("expected invalid-request shed, got {other:?}"),
        }
        assert!(!out.cancelled());
        assert_eq!(out.resolution, Resolution::Shed(ShedReason::InvalidRequest));
        assert_eq!(o.queue_depth(), 0);
        // blocking path enforces the same contract
        let out2 = o.submit_request(s, SubmitRequest::new("hello").deadline_ms(0.0)).unwrap();
        match &out2.decision {
            Decision::Reject { reason } => assert!(reason.contains("deadline_ms"), "{reason}"),
            other => panic!("expected invalid-request shed, got {other:?}"),
        }
        assert_eq!(o.metrics.counter_value("rejected_invalid_request"), 2);
        // both consumed ids and both are on the audit trail as sheds
        assert_eq!(o.audit.len(), 2);
        assert_eq!(o.audit.sheds().len(), 2);
    }

    #[test]
    fn cancel_while_queued_resolves_without_routing() {
        let o = Arc::new(sim_orchestrator());
        let s = o.open_session("canceller");
        // workers not started: the request parks, the cancel lands first
        let t = o.enqueue(s, SubmitRequest::new("hello world"));
        t.cancel();
        assert!(!t.is_resolved(), "cancel is cooperative — resolved at drain time");
        Arc::clone(&o).start_queue();
        let out = t.wait().unwrap();
        assert!(out.cancelled());
        assert_eq!(out.resolution, Resolution::Cancelled(CancelPoint::WhileQueued));
        assert_eq!(out.cost, 0.0);
        assert_eq!(out.tokens_generated, 0);
        assert_eq!(o.metrics.counter_value("cancelled_while_queued"), 1);
        assert_eq!(o.audit.cancellations().len(), 1);
        assert!(o.audit.sheds().is_empty(), "a cancel is not load shedding");
        assert_eq!(o.ledger.total(), 0.0);
    }

    #[test]
    fn deadline_expiring_mid_decode_cancels_stream_and_charges_partial() {
        use crate::server::ticket::TokenEvent;
        let o = Arc::new(sim_orchestrator());
        let s = o.open_session("doomed");
        // 512 tokens cannot decode inside 300 virtual ms on any island
        // (fastest per-token rate is 1.2 ms), but the deadline filter is
        // soft, so the request routes and starts decoding — the step loop
        // must stop it at a chunk boundary once the cursor passes 300 ms
        let t = o.enqueue(s, SubmitRequest::new("hello world").deadline_ms(300.0).max_new_tokens(512));
        Arc::clone(&o).start_queue();
        let events: Vec<TokenEvent> = t.stream().collect();
        assert!(matches!(events.first(), Some(TokenEvent::First { .. })), "{events:?}");
        assert!(matches!(events.last(), Some(TokenEvent::Cancelled { .. })), "{events:?}");
        let out = t.wait().unwrap();
        assert!(out.cancelled());
        assert_eq!(out.resolution, Resolution::Cancelled(CancelPoint::DeadlineMidDecode));
        assert!(out.decision.target().is_some(), "cancelled mid-decode, not rejected: {:?}", out.decision);
        assert!(out.tokens_generated > 0, "prefill beat the deadline, some tokens decoded");
        assert!(out.tokens_generated < 512, "decode must stop early, got {}", out.tokens_generated);
        assert_eq!(o.metrics.counter_value("cancelled_deadline_mid_decode"), 1);
        assert_eq!(o.audit.len(), 1);
        assert_eq!(o.audit.cancellations().len(), 1);
        let entry = &o.audit.cancellations()[0];
        assert!(entry.island.is_some(), "the audit entry keeps the island it ran on");
    }

    #[test]
    fn sensitivity_floor_tightens_routing_from_the_server_surface() {
        let o = sim_orchestrator();
        let s = o.open_session("cautious");
        // a benign prompt, declared sensitive by the caller: routing must
        // honor the floor even though MIST scores it low
        let out = o.submit_request(s, SubmitRequest::new("hello world").sensitivity(0.95)).unwrap();
        assert!(out.s_r >= 0.95);
        let islands = preset_personal_group();
        let target = islands.iter().find(|i| Some(i.id) == out.decision.target()).unwrap();
        assert!(target.privacy >= 0.95, "landed on {} (P={})", target.name, target.privacy);
    }
}
