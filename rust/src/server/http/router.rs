//! Per-connection request loop and route dispatch. One thread per accepted
//! connection runs [`serve_connection`]: parse a request, authenticate,
//! dispatch, record the per-route metrics, repeat until the client hangs
//! up, a write fails, or the server drains.
//!
//! Fail-closed posture at the boundary (§XIV): unauthenticated requests
//! are refused before any body is interpreted and consume no request id;
//! authenticated-but-malformed submits consume an id and leave exactly one
//! audit entry via [`Orchestrator::reject_at_front_door`]; rate-limited
//! submits answer 429 and bump the shared `rejected_rate_limited` cell.
//! Ticket ids are scoped to the submitting key's session: poll, stream and
//! cancel look the id up under the authenticated session, so a foreign id
//! answers 404 exactly like an unknown one — no cross-tenant reads,
//! cancels, or id-existence oracle. Trace ids on `GET /v1/traces/:id` are
//! scoped the same way, against the user recorded on the kept trace.
//!
//! Tracing at the boundary: the submit handler starts the request's trace
//! before the body is interpreted, adopting a valid inbound W3C
//! `traceparent` (malformed values fail open to a fresh root — a bad
//! header never rejects a request) and echoing the root's `traceparent`
//! on the response so external callers can correlate.
//!
//! [`Orchestrator::reject_at_front_door`]: crate::server::Orchestrator::reject_at_front_door

use std::io::{self, BufReader};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use super::conn::{self, HttpRequest};
use super::wire;
use super::{KeyEntry, Shared};
use crate::config::json::Json;
use crate::telemetry::traceout;
use crate::telemetry::{parse_traceparent, TraceId, TraceSink};

use crate::util::sync::LockExt;

/// Read timeout used to poll the drain flag on idle keep-alive connections.
const READ_POLL: Duration = Duration::from_millis(100);
/// Safety cap on a single blocked write (a stuck client must not pin a
/// handler thread through drain forever).
const WRITE_CAP: Duration = Duration::from_secs(10);

/// Refuse a connection over the concurrency cap without spawning a handler.
pub(crate) fn refuse_overloaded(mut stream: TcpStream) -> io::Result<()> {
    conn::write_response(&mut stream, 503, "application/json", &[], &wire::error_json("server overloaded"), true)
}

pub(crate) fn serve_connection(shared: &Shared, stream: TcpStream) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() || stream.set_write_timeout(Some(WRITE_CAP)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let draining = || shared.draining.load(Ordering::SeqCst);
    loop {
        let req = match conn::read_request(&mut reader, &draining) {
            Ok(Some(req)) => req,
            // clean end: client EOF between requests, or idle at drain
            Ok(None) => return,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // unroutable framing: answer 400 — or 413 when the only
                // problem is the declared body size, so clients can tell
                // "shrink the request" from "malformed request" — and
                // close. No request id is consumed — nothing was
                // authenticated, so there is nothing to audit against (the
                // JSON-level 400s are per-route).
                let (status, msg) = if conn::is_payload_too_large(&e) {
                    (413, "payload too large")
                } else {
                    (400, "bad request")
                };
                let _ = conn::write_response(
                    &mut writer,
                    status,
                    "application/json",
                    &[],
                    &wire::error_json(msg),
                    true,
                );
                shared.http.observe("other", status, 0.0);
                return;
            }
            Err(_) => return,
        };
        let t0 = Instant::now();
        // in-flight requests finish during drain, but the connection closes
        // after the response so the handler thread can be joined
        let close = draining();
        match dispatch(shared, &req, &mut writer, close) {
            Ok((route, status, end)) => {
                shared.http.observe(route, status, t0.elapsed().as_secs_f64() * 1e3);
                if end || close {
                    return;
                }
            }
            Err(_) => return, // write failed: client gone
        }
    }
}

/// Route one request. Returns `(route label, status, close-after)`; `Err`
/// only for write failures (the connection is then abandoned).
fn dispatch(shared: &Shared, req: &HttpRequest, w: &mut TcpStream, close: bool) -> io::Result<(&'static str, u16, bool)> {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match segs.as_slice() {
        ["metrics"] => {
            if req.method != "GET" {
                return method_not_allowed(w, "metrics", "GET", close);
            }
            let text = shared.orch.metrics.render_prometheus();
            conn::write_response(w, 200, "text/plain; version=0.0.4", &[], text.as_bytes(), close)?;
            Ok(("metrics", 200, close))
        }
        ["healthz"] => {
            if req.method != "GET" {
                return method_not_allowed(w, "healthz", "GET", close);
            }
            handle_healthz(shared, w, close)
        }
        ["v1", "submit"] => {
            if req.method != "POST" {
                return method_not_allowed(w, "submit", "POST", close);
            }
            handle_submit(shared, req, w, close)
        }
        ["v1", "tickets", id] => {
            if req.method != "GET" {
                return method_not_allowed(w, "ticket", "GET", close);
            }
            handle_poll(shared, req, w, id, close)
        }
        ["v1", "tickets", id, "cancel"] => {
            if req.method != "POST" {
                return method_not_allowed(w, "cancel", "POST", close);
            }
            handle_cancel(shared, req, w, id, close)
        }
        ["v1", "stream", id] => {
            if req.method != "GET" {
                return method_not_allowed(w, "stream", "GET", close);
            }
            handle_stream(shared, req, w, id, close)
        }
        ["v1", "traces", id] => {
            if req.method != "GET" {
                return method_not_allowed(w, "trace", "GET", close);
            }
            handle_trace(shared, req, w, id, close)
        }
        _ => {
            let status = write_json(w, 404, &Json::obj(vec![("error", Json::str("no such route"))]), close)?;
            Ok(("other", status, close))
        }
    }
}

fn write_json(w: &mut TcpStream, status: u16, body: &Json, close: bool) -> io::Result<u16> {
    write_json_with(w, status, &[], body, close)
}

fn write_json_with(
    w: &mut TcpStream,
    status: u16,
    headers: &[(&str, &str)],
    body: &Json,
    close: bool,
) -> io::Result<u16> {
    conn::write_response(w, status, "application/json", headers, body.to_string().as_bytes(), close)?;
    Ok(status)
}

fn method_not_allowed(
    w: &mut TcpStream,
    route: &'static str,
    allow: &'static str,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    conn::write_response(
        w,
        405,
        "application/json",
        &[("Allow", allow)],
        &wire::error_json("method not allowed"),
        close,
    )?;
    Ok((route, 405, close))
}

/// Bearer-token lookup. `None` means the caller gets a 401 — before any
/// body interpretation, consuming no request id and writing no audit entry
/// (there is no authenticated principal to attribute one to).
fn authenticate<'a>(shared: &'a Shared, req: &HttpRequest) -> Option<&'a KeyEntry> {
    let token = req.header("authorization")?.strip_prefix("Bearer ")?;
    shared.keys.get(token)
}

fn unauthorized(w: &mut TcpStream, close: bool) -> io::Result<u16> {
    conn::write_response(
        w,
        401,
        "application/json",
        &[("WWW-Authenticate", "Bearer")],
        &wire::error_json("missing or unknown API key"),
        close,
    )?;
    Ok(401)
}

fn handle_submit(
    shared: &Shared,
    req: &HttpRequest,
    w: &mut TcpStream,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    const ROUTE: &str = "submit";
    let Some(entry) = authenticate(shared, req) else {
        return Ok((ROUTE, unauthorized(w, close)?, close));
    };
    // per-key token bucket at the front door (wall-clock ms); the
    // orchestrator's own limiter still applies behind it
    if !shared.limiter.lock_clean().admit(&entry.user, shared.wall_ms()) {
        shared.http.rejected_rate_limited.inc();
        let body = Json::obj(vec![("error", Json::str("rate limited")), ("reason", Json::str("rate_limited"))]);
        return Ok((ROUTE, write_json(w, 429, &body, close)?, close));
    }
    // Start the request's trace before the body is interpreted. A valid
    // inbound traceparent is adopted (the remote span parents our root); a
    // malformed one fails open to a fresh root — never a rejection.
    let remote = req.header("traceparent").and_then(parse_traceparent);
    let trace = TraceSink::start(&shared.orch.traces, shared.orch.now_ms(), remote);
    trace.set_user(&entry.user);
    let tp = trace.traceparent();
    let echo: Vec<(&str, &str)> = tp.as_deref().map(|v| ("traceparent", v)).into_iter().collect();
    let parsed = wire::parse_submit(&req.body).and_then(|sr| match sr.validate() {
        Ok(()) => Ok(sr),
        Err(why) => Err(why),
    });
    let sr = match parsed {
        Ok(sr) => sr,
        Err(why) => {
            // fail-closed 400: consumes a request id and leaves exactly one
            // audit entry, like any in-process invalid submit
            let out = shared.orch.reject_at_front_door(&entry.user, &why, &trace);
            let body =
                Json::obj(vec![("error", Json::str(&why)), ("request_id", Json::num(out.request_id as f64))]);
            return Ok((ROUTE, write_json_with(w, 400, &echo, &body, close)?, close));
        }
    };
    let sr = sr.trace(trace.clone());
    let ticket = shared.orch.enqueue(entry.session_id, sr);
    match shared.registry.insert(ticket.clone(), entry.session_id, trace.clone()) {
        Some(id) => {
            let mut fields = vec![("ticket", Json::num(id as f64))];
            if let Some(hex) = trace.trace_hex() {
                fields.push(("trace_id", Json::str(&hex)));
            }
            Ok((ROUTE, write_json_with(w, 200, &echo, &Json::obj(fields), close)?, close))
        }
        None => {
            // registry full of live tickets. The request is already admitted
            // and will resolve + audit server-side (no ticket lost); cancel
            // cooperatively so the unreachable handle stops burning decode.
            ticket.cancel();
            let body = Json::obj(vec![("error", Json::str("ticket registry full"))]);
            Ok((ROUTE, write_json_with(w, 503, &echo, &body, close)?, close))
        }
    }
}

fn handle_poll(
    shared: &Shared,
    req: &HttpRequest,
    w: &mut TcpStream,
    id: &str,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    const ROUTE: &str = "ticket";
    let Some(entry) = authenticate(shared, req) else {
        return Ok((ROUTE, unauthorized(w, close)?, close));
    };
    let Some(ticket) = id.parse::<u64>().ok().and_then(|id| shared.registry.get(id, entry.session_id)) else {
        return Ok((ROUTE, write_json(w, 404, &Json::obj(vec![("error", Json::str("unknown ticket"))]), close)?, close));
    };
    let body = match ticket.try_poll() {
        None => Json::obj(vec![("done", Json::Bool(false))]),
        Some(Ok(out)) => Json::obj(vec![("done", Json::Bool(true)), ("outcome", wire::outcome_json(&out))]),
        Some(Err(e)) => Json::obj(vec![("done", Json::Bool(true)), ("error", Json::str(&e.to_string()))]),
    };
    Ok((ROUTE, write_json(w, 200, &body, close)?, close))
}

fn handle_cancel(
    shared: &Shared,
    req: &HttpRequest,
    w: &mut TcpStream,
    id: &str,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    const ROUTE: &str = "cancel";
    let Some(entry) = authenticate(shared, req) else {
        return Ok((ROUTE, unauthorized(w, close)?, close));
    };
    let Some(ticket) = id.parse::<u64>().ok().and_then(|id| shared.registry.get(id, entry.session_id)) else {
        return Ok((ROUTE, write_json(w, 404, &Json::obj(vec![("error", Json::str("unknown ticket"))]), close)?, close));
    };
    ticket.cancel();
    Ok((ROUTE, write_json(w, 200, &Json::obj(vec![("cancelled", Json::Bool(true))]), close)?, close))
}

/// Relay the ticket's token events as SSE over a chunked body. The stream
/// keeps the connection reusable (terminating chunk) unless a write fails —
/// a mid-stream client disconnect — in which case the ticket is cancelled
/// cooperatively so the abandoned request stops burning its decode slot.
fn handle_stream(
    shared: &Shared,
    req: &HttpRequest,
    w: &mut TcpStream,
    id: &str,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    const ROUTE: &str = "stream";
    let Some(entry) = authenticate(shared, req) else {
        return Ok((ROUTE, unauthorized(w, close)?, close));
    };
    let wire_id = id.parse::<u64>().ok();
    let Some(ticket) = wire_id.and_then(|id| shared.registry.get(id, entry.session_id)) else {
        return Ok((ROUTE, write_json(w, 404, &Json::obj(vec![("error", Json::str("unknown ticket"))]), close)?, close));
    };
    let trace = wire_id.and_then(|id| shared.registry.trace_of(id, entry.session_id)).unwrap_or_default();
    conn::write_stream_head(w)?;
    let relay_start = shared.orch.now_ms();
    let mut relayed = 0u32;
    let mut disconnected = false;
    for event in ticket.stream() {
        let frame = wire::sse_event(&event);
        if conn::write_chunk(w, frame.as_bytes()).is_err() {
            ticket.cancel();
            disconnected = true;
            break;
        }
        relayed += 1;
    }
    // late span: the request's terminal usually fires mid-relay, and kept
    // traces accept spans recorded after the root closed
    trace.add_span(
        "sse_relay",
        relay_start,
        shared.orch.now_ms(),
        vec![("events", Json::num(relayed as f64)), ("disconnected", Json::Bool(disconnected))],
    );
    if disconnected {
        return Ok((ROUTE, 200, true));
    }
    if conn::write_last_chunk(w).is_err() {
        return Ok((ROUTE, 200, true));
    }
    Ok((ROUTE, 200, close))
}

/// `GET /v1/traces/:id` — one kept trace as JSON. Scoped to the caller's
/// user: a foreign trace id answers 404 exactly like an unknown,
/// sampled-out, or evicted one, so the endpoint is not an existence
/// oracle across tenants.
fn handle_trace(
    shared: &Shared,
    req: &HttpRequest,
    w: &mut TcpStream,
    id: &str,
    close: bool,
) -> io::Result<(&'static str, u16, bool)> {
    const ROUTE: &str = "trace";
    let Some(entry) = authenticate(shared, req) else {
        return Ok((ROUTE, unauthorized(w, close)?, close));
    };
    let found = TraceId::from_hex(id)
        .and_then(|tid| shared.orch.traces.get(tid))
        .filter(|t| t.user == entry.user);
    let Some(trace) = found else {
        return Ok((ROUTE, write_json(w, 404, &Json::obj(vec![("error", Json::str("unknown trace"))]), close)?, close));
    };
    Ok((ROUTE, write_json(w, 200, &traceout::trace_json(&trace), close)?, close))
}

fn handle_healthz(shared: &Shared, w: &mut TcpStream, close: bool) -> io::Result<(&'static str, u16, bool)> {
    let lh = &shared.orch.lighthouse;
    let alive = lh.is_alive();
    let islands = lh.islands();
    let online = islands.iter().filter(|i| lh.is_online(i.id)).count();
    let degraded = islands.iter().filter(|i| lh.is_degraded(i.id)).count();
    let body = Json::obj(vec![
        ("status", Json::str(if alive { "ok" } else { "down" })),
        ("lighthouse_alive", Json::Bool(alive)),
        ("islands", Json::num(islands.len() as f64)),
        ("islands_online", Json::num(online as f64)),
        ("islands_degraded", Json::num(degraded as f64)),
        ("queue_depth", Json::num(shared.orch.queue_depth() as f64)),
        ("draining", Json::Bool(shared.draining.load(Ordering::SeqCst))),
    ]);
    let status = if alive { 200 } else { 503 };
    Ok(("healthz", write_json(w, status, &body, close)?, close))
}
