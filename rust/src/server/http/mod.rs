//! HTTP/1.1 network serving surface — the socket boundary in front of the
//! orchestrator's non-blocking request lifecycle. Dependency-free: a std
//! [`TcpListener`], a hand-rolled HTTP/1.1 parser (`conn`) and wire-JSON
//! codecs (`wire`), same offline-vendoring discipline as the rest of the
//! crate.
//!
//! Endpoints:
//!
//! | Route | Meaning |
//! |---|---|
//! | `POST /v1/submit` | JSON body → [`SubmitRequest`] → `enqueue`; returns a ticket id |
//! | `GET /v1/tickets/:id` | non-blocking poll → typed resolution JSON (404 once reaped) |
//! | `GET /v1/stream/:id` | chunked SSE relay of [`TokenEvent`]s; disconnect cancels |
//! | `POST /v1/tickets/:id/cancel` | cooperative cancel |
//! | `GET /v1/traces/:id` | one kept trace's span tree (owner-scoped) |
//! | `GET /metrics` | Prometheus exposition (unauthenticated scrape) |
//! | `GET /healthz` | Lighthouse liveness summary (unauthenticated probe) |
//!
//! The submit handler starts each request's trace (adopting a valid W3C
//! `traceparent` header, failing open on malformed values) and echoes the
//! root's `traceparent` on the response.
//!
//! The trust anchor is the authenticated request boundary: API keys
//! (`Authorization: Bearer`) map to orchestrator sessions, ticket ids are
//! scoped to the session that submitted them (a foreign key's poll,
//! stream, or cancel answers 404 exactly like an unknown id), each key is
//! rate-limited by the same token-bucket implementation the orchestrator
//! uses ([`RateLimiter`]), and every refusal is observable — 401s consume
//! nothing, 429s bump `rejected_rate_limited`, malformed submits consume a
//! request id and leave exactly one audit entry.
//!
//! Shutdown is a graceful drain: new accepts are refused, idle keep-alive
//! connections close at the next read-timeout poll, in-flight requests
//! (including running SSE relays) finish, and every admitted ticket still
//! resolves server-side — the no-ticket-lost invariant holds across the
//! wire.
//!
//! [`SubmitRequest`]: crate::server::SubmitRequest
//! [`TokenEvent`]: crate::server::TokenEvent

pub mod client;
mod conn;
mod registry;
mod router;
pub(crate) mod wire;

pub use registry::TicketRegistry;

use std::collections::BTreeMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::server::{Orchestrator, RateLimiter};
use crate::telemetry::serving::HttpMetrics;

use crate::util::sync::LockExt;

/// Tunables for one [`HttpServer`]. Defaults suit an interactive `serve`;
/// tests and benches shrink the TTL / raise the rate.
pub struct HttpConfig {
    /// Per-key token-bucket rate (requests per second) at the front door.
    pub rate_per_sec: f64,
    /// Token-bucket burst capacity.
    pub burst: f64,
    /// Concurrent-connection cap; accepts over it are refused with 503.
    pub max_connections: usize,
    /// Ticket-registry capacity (unresolved tickets never evicted).
    pub ticket_capacity: usize,
    /// How long a resolved ticket stays pollable before it is reaped.
    pub ticket_ttl_ms: u64,
    /// Drive the Sim backend's virtual clock from wall time so token
    /// buckets refill and liveness ticks fire while serving real sockets.
    pub pump_sim_clock: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            rate_per_sec: 50.0,
            burst: 50.0,
            max_connections: 256,
            ticket_capacity: 4096,
            ticket_ttl_ms: 60_000,
            pump_sim_clock: true,
        }
    }
}

/// One API key's grant: the user it bills to and the session it submits on.
pub(crate) struct KeyEntry {
    pub user: String,
    pub session_id: u64,
}

/// State shared by the accept loop and every connection handler.
pub(crate) struct Shared {
    pub orch: Arc<Orchestrator>,
    pub keys: BTreeMap<String, KeyEntry>,
    pub limiter: Mutex<RateLimiter>,
    pub registry: TicketRegistry,
    pub http: HttpMetrics,
    pub draining: AtomicBool,
    pub active: AtomicUsize,
    pub max_connections: usize,
    started: Instant,
}

impl Shared {
    /// Wall-clock milliseconds since the server started — the front-door
    /// limiter's clock (the orchestrator's own limiter keeps using
    /// orchestrator time).
    pub fn wall_ms(&self) -> f64 {
        self.started.elapsed().as_secs_f64() * 1e3
    }
}

/// A running HTTP server. Dropping it (or calling [`HttpServer::shutdown`])
/// drains gracefully; the orchestrator behind it is shared and survives.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    pump: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), open one
    /// orchestrator session per API key, start the accept loop and (on Sim
    /// backends) the clock pump. The queue worker pool is started
    /// idempotently.
    pub fn start<A: ToSocketAddrs>(
        orch: Arc<Orchestrator>,
        addr: A,
        keys: &[(String, String)],
        config: HttpConfig,
    ) -> io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Arc::clone(&orch).start_queue();
        let mut key_map = BTreeMap::new();
        for (key, user) in keys {
            let session_id = orch.open_session(user);
            key_map.insert(key.clone(), KeyEntry { user: user.clone(), session_id });
        }
        let http = HttpMetrics::register(&orch.metrics);
        let registry = TicketRegistry::new(config.ticket_capacity, config.ticket_ttl_ms, http.tickets_reaped.clone());
        let shared = Arc::new(Shared {
            orch: Arc::clone(&orch),
            keys: key_map,
            limiter: Mutex::new(RateLimiter::new(config.rate_per_sec, config.burst.max(1.0))),
            registry,
            http,
            draining: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            max_connections: config.max_connections.max(1),
            started: Instant::now(),
        });
        let pump = if config.pump_sim_clock && orch.sim_backed() {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name("islandrun-http-clock".into())
                .spawn(move || {
                    // virtual time tracks wall time: token buckets refill,
                    // capacity recovers, liveness ticks fire
                    let mut last = Instant::now();
                    while !shared.draining.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(1));
                        let dt_ms = last.elapsed().as_secs_f64() * 1e3;
                        last = Instant::now();
                        shared.orch.advance(dt_ms);
                    }
                })?;
            Some(handle)
        } else {
            None
        };
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::Builder::new()
                .name("islandrun-http-accept".into())
                .spawn(move || accept_loop(listener, shared, handlers))?
        };
        Ok(HttpServer { addr, shared, accept: Some(accept), pump, handlers })
    }

    /// The bound address (resolves the port when started on `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests the registry currently tracks (test/diagnostic surface).
    pub fn tickets_registered(&self) -> usize {
        self.shared.registry.len()
    }

    /// Graceful drain: refuse new accepts, close idle connections at their
    /// next drain poll, let in-flight requests finish, join every thread.
    /// Admitted tickets keep resolving on the orchestrator, which outlives
    /// the server — no ticket is lost.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        if self.shared.draining.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the blocked accept() so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pump.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.handlers.lock_clean());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.drain();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, handlers: Arc<Mutex<Vec<JoinHandle<()>>>>) {
    for stream in listener.incoming() {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        if shared.active.load(Ordering::SeqCst) >= shared.max_connections {
            // over the cap: refuse inline, never spawn
            let _ = router::refuse_overloaded(stream);
            continue;
        }
        // gauge moves by deltas, never absolute sets: interleaved set()s
        // from the accept loop and handler threads could publish a stale
        // count; paired +1/-1 always converge to the live total
        shared.active.fetch_add(1, Ordering::SeqCst);
        shared.http.active_connections.add(1.0);
        let conn_shared = Arc::clone(&shared);
        let spawned = std::thread::Builder::new().name("islandrun-http-conn".into()).spawn(move || {
            router::serve_connection(&conn_shared, stream);
            conn_shared.active.fetch_sub(1, Ordering::SeqCst);
            conn_shared.http.active_connections.add(-1.0);
        });
        let handle = match spawned {
            Ok(handle) => handle,
            Err(_) => {
                // thread exhaustion: the closure (and the stream with it)
                // is dropped, closing the connection; undo the counters the
                // handler would have owned and keep accepting
                shared.active.fetch_sub(1, Ordering::SeqCst);
                shared.http.active_connections.add(-1.0);
                continue;
            }
        };
        let mut hs = handlers.lock_clean();
        hs.retain(|h| !h.is_finished());
        hs.push(handle);
    }
    // the listener drops here: further connects are refused by the OS
}
