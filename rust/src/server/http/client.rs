//! Minimal blocking HTTP/1.1 client over one keep-alive connection —
//! scaffolding for the loopback integration tests, the socket-true
//! loadgen ([`crate::eval::loadgen::run_open_loop_http`]) and the
//! `http_e2e` bench. Not a general-purpose client: it speaks exactly the
//! dialect the server emits (`Content-Length` or chunked responses, SSE
//! event framing) and nothing more.

use std::collections::VecDeque;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

use crate::config::json::Json;

/// One response, fully buffered (chunked bodies are de-chunked).
pub struct ClientResponse {
    pub status: u16,
    pub body: Vec<u8>,
    /// Response headers, lower-cased names, arrival order.
    pub headers: Vec<(String, String)>,
}

impl ClientResponse {
    /// Parse the body as JSON (`None` when it is not valid JSON).
    pub fn json(&self) -> Option<Json> {
        Json::parse(std::str::from_utf8(&self.body).ok()?).ok()
    }

    /// Case-insensitive single-valued header lookup (e.g. `traceparent`).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// One keep-alive connection to the server. Dropping the client closes the
/// socket — mid-stream, that is exactly the "client went away" signal the
/// server turns into a cooperative cancel.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// De-chunked SSE bytes read ahead of the current record boundary.
    pending: VecDeque<u8>,
}

fn read_line(r: &mut impl BufRead) -> io::Result<String> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed the connection"));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpClient { reader, writer: stream, pending: VecDeque::new() })
    }

    /// Issue one request and read the full response (keep-alive: the
    /// connection is reusable afterwards). `api_key` becomes a bearer
    /// token; `body` is sent as JSON.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&Json>,
    ) -> io::Result<ClientResponse> {
        self.request_traced(method, path, api_key, body, None)
    }

    /// Like [`Self::request`] but carrying an outbound W3C `traceparent`
    /// header, so callers can join the server-side trace to their own.
    pub fn request_traced(
        &mut self,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&Json>,
        traceparent: Option<&str>,
    ) -> io::Result<ClientResponse> {
        let payload = body.map(|b| b.to_string().into_bytes());
        self.send(method, path, api_key, payload.as_deref(), traceparent)?;
        let (status, chunked, len, headers) = self.read_head()?;
        let body = if chunked { self.read_chunked()? } else { self.read_sized(len)? };
        Ok(ClientResponse { status, body, headers })
    }

    /// Like [`Self::request`] but with a raw body — lets tests send
    /// deliberately malformed JSON to exercise the fail-closed 400 path.
    pub fn request_raw(
        &mut self,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&[u8]>,
    ) -> io::Result<ClientResponse> {
        self.send(method, path, api_key, body, None)?;
        let (status, chunked, len, headers) = self.read_head()?;
        let body = if chunked { self.read_chunked()? } else { self.read_sized(len)? };
        Ok(ClientResponse { status, body, headers })
    }

    /// Issue a `GET` for an SSE stream and read only the response head,
    /// leaving the chunked body on the wire. Follow with [`Self::read_event`];
    /// drop the client to abandon the stream mid-way.
    pub fn start_stream(&mut self, path: &str, api_key: Option<&str>) -> io::Result<u16> {
        self.send("GET", path, api_key, None, None)?;
        let (status, _chunked, _len, _headers) = self.read_head()?;
        Ok(status)
    }

    /// Hard-close the underlying socket (both directions) — the abrupt
    /// "client went away" a mid-stream disconnect test needs, without
    /// waiting for the value to drop.
    pub fn disconnect(&mut self) {
        let _ = self.writer.shutdown(std::net::Shutdown::Both);
    }

    /// Read the next SSE event off an open stream: `Ok(Some((event, data)))`
    /// per record, `Ok(None)` at the end of the stream (terminating chunk).
    pub fn read_event(&mut self) -> io::Result<Option<(String, String)>> {
        let (mut event, mut data) = (String::new(), String::new());
        loop {
            let Some(line) = self.read_chunked_line()? else {
                return Ok(None);
            };
            if line.is_empty() {
                if event.is_empty() && data.is_empty() {
                    continue;
                }
                return Ok(Some((event, data)));
            }
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
        }
    }

    /// Drain an entire SSE stream to its end and return every event. On a
    /// non-200 (fixed-length error body) the body is consumed so the
    /// connection stays reusable.
    pub fn stream_events(&mut self, path: &str, api_key: Option<&str>) -> io::Result<(u16, Vec<(String, String)>)> {
        self.send("GET", path, api_key, None, None)?;
        let (status, chunked, len, _headers) = self.read_head()?;
        let mut events = Vec::new();
        if !chunked {
            let _ = self.read_sized(len)?;
            return Ok((status, events));
        }
        while let Some(ev) = self.read_event()? {
            events.push(ev);
        }
        Ok((status, events))
    }

    fn send(
        &mut self,
        method: &str,
        path: &str,
        api_key: Option<&str>,
        body: Option<&[u8]>,
        traceparent: Option<&str>,
    ) -> io::Result<()> {
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: islandrun\r\n");
        if let Some(key) = api_key {
            req.push_str(&format!("Authorization: Bearer {key}\r\n"));
        }
        if let Some(tp) = traceparent {
            req.push_str(&format!("traceparent: {tp}\r\n"));
        }
        if let Some(payload) = body {
            req.push_str(&format!("Content-Type: application/json\r\nContent-Length: {}\r\n", payload.len()));
        }
        req.push_str("\r\n");
        let mut bytes = req.into_bytes();
        bytes.extend_from_slice(body.unwrap_or_default());
        self.writer.write_all(&bytes)?;
        self.writer.flush()
    }

    /// Status line + headers; returns (status, chunked?, content-length, headers).
    fn read_head(&mut self) -> io::Result<(u16, bool, usize, Vec<(String, String)>)> {
        let status_line = read_line(&mut self.reader)?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, format!("bad status line: {status_line}")))?;
        let (mut chunked, mut len) = (false, 0usize);
        let mut headers = Vec::new();
        loop {
            let line = read_line(&mut self.reader)?;
            if line.is_empty() {
                break;
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let (name, value) = (name.trim().to_ascii_lowercase(), value.trim());
            if name == "transfer-encoding" && value.eq_ignore_ascii_case("chunked") {
                chunked = true;
            } else if name == "content-length" {
                len = value
                    .parse()
                    .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad content-length"))?;
            }
            headers.push((name, value.to_string()));
        }
        Ok((status, chunked, len, headers))
    }

    fn read_sized(&mut self, len: usize) -> io::Result<Vec<u8>> {
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body)?;
        Ok(body)
    }

    /// De-chunk a whole body (terminating chunk included).
    fn read_chunked(&mut self) -> io::Result<Vec<u8>> {
        let mut body = Vec::new();
        while let Some(chunk) = self.read_chunk()? {
            body.extend_from_slice(&chunk);
        }
        Ok(body)
    }

    /// One chunk, `None` on the zero-length terminator.
    fn read_chunk(&mut self) -> io::Result<Option<Vec<u8>>> {
        let size_line = read_line(&mut self.reader)?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, format!("bad chunk size: {size_line}")))?;
        if size == 0 {
            let _ = read_line(&mut self.reader); // trailing CRLF after the last chunk
            return Ok(None);
        }
        let mut chunk = vec![0u8; size];
        self.reader.read_exact(&mut chunk)?;
        read_line(&mut self.reader)?; // chunk-terminating CRLF
        Ok(Some(chunk))
    }

    /// Buffered line reader over the chunked SSE body: chunk boundaries and
    /// SSE record boundaries are independent, so this re-frames by lines.
    fn read_chunked_line(&mut self) -> io::Result<Option<String>> {
        let mut line = Vec::new();
        loop {
            match self.read_byte()? {
                None => {
                    return if line.is_empty() {
                        Ok(None)
                    } else {
                        Err(io::Error::new(io::ErrorKind::UnexpectedEof, "stream ended mid-line"))
                    };
                }
                Some(b'\n') => {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
                }
                Some(b) => line.push(b),
            }
        }
    }

    fn read_byte(&mut self) -> io::Result<Option<u8>> {
        if self.pending.is_empty() {
            match self.read_chunk()? {
                None => return Ok(None),
                Some(chunk) => self.pending = chunk.into(),
            }
        }
        Ok(self.pending.pop_front())
    }
}
