//! Wire-JSON codecs for the HTTP surface: the submit-body decoder (strict,
//! fail-closed — unknown fields and type mismatches are rejected, per the
//! same §XIV fail-closed posture as routing) and the outcome / token-event
//! encoders shared by the poll and stream endpoints.

use crate::config::json::Json;
use crate::server::{Outcome, SubmitRequest, TokenEvent};
use crate::types::PriorityTier;

const SUBMIT_FIELDS: [&str; 8] =
    ["prompt", "priority", "deadline_ms", "sensitivity_floor", "min_jurisdiction", "model", "dataset", "max_new_tokens"];

fn parse_priority(name: &str) -> Result<PriorityTier, String> {
    match name {
        "primary" => Ok(PriorityTier::Primary),
        "secondary" => Ok(PriorityTier::Secondary),
        "burstable" => Ok(PriorityTier::Burstable),
        other => Err(format!("unknown priority {other:?} (expected primary/secondary/burstable)")),
    }
}

pub(crate) fn priority_name(p: PriorityTier) -> &'static str {
    match p {
        PriorityTier::Primary => "primary",
        PriorityTier::Secondary => "secondary",
        PriorityTier::Burstable => "burstable",
    }
}

/// Decode a `POST /v1/submit` body into a [`SubmitRequest`]. Strict: the
/// body must be a JSON object, `prompt` is required, every other field is
/// optional, and anything unrecognized or mistyped is an error the handler
/// turns into a fail-closed 400 (with one audit entry).
pub(crate) fn parse_submit(body: &[u8]) -> Result<SubmitRequest, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = Json::parse(text).map_err(|e| format!("malformed JSON: {e}"))?;
    let obj = v.as_obj().ok_or_else(|| "request body must be a JSON object".to_string())?;
    for key in obj.keys() {
        if !SUBMIT_FIELDS.contains(&key.as_str()) {
            return Err(format!("unknown field {key:?}"));
        }
    }
    let prompt = v.get("prompt").as_str().ok_or_else(|| "missing required string field \"prompt\"".to_string())?;
    let mut sr = SubmitRequest::new(prompt);
    let num = |name: &str| -> Result<Option<f64>, String> {
        match v.get(name) {
            Json::Null => Ok(None),
            j => j.as_f64().map(Some).ok_or_else(|| format!("field {name:?} must be a number")),
        }
    };
    let string = |name: &str| -> Result<Option<&str>, String> {
        match v.get(name) {
            Json::Null => Ok(None),
            j => j.as_str().map(Some).ok_or_else(|| format!("field {name:?} must be a string")),
        }
    };
    if let Some(p) = string("priority")? {
        sr = sr.priority(parse_priority(p)?);
    }
    if let Some(ms) = num("deadline_ms")? {
        sr = sr.deadline_ms(ms);
    }
    if let Some(floor) = num("sensitivity_floor")? {
        sr = sr.sensitivity(floor);
    }
    if let Some(floor) = num("min_jurisdiction")? {
        sr = sr.min_jurisdiction(floor);
    }
    if let Some(model) = string("model")? {
        sr = sr.model(model);
    }
    if let Some(dataset) = string("dataset")? {
        sr = sr.dataset(dataset);
    }
    if let Some(n) = num("max_new_tokens")? {
        if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0) {
            return Err("field \"max_new_tokens\" must be a non-negative integer".to_string());
        }
        sr = sr.max_new_tokens(n as usize);
    }
    Ok(sr)
}

/// Encode a terminal [`Outcome`] for `GET /v1/tickets/:id`.
pub(crate) fn outcome_json(out: &Outcome) -> Json {
    Json::obj(vec![
        ("request_id", Json::num(out.request_id as f64)),
        ("outcome", Json::str(out.resolution.class())),
        ("reason", Json::str(out.resolution.reason())),
        ("island", out.decision.target().map(|id| Json::str(&id.to_string())).unwrap_or(Json::Null)),
        ("s_r", Json::num(out.s_r)),
        ("latency_ms", Json::num(out.latency_ms)),
        ("cost_usd", Json::num(out.cost)),
        ("tokens_generated", Json::num(out.tokens_generated as f64)),
        ("sanitized", Json::Bool(out.sanitized)),
        ("response", Json::str(&out.response)),
    ])
}

/// Encode one [`TokenEvent`] as an SSE record (`event:` + `data:` lines).
pub(crate) fn sse_event(ev: &TokenEvent) -> String {
    let (name, data) = match ev {
        TokenEvent::First { text } => ("first", Json::obj(vec![("text", Json::str(text))])),
        TokenEvent::Token { text } => ("token", Json::obj(vec![("text", Json::str(text))])),
        TokenEvent::Done => ("done", Json::obj(vec![])),
        TokenEvent::Cancelled { reason } => ("cancelled", Json::obj(vec![("reason", Json::str(reason))])),
    };
    format!("event: {name}\ndata: {}\n\n", data.to_string())
}

/// `{"error": msg}` — the uniform error body.
pub(crate) fn error_json(msg: &str) -> Vec<u8> {
    Json::obj(vec![("error", Json::str(msg))]).to_string().into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_fully_specified_submit() {
        let body = br#"{"prompt": "hello", "priority": "primary", "deadline_ms": 1500.5,
            "sensitivity_floor": 0.8, "min_jurisdiction": 0.5, "model": "m1",
            "dataset": "d1", "max_new_tokens": 32}"#;
        let sr = parse_submit(body).unwrap();
        assert_eq!(sr.prompt, "hello");
        assert_eq!(sr.priority, PriorityTier::Primary);
        assert_eq!(sr.deadline_ms, 1500.5);
        assert_eq!(sr.sensitivity_floor, Some(0.8));
        assert_eq!(sr.min_jurisdiction, Some(0.5));
        assert_eq!(sr.model.as_deref(), Some("m1"));
        assert_eq!(sr.dataset.as_deref(), Some("d1"));
        assert_eq!(sr.max_new_tokens, 32);
    }

    #[test]
    fn prompt_alone_gets_defaults() {
        let sr = parse_submit(br#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(sr.priority, PriorityTier::Secondary);
        assert_eq!(sr.deadline_ms, 2000.0);
        assert_eq!(sr.sensitivity_floor, None);
    }

    #[test]
    fn rejects_malformed_and_mistyped_bodies_fail_closed() {
        assert!(parse_submit(b"{not json").is_err());
        assert!(parse_submit(b"[1,2]").is_err(), "non-object body");
        assert!(parse_submit(br#"{"priority": "primary"}"#).is_err(), "missing prompt");
        assert!(parse_submit(br#"{"prompt": 3}"#).is_err(), "mistyped prompt");
        assert!(parse_submit(br#"{"prompt": "x", "deadline_ms": "soon"}"#).is_err());
        assert!(parse_submit(br#"{"prompt": "x", "priority": "urgent"}"#).is_err());
        assert!(parse_submit(br#"{"prompt": "x", "max_new_tokens": 1.5}"#).is_err());
        assert!(parse_submit(br#"{"prompt": "x", "turbo": true}"#).is_err(), "unknown field");
        assert!(parse_submit(&[0xff, 0xfe]).is_err(), "not UTF-8");
    }

    #[test]
    fn sse_events_carry_name_and_data() {
        let first = sse_event(&TokenEvent::First { text: "he".into() });
        assert_eq!(first, "event: first\ndata: {\"text\":\"he\"}\n\n");
        let done = sse_event(&TokenEvent::Done);
        assert!(done.starts_with("event: done\n"));
        let cancelled = sse_event(&TokenEvent::Cancelled { reason: "cancelled after 3 tokens".into() });
        assert!(cancelled.contains("cancelled after 3 tokens"));
    }

    #[test]
    fn priority_names_round_trip() {
        for p in [PriorityTier::Primary, PriorityTier::Secondary, PriorityTier::Burstable] {
            assert_eq!(parse_priority(priority_name(p)).unwrap(), p);
        }
    }
}
