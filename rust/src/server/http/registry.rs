//! Bounded ticket registry for the HTTP surface: maps wire-visible ticket
//! ids to live [`Ticket`]s so poll/stream/cancel can find them, and reaps
//! resolved entries after a TTL so the server never leaks terminal
//! `TicketCell`s (metric: `tickets_reaped`).
//!
//! Every entry records the owning session at insert time, and
//! [`TicketRegistry::get`] requires the caller to present the same owner:
//! ids are sequential, so without the owner check any authenticated key
//! could enumerate them and read, stream, or cancel another tenant's
//! requests. A foreign id misses
//! exactly like a never-issued one (the handler answers 404 either way),
//! so the lookup is not an id-existence oracle across keys.
//!
//! Two invariants:
//! * **No ticket lost** — an *unresolved* ticket is never evicted. When
//!   every slot holds an unresolved ticket, `insert` refuses (the handler
//!   answers 503) rather than dropping a live request's handle.
//! * **Bounded memory** — resolved entries are dropped once their TTL
//!   elapses (reaped lazily on the next registry operation), and
//!   resolved-first eviction runs early when the registry hits capacity.
//!   A reaped or never-issued id answers 404, never a panic or a hang.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::server::Ticket;
use crate::telemetry::{Counter, TraceContext};

use crate::util::sync::LockExt;

struct Entry {
    ticket: Ticket,
    /// The session that submitted the ticket; lookups under any other
    /// owner miss.
    owner: u64,
    /// The request's trace handle, kept so the stream handler can attach
    /// the late `sse_relay` span after the terminal fires.
    trace: TraceContext,
    /// Stamped lazily the first time a registry operation observes the
    /// ticket resolved; the TTL counts from this observation.
    resolved_at: Option<Instant>,
}

struct Inner {
    next_id: u64,
    entries: BTreeMap<u64, Entry>,
}

/// See the module docs. All operations take the one internal lock; the
/// maps are small (bounded by `capacity`) and reaping is a linear sweep.
pub struct TicketRegistry {
    inner: Mutex<Inner>,
    capacity: usize,
    ttl: Duration,
    reaped: Counter,
}

impl TicketRegistry {
    pub fn new(capacity: usize, ttl_ms: u64, reaped: Counter) -> TicketRegistry {
        TicketRegistry {
            inner: Mutex::new(Inner { next_id: 1, entries: BTreeMap::new() }),
            capacity: capacity.max(1),
            ttl: Duration::from_millis(ttl_ms),
            reaped,
        }
    }

    /// Register a ticket owned by `owner` (the submitting session) and
    /// return its wire-visible id, or `None` when every slot holds an
    /// unresolved ticket (the caller sheds with 503 — refusing new work
    /// beats dropping handles to admitted work).
    pub fn insert(&self, ticket: Ticket, owner: u64, trace: TraceContext) -> Option<u64> {
        let mut inner = self.inner.lock_clean();
        self.reap_locked(&mut inner);
        if inner.entries.len() >= self.capacity {
            // at capacity before the TTL ran out: evict resolved entries
            // early — their outcome has been readable for a full sweep
            let resolved: Vec<u64> =
                inner.entries.iter().filter(|(_, e)| e.ticket.is_resolved()).map(|(id, _)| *id).collect();
            for id in resolved {
                inner.entries.remove(&id);
                self.reaped.inc();
            }
        }
        if inner.entries.len() >= self.capacity {
            return None;
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.entries.insert(id, Entry { ticket, owner, trace, resolved_at: None });
        Some(id)
    }

    /// Look up a ticket by wire id on behalf of `owner`. `None` for ids
    /// never issued, already reaped, or owned by a different session — all
    /// three miss identically, so the handler's 404 leaks nothing about
    /// other tenants' ids.
    pub fn get(&self, id: u64, owner: u64) -> Option<Ticket> {
        let mut inner = self.inner.lock_clean();
        self.reap_locked(&mut inner);
        inner.entries.get(&id).filter(|e| e.owner == owner).map(|e| e.ticket.clone())
    }

    /// The trace handle registered with a ticket, under the same owner
    /// check as [`TicketRegistry::get`]. Inert for pre-tracing tickets.
    pub fn trace_of(&self, id: u64, owner: u64) -> Option<TraceContext> {
        let mut inner = self.inner.lock_clean();
        self.reap_locked(&mut inner);
        inner.entries.get(&id).filter(|e| e.owner == owner).map(|e| e.trace.clone())
    }

    /// Entries currently registered (resolved-but-unreaped included).
    pub fn len(&self) -> usize {
        self.inner.lock_clean().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn reap_locked(&self, inner: &mut Inner) {
        let now = Instant::now();
        for e in inner.entries.values_mut() {
            if e.resolved_at.is_none() && e.ticket.is_resolved() {
                e.resolved_at = Some(now);
            }
        }
        let dead: Vec<u64> = inner
            .entries
            .iter()
            .filter(|(_, e)| e.resolved_at.is_some_and(|t| now.duration_since(t) >= self.ttl))
            .map(|(id, _)| *id)
            .collect();
        for id in dead {
            inner.entries.remove(&id);
            self.reaped.inc();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::waves::Decision;
    use crate::server::resolution::Resolution;
    use crate::server::{Outcome, Ticket};
    use crate::telemetry::Metrics;

    fn reap_counter(m: &Metrics) -> Counter {
        m.register_counter("tickets_reaped", "resolved tickets reaped from the HTTP ticket registry")
    }

    fn resolved_ticket() -> Ticket {
        let (ticket, cell) = Ticket::new_pair();
        cell.resolve(Ok(Outcome {
            request_id: 1,
            s_r: 0.0,
            decision: Decision::Reject { reason: "test".into() },
            latency_ms: 0.0,
            cost: 0.0,
            response: String::new(),
            sanitized: false,
            tokens_generated: 0,
            resolution: Resolution::Served,
        }));
        ticket
    }

    const OWNER: u64 = 7;

    #[test]
    fn issues_monotonic_ids_and_finds_tickets() {
        let m = Metrics::new();
        let r = TicketRegistry::new(8, 60_000, reap_counter(&m));
        let (t1, _c1) = Ticket::new_pair();
        let (t2, _c2) = Ticket::new_pair();
        let a = r.insert(t1, OWNER, TraceContext::none()).unwrap();
        let b = r.insert(t2, OWNER, TraceContext::none()).unwrap();
        assert!(b > a);
        assert!(r.get(a, OWNER).is_some());
        assert!(r.get(999, OWNER).is_none(), "never-issued id is a miss");
    }

    #[test]
    fn foreign_owner_lookup_misses_like_an_unknown_id() {
        let m = Metrics::new();
        let r = TicketRegistry::new(8, 60_000, reap_counter(&m));
        let (ticket, _cell) = Ticket::new_pair();
        let id = r.insert(ticket, OWNER, TraceContext::none()).unwrap();
        assert!(r.get(id, OWNER + 1).is_none(), "another session must not see the ticket");
        assert!(r.get(id, OWNER).is_some(), "the owner still can");
        assert!(r.trace_of(id, OWNER + 1).is_none(), "trace lookups honor the same owner check");
        assert!(r.trace_of(id, OWNER).is_some());
    }

    #[test]
    fn reaps_resolved_tickets_after_ttl() {
        let m = Metrics::new();
        let r = TicketRegistry::new(8, 20, reap_counter(&m));
        let id = r.insert(resolved_ticket(), OWNER, TraceContext::none()).unwrap();
        assert!(r.get(id, OWNER).is_some(), "within TTL the outcome stays readable");
        std::thread::sleep(Duration::from_millis(40));
        assert!(r.get(id, OWNER).is_none(), "past TTL the entry is reaped");
        assert_eq!(m.counter_value("tickets_reaped"), 1);
        assert!(r.is_empty());
    }

    #[test]
    fn unresolved_tickets_survive_ttl() {
        let m = Metrics::new();
        let r = TicketRegistry::new(8, 10, reap_counter(&m));
        let (ticket, _cell) = Ticket::new_pair();
        let id = r.insert(ticket, OWNER, TraceContext::none()).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(r.get(id, OWNER).is_some(), "TTL counts from resolution, not insertion");
        assert_eq!(m.counter_value("tickets_reaped"), 0);
    }

    #[test]
    fn at_capacity_evicts_resolved_first_and_refuses_when_all_live() {
        let m = Metrics::new();
        let r = TicketRegistry::new(2, 60_000, reap_counter(&m));
        let done = r.insert(resolved_ticket(), OWNER, TraceContext::none()).unwrap();
        let (live, _cell) = Ticket::new_pair();
        let live_id = r.insert(live, OWNER, TraceContext::none()).unwrap();
        // full; a resolved slot is reclaimed early, before its TTL
        let (third, _cell3) = Ticket::new_pair();
        let third_id = r.insert(third, OWNER, TraceContext::none()).expect("resolved entry must be evicted to make room");
        assert!(r.get(done, OWNER).is_none());
        assert!(r.get(live_id, OWNER).is_some());
        assert!(r.get(third_id, OWNER).is_some());
        assert_eq!(m.counter_value("tickets_reaped"), 1);
        // now every slot is unresolved: refuse, never evict live handles
        let (fourth, _cell4) = Ticket::new_pair();
        assert!(r.insert(fourth, OWNER, TraceContext::none()).is_none());
    }
}
