//! Hand-rolled HTTP/1.1 framing: request parsing and response writing over
//! any [`BufRead`]/[`Write`] pair — no dependencies, same offline-vendoring
//! discipline as the rest of the crate.
//!
//! The parser is deliberately narrow: request line + headers + an optional
//! `Content-Length` body (the only framing our clients use). Everything
//! else — chunked request bodies (any `Transfer-Encoding` header),
//! duplicate `Content-Length` headers (RFC 9112 §6.3 framing ambiguity),
//! multi-line headers, HTTP/2 preface — is rejected fail-closed as
//! `InvalidData`, which the connection loop answers with a 400 and a
//! close; silently mis-framing either would desync the keep-alive stream
//! (request smuggling). Bodies over [`MAX_BODY_BYTES`] are the one
//! distinguishable parse error ([`is_payload_too_large`]) so the loop can
//! answer 413 instead of 400. Reads tolerate the socket read timeout the
//! server installs for drain polling: a timeout *between* requests is an
//! idle keep-alive connection (close it only when draining), a timeout
//! *inside* a request is retried until the drain flag flips.

use std::io::{self, BufRead, Read, Write};

/// Hard cap on request bodies; larger submits are rejected before buffering.
pub(crate) const MAX_BODY_BYTES: usize = 1 << 20;
const MAX_HEADER_LINE: usize = 16 * 1024;
const MAX_HEADERS: usize = 100;

/// One parsed request: method, origin-form target, lower-cased headers and
/// the (possibly empty) body.
pub(crate) struct HttpRequest {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive single-valued header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Marker message for the one parse error that gets its own status code.
const PAYLOAD_TOO_LARGE: &str = "payload too large";

/// True iff `e` is the oversized-body parse error — the connection loop
/// answers it with 413 instead of the generic framing 400.
pub(crate) fn is_payload_too_large(e: &io::Error) -> bool {
    e.kind() == io::ErrorKind::InvalidData && e.get_ref().is_some_and(|inner| inner.to_string() == PAYLOAD_TOO_LARGE)
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read one CRLF- (or LF-) terminated line. `at_boundary` marks the
/// request line of a keep-alive connection: there, a clean EOF — or a read
/// timeout once the server is draining — returns `None` (close the
/// connection); anywhere else both are errors.
fn read_line<R: BufRead>(r: &mut R, draining: &dyn Fn() -> bool, at_boundary: bool) -> io::Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (consumed, done) = {
            let buf = match r.fill_buf() {
                Ok(b) => b,
                Err(e) if is_timeout(&e) => {
                    if !draining() {
                        continue;
                    }
                    if at_boundary && line.is_empty() {
                        return Ok(None);
                    }
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "server draining mid-request"));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if buf.is_empty() {
                if at_boundary && line.is_empty() {
                    return Ok(None);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-line"));
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    line.extend_from_slice(&buf[..pos]);
                    (pos + 1, true)
                }
                None => {
                    line.extend_from_slice(buf);
                    (buf.len(), false)
                }
            }
        };
        r.consume(consumed);
        if done {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
        }
        if line.len() > MAX_HEADER_LINE {
            return Err(bad("header line too long"));
        }
    }
}

fn read_body<R: Read>(r: &mut R, len: usize, draining: &dyn Fn() -> bool) -> io::Result<Vec<u8>> {
    let mut body = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        match r.read(&mut body[filled..]) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in body")),
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => {
                if draining() {
                    return Err(io::Error::new(io::ErrorKind::TimedOut, "server draining mid-body"));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(body)
}

/// Read one request off a keep-alive connection. `Ok(None)` means the
/// connection ended cleanly between requests (client EOF, or an idle
/// connection observed after the drain flag flipped); `Err` means a
/// malformed or truncated request — the caller answers 400/closes.
pub(crate) fn read_request<R: BufRead>(r: &mut R, draining: &dyn Fn() -> bool) -> io::Result<Option<HttpRequest>> {
    // tolerate stray blank lines between keep-alive requests (RFC 9112 §2.2)
    let line = loop {
        match read_line(r, draining, true)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => return Err(bad("malformed request line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad("unsupported HTTP version"));
    }
    let mut headers = Vec::new();
    loop {
        let Some(hline) = read_line(r, draining, false)? else {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in headers"));
        };
        if hline.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(bad("too many headers"));
        }
        let Some((name, value)) = hline.split_once(':') else {
            return Err(bad("malformed header"));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    // any Transfer-Encoding (chunked or otherwise) is unsupported framing:
    // parsing the request as zero-length would leave the encoded body on
    // the stream to be misread as the next pipelined request
    if headers.iter().any(|(n, _)| n == "transfer-encoding") {
        return Err(bad("chunked request bodies unsupported"));
    }
    let mut lens = headers.iter().filter(|(n, _)| n == "content-length");
    let len = match lens.next() {
        Some((_, v)) => {
            // duplicates are a framing ambiguity even when they agree
            // (RFC 9112 §6.3): reject rather than pick one
            if lens.next().is_some() {
                return Err(bad("duplicate content-length"));
            }
            v.parse::<usize>().map_err(|_| bad("malformed content-length"))?
        }
        None => 0,
    };
    if len > MAX_BODY_BYTES {
        return Err(bad(PAYLOAD_TOO_LARGE));
    }
    let body = read_body(r, len, draining)?;
    // the query string is routing noise for this API: strip it
    let path = target.split('?').next().unwrap_or(target).to_string();
    Ok(Some(HttpRequest { method: method.to_string(), path, headers, body }))
}

pub(crate) fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one fixed-length response. Head and body go out in a single
/// `write_all` so concurrent peeks never see a torn response.
pub(crate) fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    close: bool,
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason_phrase(status),
        body.len(),
        if close { "close" } else { "keep-alive" },
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    let mut buf = head.into_bytes();
    buf.extend_from_slice(body);
    w.write_all(&buf)?;
    w.flush()
}

/// Start a chunked `text/event-stream` response. The stream stays
/// keep-alive: the terminating zero-length chunk marks the end of the
/// body, so the client can reuse the connection afterwards.
pub(crate) fn write_stream_head(w: &mut impl Write) -> io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Transfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n",
    )?;
    w.flush()
}

/// Write one chunk of a chunked body (flushed: SSE consumers read live).
pub(crate) fn write_chunk(w: &mut impl Write, data: &[u8]) -> io::Result<()> {
    let mut buf = format!("{:x}\r\n", data.len()).into_bytes();
    buf.extend_from_slice(data);
    buf.extend_from_slice(b"\r\n");
    w.write_all(&buf)?;
    w.flush()
}

/// Terminate a chunked body.
pub(crate) fn write_last_chunk(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> io::Result<Option<HttpRequest>> {
        read_request(&mut Cursor::new(raw.to_vec()), &|| false)
    }

    #[test]
    fn parses_request_with_body_and_headers() {
        let raw = b"POST /v1/submit?x=1 HTTP/1.1\r\nHost: localhost\r\nAuthorization: Bearer k1\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/submit", "query string is stripped");
        assert_eq!(req.header("authorization"), Some("Bearer k1"));
        assert_eq!(req.header("AUTHORIZATION"), Some("Bearer k1"), "header lookup is case-insensitive");
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parses_two_keepalive_requests_off_one_stream() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.to_vec());
        let a = read_request(&mut cur, &|| false).unwrap().unwrap();
        let b = read_request(&mut cur, &|| false).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        assert_eq!(b.path, "/metrics");
        assert!(read_request(&mut cur, &|| false).unwrap().is_none(), "clean EOF between requests");
    }

    #[test]
    fn rejects_malformed_request_lines_and_headers() {
        assert!(parse(b"NOT-HTTP\r\n\r\n").is_err());
        assert!(parse(b"GET /x HTTP/2.0\r\n\r\n").is_err());
        assert!(parse(b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n").is_err());
        assert!(parse(b"GET /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
    }

    #[test]
    fn rejects_oversized_bodies_fail_closed_and_distinguishably() {
        let raw = format!("POST /v1/submit HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err = parse(raw.as_bytes()).unwrap_err();
        assert!(is_payload_too_large(&err), "oversized body must map to 413, not a generic 400");
        assert!(!is_payload_too_large(&parse(b"NOT-HTTP\r\n\r\n").unwrap_err()));
    }

    #[test]
    fn rejects_transfer_encoding_before_reading_any_body() {
        // parsing this as a zero-length body would leave "5\r\nhello..."
        // on the stream as a smuggled second request
        let raw = b"POST /v1/submit HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        assert!(parse(raw).is_err());
        assert!(parse(b"POST /x HTTP/1.1\r\nTransfer-Encoding: identity\r\nContent-Length: 2\r\n\r\nok").is_err());
    }

    #[test]
    fn rejects_duplicate_content_length_even_when_values_agree() {
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 4\r\n\r\nabcd").is_err());
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nab").is_err());
    }

    #[test]
    fn truncated_body_is_an_error_not_a_hang() {
        assert!(parse(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err());
    }

    #[test]
    fn response_writer_frames_status_and_length() {
        let mut out = Vec::new();
        write_response(&mut out, 404, "application/json", &[], b"{}", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn chunked_writer_hex_frames_and_terminates() {
        let mut out = Vec::new();
        write_chunk(&mut out, b"0123456789abcdef0").unwrap();
        write_last_chunk(&mut out).unwrap();
        assert_eq!(out, b"11\r\n0123456789abcdef0\r\n0\r\n\r\n");
    }
}
