//! Request-path server: session store, rate limiting, the typed submission
//! surface ([`SubmitRequest`] / [`Ticket`] / the admission queue in
//! [`queue`]) and the orchestrator façade implementing the Fig. 2
//! route-then-sanitize pipeline as an explicit request lifecycle
//! (enqueue → admit → route → batch → decode steps → resolve), with
//! streaming token delivery ([`TokenStream`]) and cooperative mid-decode
//! cancellation ([`Ticket::cancel`]), all exposed over sockets by the
//! dependency-free HTTP/1.1 surface in [`http`].

pub mod audit;
pub mod http;
pub mod orchestrator;
pub mod queue;
pub mod ratelimit;
pub mod resolution;
pub mod session;
pub mod ticket;

pub use http::{HttpConfig, HttpServer, TicketRegistry};
pub use orchestrator::{Backend, IslandSnapshot, Orchestrator, Outcome};
pub use queue::SubmitRequest;
pub use ratelimit::RateLimiter;
pub use resolution::{AuditReason, CancelPoint, FailReason, Resolution, ShedReason};
pub use session::{Session, SessionStore};
pub use ticket::{Ticket, TokenEvent, TokenStream};
