//! Request-path server: session store, rate limiting and the orchestrator
//! façade implementing the Fig. 2 route-then-sanitize pipeline.

pub mod audit;
pub mod orchestrator;
pub mod ratelimit;
pub mod session;

pub use orchestrator::{Backend, BatchItem, Orchestrator, Outcome};
pub use ratelimit::RateLimiter;
pub use session::{Session, SessionStore};
