//! Request-path server: session store, rate limiting, the typed submission
//! surface ([`SubmitRequest`] / [`Ticket`] / the admission queue in
//! [`queue`]) and the orchestrator façade implementing the Fig. 2
//! route-then-sanitize pipeline as an explicit request lifecycle
//! (enqueue → admit → route → batch → decode steps → resolve), with
//! streaming token delivery ([`TokenStream`]) and cooperative mid-decode
//! cancellation ([`Ticket::cancel`]).

pub mod audit;
pub mod orchestrator;
pub mod queue;
pub mod ratelimit;
pub mod resolution;
pub mod session;
pub mod ticket;

pub use orchestrator::{Backend, IslandSnapshot, Orchestrator, Outcome};
pub use queue::SubmitRequest;
pub use ratelimit::RateLimiter;
pub use resolution::{AuditReason, CancelPoint, FailReason, Resolution, ShedReason};
pub use session::{Session, SessionStore};
pub use ticket::{Ticket, TokenEvent, TokenStream};
