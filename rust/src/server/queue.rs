//! Request-lifecycle frontend: the typed [`SubmitRequest`] builder and the
//! bounded, priority+deadline-ordered `AdmissionQueue` (crate-internal)
//! behind [`Orchestrator::enqueue`].
//!
//! The queue is the backpressure point of the non-blocking serving surface
//! (enqueue → admit → route → batch → execute → resolve): producers push
//! admitted requests and return immediately with a [`Ticket`]; the worker
//! pool pops *batches* so co-routed requests coalesce across sessions and
//! submitters. A full queue sheds the incoming request fail-closed — the
//! shed is audited and metered (`rejected_queue_full`), never silent.
//!
//! Ordering: [`PriorityTier`] first (Primary ahead of Secondary ahead of
//! Burstable), then earliest absolute deadline (enqueue time + `d_r`), then
//! FIFO sequence as the total-order tiebreak. Requests whose deadline
//! already expired while queued are shed at pop time by the drain
//! (`shed_deadline_expired`).
//!
//! [`Orchestrator::enqueue`]: crate::server::Orchestrator::enqueue
//! [`Ticket`]: crate::server::Ticket

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::server::ticket::TicketCell;
use crate::telemetry::TraceContext;
use crate::types::PriorityTier;

use crate::util::sync::{cond_wait_timeout, cond_wait_while, LockExt};

/// Typed, builder-style submission: every routing-relevant [`Request`] knob
/// the serving surface supports, without positional-argument creep.
///
/// ```
/// use islandrun::server::SubmitRequest;
/// use islandrun::types::PriorityTier;
///
/// let sr = SubmitRequest::new("summarize the contract")
///     .priority(PriorityTier::Secondary)
///     .deadline_ms(500.0)
///     .min_jurisdiction(0.9)
///     .dataset("case_law")
///     .max_new_tokens(32);
/// assert_eq!(sr.deadline_ms, 500.0);
/// ```
///
/// [`Request`]: crate::types::Request
#[derive(Clone, Debug)]
pub struct SubmitRequest {
    /// Input prompt `q`.
    pub prompt: String,
    /// §IX.B priority tier (drives queue ordering and tier admission).
    pub priority: PriorityTier,
    /// Maximum acceptable latency `d_r` in ms. Orders the admission queue,
    /// sheds expired requests at drain time, and excludes islands whose
    /// base RTT already exceeds it from the scored routing sets.
    pub deadline_ms: f64,
    /// Caller-declared sensitivity floor: routing uses
    /// `max(MIST score, floor)`, so a caller can only *tighten* the privacy
    /// constraint, never relax it below what MIST measured.
    pub sensitivity_floor: Option<f64>,
    /// §XIV regulatory compliance: minimum jurisdiction score.
    pub min_jurisdiction: Option<f64>,
    /// §XIV heterogeneous model support: required model family.
    pub model: Option<String>,
    /// Data-locality constraint (§III.F): dataset the request must run next to.
    pub dataset: Option<String>,
    /// Max new tokens to generate.
    pub max_new_tokens: usize,
    /// Request-scoped trace handle. Inert by default; the HTTP submit path
    /// starts it early (adopting an inbound `traceparent`) and the
    /// orchestrator starts it at `enqueue` otherwise. Threaded by value —
    /// never a thread-local — so worker handoffs keep the span tree intact.
    pub trace: TraceContext,
}

impl SubmitRequest {
    /// A single-turn submission with the same defaults as
    /// [`Request::new`](crate::types::Request::new).
    pub fn new(prompt: impl Into<String>) -> SubmitRequest {
        SubmitRequest {
            prompt: prompt.into(),
            priority: PriorityTier::Secondary,
            deadline_ms: 2000.0,
            sensitivity_floor: None,
            min_jurisdiction: None,
            model: None,
            dataset: None,
            max_new_tokens: 16,
            trace: TraceContext::none(),
        }
    }

    pub fn priority(mut self, p: PriorityTier) -> Self {
        self.priority = p;
        self
    }

    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = ms;
        self
    }

    /// Declare a sensitivity floor in [0,1]; routing uses the max of this
    /// and the MIST score (callers can tighten privacy, never loosen it).
    pub fn sensitivity(mut self, floor: f64) -> Self {
        self.sensitivity_floor = Some(floor.clamp(0.0, 1.0));
        self
    }

    pub fn min_jurisdiction(mut self, floor: f64) -> Self {
        self.min_jurisdiction = Some(floor);
        self
    }

    pub fn model(mut self, model: &str) -> Self {
        self.model = Some(model.to_string());
        self
    }

    pub fn dataset(mut self, dataset: &str) -> Self {
        self.dataset = Some(dataset.to_string());
        self
    }

    pub fn max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n;
        self
    }

    /// Attach an already-started trace context (HTTP submit does this so the
    /// root span covers transport time and inbound `traceparent` adoption).
    pub fn trace(mut self, trace: TraceContext) -> Self {
        self.trace = trace;
        self
    }

    /// Structural validity check, enforced at the serving front door
    /// ([`Orchestrator::enqueue`](crate::server::Orchestrator::enqueue) and
    /// the blocking submit path). A zero token budget would route and then
    /// occupy a worker generating nothing; a zero, negative or non-finite
    /// deadline insta-expires inside the drain loop. Both are shed
    /// fail-closed with an audited reject instead.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_new_tokens == 0 {
            return Err("max_new_tokens must be >= 1".to_string());
        }
        if self.deadline_ms.is_nan() || self.deadline_ms <= 0.0 {
            return Err(format!("deadline_ms must be a positive number of milliseconds (got {})", self.deadline_ms));
        }
        Ok(())
    }
}

/// One admitted request parked in the queue: everything the drain needs to
/// finish the lifecycle without touching the producer again.
#[derive(Debug)]
pub(crate) struct QueueItem {
    /// Request id, allocated at enqueue time (sheds are audited under it).
    pub id: u64,
    pub session_id: u64,
    pub user: String,
    pub submit: SubmitRequest,
    /// Orchestrator clock (virtual or wall ms) at enqueue.
    pub enqueued_ms: f64,
    /// Absolute deadline: `enqueued_ms + submit.deadline_ms`.
    pub deadline_at_ms: f64,
    /// FIFO sequence, the final total-order tiebreak.
    pub seq: u64,
    pub ticket: Arc<TicketCell>,
}

impl QueueItem {
    /// Lexicographic pop key: smallest pops first.
    fn key_cmp(&self, other: &QueueItem) -> Ordering {
        self.submit
            .priority
            .cmp(&other.submit.priority)
            .then(self.deadline_at_ms.total_cmp(&other.deadline_at_ms))
            .then(self.seq.cmp(&other.seq))
    }
}

// `BinaryHeap` is a max-heap; reverse the key so the smallest (most urgent)
// item is the heap maximum.
impl Ord for QueueItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key_cmp(other).reverse()
    }
}

impl PartialOrd for QueueItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for QueueItem {
    fn eq(&self, other: &Self) -> bool {
        self.key_cmp(other) == Ordering::Equal
    }
}

impl Eq for QueueItem {}

#[derive(Debug)]
struct Inner {
    heap: BinaryHeap<QueueItem>,
    next_seq: u64,
    closed: bool,
}

/// Bounded, priority+deadline-ordered admission queue (see module docs).
#[derive(Debug)]
pub(crate) struct AdmissionQueue {
    inner: Mutex<Inner>,
    cond: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub(crate) fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner { heap: BinaryHeap::new(), next_seq: 0, closed: false }),
            cond: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    pub(crate) fn len(&self) -> usize {
        self.inner.lock_clean().heap.len()
    }

    /// Push an admitted request. `Ok(depth)` on success; `Err(item)` hands
    /// the item back when the queue is full (or closed) so the caller can
    /// shed it fail-closed with an audit entry.
    pub(crate) fn push(
        &self,
        id: u64,
        session_id: u64,
        user: String,
        submit: SubmitRequest,
        now_ms: f64,
        ticket: Arc<TicketCell>,
    ) -> Result<usize, QueueItem> {
        let mut inner = self.inner.lock_clean();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let deadline_at_ms = now_ms + submit.deadline_ms.max(0.0);
        let item = QueueItem { id, session_id, user, submit, enqueued_ms: now_ms, deadline_at_ms, seq, ticket };
        if inner.closed || inner.heap.len() >= self.capacity {
            return Err(item);
        }
        inner.heap.push(item);
        let depth = inner.heap.len();
        drop(inner);
        self.cond.notify_one();
        Ok(depth)
    }

    /// Pop up to `max` items in priority order, blocking while the queue is
    /// empty. Once at least one item is available, lingers up to `max_wait`
    /// (wall time) for the batch to fill toward `max` — the classic
    /// latency-vs-occupancy tradeoff of `BatchPolicy`, applied at the
    /// cross-session coalescing point; `Duration::ZERO` disables the
    /// linger. Returns `None` once the queue is closed and drained (worker
    /// shutdown signal).
    pub(crate) fn pop_batch(&self, max: usize, max_wait: Duration) -> Option<Vec<QueueItem>> {
        let max = max.max(1);
        let mut inner = self.inner.lock_clean();
        loop {
            inner = cond_wait_while(&self.cond, inner, |i| i.heap.is_empty() && !i.closed);
            if inner.heap.is_empty() {
                return None; // closed and drained
            }
            // linger for more arrivals while the batch is below `max`
            let give_up_at = Instant::now() + max_wait;
            while inner.heap.len() < max && !inner.closed {
                let now = Instant::now();
                if now >= give_up_at {
                    break;
                }
                let (guard, wait) = cond_wait_timeout(&self.cond, inner, give_up_at - now);
                inner = guard;
                if wait.timed_out() {
                    break;
                }
            }
            if inner.heap.is_empty() {
                continue; // another worker drained it while we lingered
            }
            let n = max.min(inner.heap.len());
            let mut batch = Vec::with_capacity(n);
            while batch.len() < n {
                match inner.heap.pop() {
                    Some(item) => batch.push(item),
                    None => break,
                }
            }
            return Some(batch);
        }
    }

    /// Close the queue: wake every blocked worker and hand back whatever was
    /// still parked so the caller can resolve those tickets (no ticket may
    /// be silently lost, even at shutdown).
    pub(crate) fn close(&self) -> Vec<QueueItem> {
        let mut inner = self.inner.lock_clean();
        inner.closed = true;
        let leftovers = std::mem::take(&mut inner.heap).into_sorted_vec();
        drop(inner);
        self.cond.notify_all();
        leftovers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::ticket::Ticket;

    fn push(q: &AdmissionQueue, id: u64, sr: SubmitRequest, now: f64) -> Result<usize, QueueItem> {
        let (_ticket, cell) = Ticket::new_pair();
        q.push(id, 1, "u".into(), sr, now, cell)
    }

    #[test]
    fn pops_priority_then_deadline_then_fifo() {
        let q = AdmissionQueue::new(16);
        push(&q, 1, SubmitRequest::new("a").priority(PriorityTier::Burstable), 0.0).unwrap();
        push(&q, 2, SubmitRequest::new("b").priority(PriorityTier::Secondary).deadline_ms(900.0), 0.0).unwrap();
        push(&q, 3, SubmitRequest::new("c").priority(PriorityTier::Primary), 0.0).unwrap();
        push(&q, 4, SubmitRequest::new("d").priority(PriorityTier::Secondary).deadline_ms(100.0), 0.0).unwrap();
        push(&q, 5, SubmitRequest::new("e").priority(PriorityTier::Secondary).deadline_ms(100.0), 0.0).unwrap();
        let order: Vec<u64> = q.pop_batch(8, Duration::ZERO).unwrap().iter().map(|i| i.id).collect();
        // primary first, then secondary by earliest deadline (FIFO tiebreak
        // between 4 and 5), burstable last
        assert_eq!(order, vec![3, 4, 5, 2, 1]);
    }

    #[test]
    fn bounded_capacity_hands_back_the_overflow_item() {
        let q = AdmissionQueue::new(2);
        push(&q, 1, SubmitRequest::new("a"), 0.0).unwrap();
        push(&q, 2, SubmitRequest::new("b"), 0.0).unwrap();
        let shed = push(&q, 3, SubmitRequest::new("c"), 0.0).unwrap_err();
        assert_eq!(shed.id, 3, "the incoming item is shed, queued work is kept");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pop_batch_caps_and_leaves_the_rest() {
        let q = AdmissionQueue::new(16);
        for id in 0..5 {
            push(&q, id, SubmitRequest::new("x"), id as f64).unwrap();
        }
        assert_eq!(q.pop_batch(3, Duration::ZERO).unwrap().len(), 3);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_batch(8, Duration::ZERO).unwrap().len(), 2);
    }

    #[test]
    fn close_returns_leftovers_and_unblocks_poppers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(16));
        let q2 = std::sync::Arc::clone(&q);
        let popper = std::thread::spawn(move || q2.pop_batch(4, Duration::ZERO));
        std::thread::sleep(std::time::Duration::from_millis(10));
        push(&q, 1, SubmitRequest::new("a"), 0.0).unwrap();
        // the blocked popper wakes with the item
        assert_eq!(popper.join().unwrap().unwrap().len(), 1);
        push(&q, 2, SubmitRequest::new("b"), 0.0).unwrap();
        let leftovers = q.close();
        assert_eq!(leftovers.len(), 1);
        assert_eq!(leftovers[0].id, 2);
        // closed + drained: poppers get the shutdown signal, pushes shed
        assert!(q.pop_batch(4, Duration::ZERO).is_none());
        assert!(push(&q, 3, SubmitRequest::new("c"), 0.0).is_err());
    }

    #[test]
    fn linger_fills_the_batch_from_late_arrivals() {
        let q = std::sync::Arc::new(AdmissionQueue::new(16));
        push(&q, 1, SubmitRequest::new("a"), 0.0).unwrap();
        let q2 = std::sync::Arc::clone(&q);
        // the popper sees one item, lingers, and the late arrival joins
        // the same batch instead of becoming its own single-item dispatch
        let popper = std::thread::spawn(move || q2.pop_batch(2, Duration::from_millis(200)));
        std::thread::sleep(Duration::from_millis(20));
        push(&q, 2, SubmitRequest::new("b"), 0.0).unwrap();
        let batch = popper.join().unwrap().unwrap();
        assert_eq!(batch.len(), 2, "linger must coalesce the late arrival");
        // with no further arrivals, the linger gives up after max_wait
        push(&q, 3, SubmitRequest::new("c"), 0.0).unwrap();
        let t0 = Instant::now();
        let batch = q.pop_batch(4, Duration::from_millis(20)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn deadline_is_absolute_from_enqueue_time() {
        let q = AdmissionQueue::new(16);
        // enqueued later but with a much shorter relative deadline → pops first
        push(&q, 1, SubmitRequest::new("a").deadline_ms(5000.0), 0.0).unwrap();
        push(&q, 2, SubmitRequest::new("b").deadline_ms(100.0), 1000.0).unwrap();
        let order: Vec<u64> = q.pop_batch(2, Duration::ZERO).unwrap().iter().map(|i| i.id).collect();
        assert_eq!(order, vec![2, 1]);
    }

    #[test]
    fn builder_covers_every_knob() {
        let sr = SubmitRequest::new("q")
            .priority(PriorityTier::Primary)
            .deadline_ms(250.0)
            .sensitivity(0.95)
            .min_jurisdiction(0.9)
            .model("tinylm")
            .dataset("case_law")
            .max_new_tokens(64);
        assert_eq!(sr.priority, PriorityTier::Primary);
        assert_eq!(sr.deadline_ms, 250.0);
        assert_eq!(sr.sensitivity_floor, Some(0.95));
        assert_eq!(sr.min_jurisdiction, Some(0.9));
        assert_eq!(sr.model.as_deref(), Some("tinylm"));
        assert_eq!(sr.dataset.as_deref(), Some("case_law"));
        assert_eq!(sr.max_new_tokens, 64);
        assert!(!sr.trace.is_active(), "trace is inert until a sink starts it");
        // the sensitivity floor clamps into [0,1]
        assert_eq!(SubmitRequest::new("q").sensitivity(7.0).sensitivity_floor, Some(1.0));
    }

    #[test]
    fn validate_rejects_degenerate_budgets() {
        assert!(SubmitRequest::new("q").validate().is_ok());
        assert!(SubmitRequest::new("q").deadline_ms(f64::INFINITY).validate().is_ok(), "no deadline pressure is fine");
        let err = SubmitRequest::new("q").max_new_tokens(0).validate().unwrap_err();
        assert!(err.contains("max_new_tokens"), "{err}");
        for bad in [0.0, -5.0, f64::NAN] {
            let err = SubmitRequest::new("q").deadline_ms(bad).validate().unwrap_err();
            assert!(err.contains("deadline_ms"), "{err}");
        }
    }
}
