//! Per-user token-bucket rate limiting (§VIII.C Attack-4 mitigation:
//! "Rate limiting at WAVES based on user identity").
//!
//! Runs in virtual time like the rest of the coordinator so the attack
//! experiments are deterministic.

use std::collections::BTreeMap;

/// Token bucket: `rate` tokens/sec, burst up to `burst`.
#[derive(Clone, Debug)]
pub struct RateLimiter {
    rate_per_ms: f64,
    burst: f64,
    buckets: BTreeMap<String, (f64, f64)>, // user -> (tokens, last_ms)
}

impl RateLimiter {
    pub fn new(rate_per_sec: f64, burst: f64) -> RateLimiter {
        RateLimiter { rate_per_ms: rate_per_sec / 1000.0, burst, buckets: BTreeMap::new() }
    }

    /// Try to admit one request from `user` at virtual time `now_ms`.
    ///
    /// Timestamps are clamped monotonic: concurrent submitters read the
    /// clock outside the limiter lock, so a stale `now_ms` may arrive after
    /// a newer one was recorded — storing the smaller value back would
    /// rewind the bucket and double-credit refill.
    pub fn admit(&mut self, user: &str, now_ms: f64) -> bool {
        let (tokens, last) = self.buckets.get(user).copied().unwrap_or((self.burst, now_ms));
        let refilled = (tokens + (now_ms - last).max(0.0) * self.rate_per_ms).min(self.burst);
        let stamp = now_ms.max(last);
        if refilled >= 1.0 {
            self.buckets.insert(user.to_string(), (refilled - 1.0, stamp));
            true
        } else {
            self.buckets.insert(user.to_string(), (refilled, stamp));
            false
        }
    }

    /// Current token count (testing / reporting).
    pub fn tokens(&self, user: &str) -> f64 {
        self.buckets.get(user).map(|&(t, _)| t).unwrap_or(self.burst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_throttle() {
        let mut rl = RateLimiter::new(10.0, 5.0); // 10 rps, burst 5
        let mut admitted = 0;
        for _ in 0..20 {
            if rl.admit("mallory", 0.0) {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 5, "only the burst admits at t=0");
    }

    #[test]
    fn refill_over_time() {
        let mut rl = RateLimiter::new(10.0, 5.0);
        for _ in 0..5 {
            assert!(rl.admit("u", 0.0));
        }
        assert!(!rl.admit("u", 0.0));
        // 10 rps → one token every 100ms
        assert!(rl.admit("u", 150.0));
        assert!(!rl.admit("u", 160.0));
    }

    #[test]
    fn stale_timestamps_do_not_rewind_the_bucket() {
        // concurrent submitters can present time out of order; an old
        // now_ms must not re-credit refill that was already granted
        let mut rl = RateLimiter::new(10.0, 1.0);
        assert!(rl.admit("u", 1000.0)); // bucket empty, last=1000
        assert!(!rl.admit("u", 0.0), "stale clock must not admit");
        // had the stamp rewound to 0, this would see 100ms of refill;
        // monotonic clamping means only 10ms elapsed since 1000
        assert!(!rl.admit("u", 1010.0));
        assert!(rl.admit("u", 1150.0), "real elapsed time still refills");
    }

    #[test]
    fn users_isolated() {
        let mut rl = RateLimiter::new(1.0, 1.0);
        assert!(rl.admit("a", 0.0));
        assert!(!rl.admit("a", 0.0));
        assert!(rl.admit("b", 0.0), "user b has their own bucket");
    }

    #[test]
    fn sustained_rate_approximates_configured_rps() {
        let mut rl = RateLimiter::new(50.0, 10.0);
        let mut admitted = 0;
        // 10 seconds, attacker tries every ms
        for t in 0..10_000 {
            if rl.admit("flood", t as f64) {
                admitted += 1;
            }
        }
        // expect ~500 + burst
        assert!((480..=560).contains(&admitted), "admitted={admitted}");
    }
}
