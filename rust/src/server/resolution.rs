//! Typed request resolutions: how a ticket terminated.
//!
//! Replaces two stringly conventions at once: `Outcome`'s ad-hoc
//! `cancelled: bool` flag, and the `"shed:"` / `"cancelled:"` prefix
//! convention on audit reject reasons. One enum drives all three consumers —
//! the `Outcome` the caller sees, the audit-log entry, and the
//! outcome-labeled metric counter — so they can never disagree about what
//! happened to a request.

/// Why a request was shed before it reached an island.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Admission queue at capacity (fail-closed back-pressure).
    QueueFull,
    /// Deadline expired while waiting in the admission queue.
    DeadlineExpired,
    /// The request failed validation before admission.
    InvalidRequest,
    /// The per-user token bucket refused the request at the front door.
    RateLimited,
    /// A serving worker or step loop panicked with the request in flight.
    WorkerPanic,
    /// The orchestrator shut down with the request still queued.
    Shutdown,
}

/// Where in the lifecycle a caller- or deadline-driven cancel landed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelPoint {
    /// Cancelled while waiting in the admission queue, before routing.
    WhileQueued,
    /// Cancelled after routing but before the island started decoding.
    BeforeExecution,
    /// Caller cancel observed between decode steps.
    MidDecode,
    /// Deadline expired between decode steps.
    DeadlineMidDecode,
}

/// Why a request failed after admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// No island satisfied the privacy/jurisdiction constraints (fail-closed).
    FailClosed,
    /// Failover retry budget exhausted without a successful attempt.
    FailoverExhausted,
    /// The island executor reported a non-recoverable error.
    ExecutionError,
    /// The session vanished mid-flight (closed by the caller).
    SessionClosed,
}

/// Terminal state of a request. Every resolved ticket carries exactly one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// Served to completion by an island.
    Served,
    /// Dropped before reaching an island (back-pressure / validation).
    Shed(ShedReason),
    /// Terminated early by the caller or a deadline.
    Cancelled(CancelPoint),
    /// Admitted but could not be served.
    Failed(FailReason),
}

/// Audit entries record the same typed reason as the outcome and the
/// outcome-class metric label — one source of truth for all three.
pub type AuditReason = Resolution;

impl Resolution {
    /// Outcome class label: `served` / `shed` / `cancelled` / `failed`.
    pub fn class(&self) -> &'static str {
        match self {
            Resolution::Served => "served",
            Resolution::Shed(_) => "shed",
            Resolution::Cancelled(_) => "cancelled",
            Resolution::Failed(_) => "failed",
        }
    }

    /// Fine-grained reason label (the `reason` metric label value).
    pub fn reason(&self) -> &'static str {
        match self {
            Resolution::Served => "ok",
            Resolution::Shed(ShedReason::QueueFull) => "queue_full",
            Resolution::Shed(ShedReason::DeadlineExpired) => "deadline_expired",
            Resolution::Shed(ShedReason::InvalidRequest) => "invalid_request",
            Resolution::Shed(ShedReason::RateLimited) => "rate_limited",
            Resolution::Shed(ShedReason::WorkerPanic) => "worker_panic",
            Resolution::Shed(ShedReason::Shutdown) => "shutdown",
            Resolution::Cancelled(CancelPoint::WhileQueued) => "while_queued",
            Resolution::Cancelled(CancelPoint::BeforeExecution) => "before_execution",
            Resolution::Cancelled(CancelPoint::MidDecode) => "mid_decode",
            Resolution::Cancelled(CancelPoint::DeadlineMidDecode) => "deadline_mid_decode",
            Resolution::Failed(FailReason::FailClosed) => "fail_closed",
            Resolution::Failed(FailReason::FailoverExhausted) => "failover_exhausted",
            Resolution::Failed(FailReason::ExecutionError) => "execution_error",
            Resolution::Failed(FailReason::SessionClosed) => "session_closed",
        }
    }

    pub fn is_served(&self) -> bool {
        matches!(self, Resolution::Served)
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, Resolution::Shed(_))
    }

    pub fn is_cancelled(&self) -> bool {
        matches!(self, Resolution::Cancelled(_))
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, Resolution::Failed(_))
    }

    /// All variants, for exhaustive metric pre-registration and tests.
    pub const ALL: [Resolution; 15] = [
        Resolution::Served,
        Resolution::Shed(ShedReason::QueueFull),
        Resolution::Shed(ShedReason::DeadlineExpired),
        Resolution::Shed(ShedReason::InvalidRequest),
        Resolution::Shed(ShedReason::RateLimited),
        Resolution::Shed(ShedReason::WorkerPanic),
        Resolution::Shed(ShedReason::Shutdown),
        Resolution::Cancelled(CancelPoint::WhileQueued),
        Resolution::Cancelled(CancelPoint::BeforeExecution),
        Resolution::Cancelled(CancelPoint::MidDecode),
        Resolution::Cancelled(CancelPoint::DeadlineMidDecode),
        Resolution::Failed(FailReason::FailClosed),
        Resolution::Failed(FailReason::FailoverExhausted),
        Resolution::Failed(FailReason::ExecutionError),
        Resolution::Failed(FailReason::SessionClosed),
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_and_reason_labels_are_consistent() {
        for r in Resolution::ALL {
            match r {
                Resolution::Served => assert_eq!(r.class(), "served"),
                Resolution::Shed(_) => assert_eq!(r.class(), "shed"),
                Resolution::Cancelled(_) => assert_eq!(r.class(), "cancelled"),
                Resolution::Failed(_) => assert_eq!(r.class(), "failed"),
            }
            assert!(!r.reason().is_empty());
        }
    }

    #[test]
    fn reason_labels_are_unique_within_class() {
        let mut seen = std::collections::BTreeSet::new();
        for r in Resolution::ALL {
            assert!(seen.insert((r.class(), r.reason())), "duplicate label pair for {r:?}");
        }
        assert_eq!(seen.len(), 15);
    }

    #[test]
    fn predicates_match_variants() {
        assert!(Resolution::Served.is_served());
        assert!(Resolution::Shed(ShedReason::QueueFull).is_shed());
        assert!(Resolution::Cancelled(CancelPoint::MidDecode).is_cancelled());
        assert!(Resolution::Failed(FailReason::FailClosed).is_failed());
        assert!(!Resolution::Served.is_cancelled());
    }
}
