//! Per-island step-loop lanes: the hand-off structure between the queue
//! drain (which routes requests) and the continuous-batching driver (which
//! interleaves decode steps on one island).
//!
//! Each island gets a lane holding an inbox of routed-but-not-yet-started
//! jobs plus a `driver_active` flag. A drain thread `admit`s jobs and then
//! `try_drive`s the lane: exactly one thread at a time becomes the island's
//! driver and runs the step loop, pulling admitted jobs into the in-flight
//! batch *between decode steps* via `take`. Other drains just drop their
//! jobs in the inbox and move on — newly routed requests join an island's
//! running batch without waiting for it to finish.
//!
//! Exit is race-free: `try_exit` only releases the driver role while the
//! inbox is empty, atomically under the lane lock, so a job admitted
//! concurrently with a driver winding down is either taken by that driver
//! or finds `try_drive` returning true for its own drain — never stranded.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::sync::LockExt;

#[derive(Debug)]
struct LaneInner<J> {
    inbox: Vec<J>,
    driver_active: bool,
}

#[derive(Debug)]
struct Lane<J> {
    inner: Mutex<LaneInner<J>>,
}

impl<J> Default for Lane<J> {
    fn default() -> Self {
        Lane { inner: Mutex::new(LaneInner { inbox: Vec::new(), driver_active: false }) }
    }
}

/// Keyed set of step-loop lanes (key = island id on the serving path).
#[derive(Debug)]
pub struct StepLanes<K: Ord + Copy, J> {
    lanes: Mutex<BTreeMap<K, Arc<Lane<J>>>>,
}

impl<K: Ord + Copy, J> Default for StepLanes<K, J> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord + Copy, J> StepLanes<K, J> {
    pub fn new() -> Self {
        StepLanes { lanes: Mutex::new(BTreeMap::new()) }
    }

    fn lane(&self, key: K) -> Arc<Lane<J>> {
        let mut lanes = self.lanes.lock_clean();
        Arc::clone(lanes.entry(key).or_default())
    }

    /// Drop jobs into the lane's inbox. A running driver picks them up at
    /// its next step boundary; otherwise the admitting thread should call
    /// [`try_drive`](Self::try_drive) to become the driver itself.
    pub fn admit(&self, key: K, jobs: Vec<J>) {
        if jobs.is_empty() {
            return;
        }
        let lane = self.lane(key);
        lane.inner.lock_clean().inbox.extend(jobs);
    }

    /// Claim the driver role for the lane. Returns `true` when this caller
    /// became the driver (and must run the step loop until
    /// [`try_exit`](Self::try_exit) succeeds), `false` when a driver is
    /// already active.
    pub fn try_drive(&self, key: K) -> bool {
        let lane = self.lane(key);
        let mut inner = lane.inner.lock_clean();
        if inner.driver_active {
            return false;
        }
        inner.driver_active = true;
        true
    }

    /// Pull up to `max` admitted jobs into the driver's in-flight batch
    /// (FIFO admission order).
    pub fn take(&self, key: K, max: usize) -> Vec<J> {
        if max == 0 {
            return Vec::new();
        }
        let lane = self.lane(key);
        let mut inner = lane.inner.lock_clean();
        let n = inner.inbox.len().min(max);
        inner.inbox.drain(..n).collect()
    }

    /// Release the driver role — but only if the inbox is still empty
    /// (checked atomically under the lane lock). Returns `true` when the
    /// driver exited; `false` means jobs arrived since the last `take` and
    /// the caller must keep driving.
    pub fn try_exit(&self, key: K) -> bool {
        let lane = self.lane(key);
        let mut inner = lane.inner.lock_clean();
        if !inner.inbox.is_empty() {
            return false;
        }
        inner.driver_active = false;
        true
    }

    /// Panic recovery: drain every pending job and clear the driver flag so
    /// the lane is usable again. The caller fails the returned jobs' tickets.
    pub fn fail_pending(&self, key: K) -> Vec<J> {
        let lane = self.lane(key);
        let mut inner = lane.inner.lock_clean();
        inner.driver_active = false;
        std::mem::take(&mut inner.inbox)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_driver_per_lane() {
        let lanes: StepLanes<u32, i32> = StepLanes::new();
        assert!(lanes.try_drive(1));
        assert!(!lanes.try_drive(1), "second driver must be refused");
        assert!(lanes.try_drive(2), "other lanes are independent");
        assert!(lanes.try_exit(1));
        assert!(lanes.try_drive(1), "exited lane accepts a new driver");
    }

    #[test]
    fn admit_take_is_fifo_and_capped() {
        let lanes: StepLanes<u32, i32> = StepLanes::new();
        lanes.admit(7, vec![1, 2, 3]);
        lanes.admit(7, vec![4]);
        assert_eq!(lanes.take(7, 2), vec![1, 2]);
        assert_eq!(lanes.take(7, 0), Vec::<i32>::new());
        assert_eq!(lanes.take(7, 10), vec![3, 4]);
        assert!(lanes.take(7, 10).is_empty());
    }

    #[test]
    fn exit_refused_while_inbox_nonempty() {
        let lanes: StepLanes<u32, i32> = StepLanes::new();
        assert!(lanes.try_drive(3));
        lanes.admit(3, vec![9]);
        assert!(!lanes.try_exit(3), "driver must keep driving while jobs are pending");
        assert_eq!(lanes.take(3, 8), vec![9]);
        assert!(lanes.try_exit(3));
    }

    #[test]
    fn fail_pending_drains_and_frees_the_lane() {
        let lanes: StepLanes<u32, i32> = StepLanes::new();
        assert!(lanes.try_drive(5));
        lanes.admit(5, vec![1, 2]);
        assert_eq!(lanes.fail_pending(5), vec![1, 2]);
        assert!(lanes.try_drive(5), "lane usable after recovery");
    }

    #[test]
    fn concurrent_admit_and_drive_loses_no_job() {
        let lanes: Arc<StepLanes<u32, usize>> = Arc::new(StepLanes::new());
        let total = 400;
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let lanes = Arc::clone(&lanes);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        lanes.admit(0, vec![t * 100 + i]);
                    }
                })
            })
            .collect();
        let consumer = {
            let lanes = Arc::clone(&lanes);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while seen.len() < total {
                    if lanes.try_drive(0) {
                        loop {
                            let got = lanes.take(0, 8);
                            if got.is_empty() {
                                if lanes.try_exit(0) {
                                    break;
                                }
                                continue;
                            }
                            seen.extend(got);
                        }
                    }
                    std::thread::yield_now();
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), total);
    }
}
