//! Hashed char-n-gram featurizer — EXACT mirror of `python/compile/model.py`
//! (`featurize`): lowercase → UTF-8 bytes → {2,3}-gram FNV-1a 64-bit hashes →
//! buckets mod 512 → counts → L2 normalize.
//!
//! The MIST Stage-2 classifier and the Embedder artifacts were trained on
//! the python featurizer; this implementation feeds them at inference time,
//! so the two must never drift. Golden vectors from `artifacts/meta.json`
//! are pinned here AND in python/tests/test_model.py.

/// Feature dimension (mirrors meta.json `feat_dim`).
pub const FEAT_DIM: usize = 512;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01B3;

/// 64-bit FNV-1a over bytes.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Featurize text into a unit-norm `FEAT_DIM` vector.
pub fn featurize(text: &str) -> Vec<f32> {
    let lower = text.to_lowercase();
    let data = lower.as_bytes();
    let mut vec = vec![0f32; FEAT_DIM];
    for n in [2usize, 3] {
        if data.len() >= n {
            for i in 0..=(data.len() - n) {
                let h = fnv1a(&data[i..i + n]);
                vec[(h % FEAT_DIM as u64) as usize] += 1.0;
            }
        }
    }
    let norm: f32 = vec.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 0.0 {
        for x in vec.iter_mut() {
            *x /= norm;
        }
    }
    vec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_golden_values_match_python() {
        // pinned in python/tests/test_model.py::test_fnv1a_golden
        assert_eq!(fnv1a(b"ab"), 0x089C4407B545986A);
        assert_eq!(fnv1a(b""), 0xCBF29CE484222325);
        assert_eq!(fnv1a(b"islandrun") % FEAT_DIM as u64, 233);
    }

    #[test]
    fn empty_and_single_byte_are_zero() {
        assert!(featurize("").iter().all(|&x| x == 0.0));
        assert!(featurize("a").iter().all(|&x| x == 0.0));
    }

    #[test]
    fn single_bigram_unit_vector() {
        let v = featurize("ab");
        let nz: Vec<usize> = (0..FEAT_DIM).filter(|&i| v[i] > 0.0).collect();
        assert_eq!(nz.len(), 1);
        assert!((v[nz[0]] - 1.0).abs() < 1e-6);
        assert_eq!(nz[0], (fnv1a(b"ab") % FEAT_DIM as u64) as usize);
    }

    #[test]
    fn case_insensitive() {
        assert_eq!(featurize("Hello World"), featurize("hello world"));
    }

    #[test]
    fn unit_norm() {
        for text in ["hello", "patient john doe", "the islands form an archipelago"] {
            let v = featurize(text);
            let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "norm={n} for {text}");
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(featurize("same text"), featurize("same text"));
        assert_ne!(featurize("text a"), featurize("text b"));
    }

    /// Cross-language anchor: mirrors the first golden entry the AOT step
    /// writes into meta.json (verified end-to-end by tests that load
    /// meta.json; this test hard-pins the arithmetic without artifacts).
    #[test]
    fn known_text_feature_stats() {
        let v = featurize("patient john doe ssn 123-45-6789 diagnosed with diabetes");
        let nnz = v.iter().filter(|&&x| x > 0.0).count();
        // 55 bytes -> 54 bigrams + 53 trigrams = 107 grams; some collide
        assert!(nnz > 60 && nnz < 108, "nnz={nnz}");
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-5);
    }
}
