//! PJRT engine: loads the AOT HLO-text artifacts and serves them from a
//! dedicated thread.
//!
//! The `xla` crate wrappers hold raw pointers (not `Send`), so the client
//! and compiled executables are confined to one engine thread; the rest of
//! the coordinator talks to it through a cloneable [`EngineHandle`]
//! (channel-based, like a driver thread for an accelerator). This matches
//! the deployment model: one compiled executable per model variant, shared
//! by every in-process island executor.
//!
//! Offline builds: the engine-thread internals need the external `xla`
//! crate, which this image does not ship. They compile only under
//! `--cfg islandrun_pjrt` (add the `xla` dependency to Cargo.toml when
//! enabling it). Without the cfg, [`Engine::load`] fails fast with a clear
//! error and every caller falls back to the Sim backend — the handle types,
//! the job protocol and the batch-variant picker stay compiled and tested
//! either way.
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`, with
//! `to_tuple1()` unwrapping (artifacts are lowered with return_tuple=True).

use std::path::Path;
use std::sync::mpsc;

use crate::runtime::meta::Meta;

/// Result of generating for one prompt.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub text: String,
    pub tokens_generated: usize,
    /// Pure PJRT compute time attributed to this prompt (ms).
    pub compute_ms: f64,
}

/// Classifier output: per-class probabilities.
pub type ClassProbs = Vec<f32>;

enum Job {
    Generate { prompts: Vec<String>, max_new_tokens: usize, reply: mpsc::Sender<anyhow::Result<Vec<GenResult>>> },
    Classify { texts: Vec<String>, reply: mpsc::Sender<anyhow::Result<Vec<ClassProbs>>> },
    Embed { texts: Vec<String>, reply: mpsc::Sender<anyhow::Result<Vec<Vec<f32>>>> },
    /// Single raw forward pass (bench hook): returns wall ms.
    RawForward { batch: usize, reply: mpsc::Sender<anyhow::Result<f64>> },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
    meta: Meta,
}

/// Owns the engine thread; dropping shuts it down.
pub struct Engine {
    handle: EngineHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Load all artifacts from `dir` and spin up the engine thread.
    /// Fails fast if artifacts are missing (run `make artifacts`) or when
    /// the crate was built without `--cfg islandrun_pjrt`.
    pub fn load(dir: &Path) -> anyhow::Result<Engine> {
        #[cfg(islandrun_pjrt)]
        {
            real::load(dir)
        }
        #[cfg(not(islandrun_pjrt))]
        {
            anyhow::bail!(
                "built without the PJRT engine (--cfg islandrun_pjrt); cannot serve artifacts from {}",
                dir.display()
            )
        }
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn meta(&self) -> &Meta {
        &self.handle.meta
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    /// Greedy-decode `max_new_tokens` for each prompt (dynamic batching over
    /// the compiled variants happens engine-side).
    pub fn generate(&self, prompts: Vec<String>, max_new_tokens: usize) -> anyhow::Result<Vec<GenResult>> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Job::Generate { prompts, max_new_tokens, reply }).map_err(|_| anyhow::anyhow!("engine down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?
    }

    /// MIST Stage-2: class probabilities for each text.
    pub fn classify(&self, texts: Vec<String>) -> anyhow::Result<Vec<ClassProbs>> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Job::Classify { texts, reply }).map_err(|_| anyhow::anyhow!("engine down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?
    }

    /// Unit-norm embeddings for each text (vector-store substrate).
    pub fn embed(&self, texts: Vec<String>) -> anyhow::Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Job::Embed { texts, reply }).map_err(|_| anyhow::anyhow!("engine down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?
    }

    /// One raw LM forward at the given batch variant; returns wall ms
    /// (bench/perf hook).
    pub fn raw_forward(&self, batch: usize) -> anyhow::Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Job::RawForward { batch, reply }).map_err(|_| anyhow::anyhow!("engine down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?
    }
}

/// Pick the smallest compiled batch variant that fits `n` rows, or the
/// largest variant for chunking when n exceeds it. (Shape-based fallback
/// when no calibration data exists.)
#[cfg_attr(not(islandrun_pjrt), allow(dead_code))]
fn pick_variant(variants: &[usize], n: usize) -> usize {
    let max = variants.iter().max().copied().unwrap_or(1);
    for &v in variants {
        if v >= n {
            return v;
        }
    }
    max
}

// ---------------------------------------------------------------------------
// Engine thread internals (compiled only with --cfg islandrun_pjrt)
// ---------------------------------------------------------------------------

#[cfg(islandrun_pjrt)]
mod real {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::sync::mpsc;
    use std::time::Instant;

    use super::{pick_variant, ClassProbs, Engine, EngineHandle, GenResult, Job};
    use crate::runtime::meta::Meta;
    use crate::substrate::tokenizer;

    pub(super) fn load(dir: &Path) -> anyhow::Result<Engine> {
        let meta = Meta::load(dir)?;
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let meta2 = meta.clone();
        let join = std::thread::Builder::new()
            .name("islandrun-pjrt".to_string())
            .spawn(move || engine_main(dir, meta2, rx, ready_tx))
            .map_err(|e| anyhow::anyhow!("spawn pjrt engine thread: {e}"))?;
        ready_rx.recv().map_err(|_| anyhow::anyhow!("pjrt engine thread died during init"))??;
        Ok(Engine { handle: EngineHandle { tx, meta }, join: Some(join) })
    }

    struct Loaded {
        meta: Meta,
        lm: BTreeMap<usize, xla::PjRtLoadedExecutable>,
        classifier: xla::PjRtLoadedExecutable,
        embedder: xla::PjRtLoadedExecutable,
        /// Calibrated per-forward wall ms for each compiled batch variant.
        /// On multi-core backends larger variants amortize; on a 1-vCPU CPU
        /// client they can be *slower per row* — the adaptive picker uses the
        /// measured costs instead of assuming (§Perf iteration log).
        variant_ms: BTreeMap<usize, f64>,
    }

    fn compile_one(client: &xla::PjRtClient, path: &PathBuf) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("load {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
    }

    fn engine_main(dir: PathBuf, meta: Meta, rx: mpsc::Receiver<Job>, ready: mpsc::Sender<anyhow::Result<()>>) {
        let loaded = (|| -> anyhow::Result<(xla::PjRtClient, Loaded)> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
            let mut lm = BTreeMap::new();
            for &b in &meta.lm_batch_variants {
                lm.insert(b, compile_one(&client, &dir.join(format!("lm_b{b}.hlo.txt")))?);
            }
            let classifier = compile_one(&client, &dir.join("classifier.hlo.txt"))?;
            let embedder = compile_one(&client, &dir.join("embedder.hlo.txt"))?;
            let mut loaded = Loaded { meta, lm, classifier, embedder, variant_ms: BTreeMap::new() };
            loaded.variant_ms = calibrate_variants(&loaded)?;
            Ok((client, loaded))
        })();

        let (_client, loaded) = match loaded {
            Ok(x) => {
                let _ = ready.send(Ok(()));
                x
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };

        while let Ok(job) = rx.recv() {
            match job {
                Job::Shutdown => break,
                Job::Generate { prompts, max_new_tokens, reply } => {
                    let _ = reply.send(generate(&loaded, &prompts, max_new_tokens));
                }
                Job::Classify { texts, reply } => {
                    let _ = reply.send(classify(&loaded, &texts));
                }
                Job::Embed { texts, reply } => {
                    let _ = reply.send(embed(&loaded, &texts));
                }
                Job::RawForward { batch, reply } => {
                    let _ = reply.send(raw_forward(&loaded, batch));
                }
            }
        }
    }

    /// Measure per-forward wall time of every compiled variant (2 warmup + 3
    /// timed). Runs once at engine startup; total cost ~100 ms.
    fn calibrate_variants(loaded: &Loaded) -> anyhow::Result<BTreeMap<usize, f64>> {
        let mut out = BTreeMap::new();
        for (&b, _) in &loaded.lm {
            let tokens = vec![65i32; b * loaded.meta.seq_len];
            for _ in 0..2 {
                run_lm(loaded, &tokens, b)?;
            }
            let t0 = Instant::now();
            for _ in 0..3 {
                run_lm(loaded, &tokens, b)?;
            }
            out.insert(b, t0.elapsed().as_secs_f64() * 1e3 / 3.0);
        }
        Ok(out)
    }

    /// Adaptive variant choice: minimize measured ms per *useful* row for the
    /// next chunk of `n_remaining` prompts. Falls back to shape-based picking
    /// without calibration data.
    fn pick_variant_adaptive(loaded: &Loaded, n_remaining: usize) -> usize {
        if loaded.variant_ms.is_empty() {
            return pick_variant(&loaded.meta.lm_batch_variants, n_remaining);
        }
        loaded
            .variant_ms
            .iter()
            .min_by(|(va, ca), (vb, cb)| {
                let ea = *ca / (n_remaining.min(**va) as f64);
                let eb = *cb / (n_remaining.min(**vb) as f64);
                ea.total_cmp(&eb)
            })
            .map(|(&v, _)| v)
            .unwrap_or_else(|| pick_variant(&loaded.meta.lm_batch_variants, n_remaining))
    }

    fn run_lm(loaded: &Loaded, tokens: &[i32], batch: usize) -> anyhow::Result<Vec<f32>> {
        let exe = loaded.lm.get(&batch).ok_or_else(|| anyhow::anyhow!("no lm variant b{batch}"))?;
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, loaded.meta.seq_len as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
        let result = exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        let logits = out.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        logits.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    fn generate(loaded: &Loaded, prompts: &[String], max_new_tokens: usize) -> anyhow::Result<Vec<GenResult>> {
        let seq = loaded.meta.seq_len;
        let vocab = loaded.meta.vocab;
        let mut results = Vec::with_capacity(prompts.len());

        // process prompts in chunks sized by the adaptive variant picker:
        // measured ms-per-useful-row, not assumed batching gains (§Perf)
        let mut remaining: &[String] = prompts;
        while !remaining.is_empty() {
            let b = pick_variant_adaptive(loaded, remaining.len());
            let chunk = &remaining[..remaining.len().min(b)];
            remaining = &remaining[chunk.len()..];
            let mut windows: Vec<Vec<i32>> = Vec::with_capacity(b);
            let mut reals: Vec<usize> = Vec::with_capacity(b);
            for p in chunk {
                windows.push(tokenizer::encode_fixed(p, seq));
                reals.push(tokenizer::real_len(p, seq));
            }
            // pad rows up to the variant size
            while windows.len() < b {
                windows.push(vec![tokenizer::PAD as i32; seq]);
                reals.push(1);
            }
            let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
            let t0 = Instant::now();
            for _ in 0..max_new_tokens {
                let flat: Vec<i32> = windows.iter().flatten().copied().collect();
                let logits = run_lm(loaded, &flat, b)?;
                for row in 0..chunk.len() {
                    let pos = reals[row].saturating_sub(1).min(seq - 1);
                    let base = row * seq * vocab + pos * vocab;
                    let slice = &logits[base..base + vocab];
                    // greedy argmax, skipping PAD so decode never stalls on filler
                    let mut best = 1usize;
                    let mut best_v = f32::NEG_INFINITY;
                    for (i, &v) in slice.iter().enumerate() {
                        if i == tokenizer::PAD as usize {
                            continue;
                        }
                        if v > best_v {
                            best_v = v;
                            best = i;
                        }
                    }
                    generated[row].push(best as i32);
                    let mut real = reals[row];
                    tokenizer::push_token(&mut windows[row], &mut real, best as i32);
                    reals[row] = real;
                }
            }
            let total_ms = t0.elapsed().as_secs_f64() * 1e3;
            let per_prompt = total_ms / chunk.len() as f64;
            for row in 0..chunk.len() {
                results.push(GenResult {
                    text: tokenizer::decode(&generated[row]),
                    tokens_generated: generated[row].len(),
                    compute_ms: per_prompt,
                });
            }
        }
        Ok(results)
    }

    fn run_feat_model(
        exe: &xla::PjRtLoadedExecutable,
        feats: &[f32],
        batch: usize,
        feat_dim: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let lit = xla::Literal::vec1(feats)
            .reshape(&[batch as i64, feat_dim as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
        let result = exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        let t = out.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        t.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    fn batched_feat_pass(
        loaded: &Loaded,
        texts: &[String],
        exe: &xla::PjRtLoadedExecutable,
        out_dim: usize,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let fb = loaded.meta.cls_batch;
        let fd = loaded.meta.feat_dim;
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(fb) {
            let mut feats = Vec::with_capacity(fb * fd);
            for t in chunk {
                feats.extend(crate::runtime::features::featurize(t));
            }
            feats.resize(fb * fd, 0.0);
            let res = run_feat_model(exe, &feats, fb, fd)?;
            for row in 0..chunk.len() {
                out.push(res[row * out_dim..(row + 1) * out_dim].to_vec());
            }
        }
        Ok(out)
    }

    fn classify(loaded: &Loaded, texts: &[String]) -> anyhow::Result<Vec<ClassProbs>> {
        let logits = batched_feat_pass(loaded, texts, &loaded.classifier, loaded.meta.n_classes)?;
        // softmax over logits (artifact emits raw logits)
        Ok(logits
            .into_iter()
            .map(|row| {
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = row.iter().map(|x| (x - m).exp()).collect();
                let s: f32 = exps.iter().sum();
                exps.into_iter().map(|x| x / s).collect()
            })
            .collect())
    }

    fn embed(loaded: &Loaded, texts: &[String]) -> anyhow::Result<Vec<Vec<f32>>> {
        batched_feat_pass(loaded, texts, &loaded.embedder, loaded.meta.embed_dim)
    }

    fn raw_forward(loaded: &Loaded, batch: usize) -> anyhow::Result<f64> {
        let b = pick_variant(&loaded.meta.lm_batch_variants, batch);
        let tokens = vec![65i32; b * loaded.meta.seq_len];
        let t0 = Instant::now();
        run_lm(loaded, &tokens, b)?;
        Ok(t0.elapsed().as_secs_f64() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_variant_logic() {
        let v = vec![1, 4, 8];
        assert_eq!(pick_variant(&v, 1), 1);
        assert_eq!(pick_variant(&v, 2), 4);
        assert_eq!(pick_variant(&v, 4), 4);
        assert_eq!(pick_variant(&v, 5), 8);
        assert_eq!(pick_variant(&v, 100), 8); // chunking case
    }

    #[cfg(not(islandrun_pjrt))]
    #[test]
    fn load_without_engine_fails_fast_with_clear_error() {
        let err = Engine::load(std::path::Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("islandrun_pjrt"), "{err}");
    }

    // Engine integration tests live in rust/tests/integration_e2e.rs (they
    // need built artifacts); unit scope here is the pure logic above.
}
