//! Runtime layer: PJRT artifact loading + serving primitives.
//!
//! - [`pjrt`]     — engine thread owning the PJRT client and compiled HLO
//!   executables (TinyLM batch variants, classifier, embedder)
//! - [`features`] — hashed n-gram featurizer (mirrors the python trainer)
//! - [`meta`]     — artifacts/meta.json contract
//! - [`batcher`]  — dynamic batching policy for generation requests
//! - [`steploop`] — per-island lanes feeding the continuous (decode-step)
//!   batching driver on the serving path
//!
//! Python never runs here: artifacts are HLO text produced once by
//! `python/compile/aot.py` (see DESIGN.md §1).

pub mod batcher;
pub mod features;
pub mod meta;
pub mod pjrt;
pub mod steploop;

pub use batcher::{chunk_by_policy, BatchMode, BatchPolicy, Batcher};
pub use steploop::StepLanes;
pub use meta::Meta;
pub use pjrt::{Engine, EngineHandle, GenResult};
