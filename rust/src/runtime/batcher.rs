//! Dynamic batcher: groups queued generation requests into the compiled
//! batch variants (B ∈ {1,4,8}) to amortize PJRT dispatch.
//!
//! Policy: wait up to `max_wait_ms` for the queue to fill the largest
//! variant; on timeout, flush whatever is pending into the smallest variant
//! that fits. This is the classic serving tradeoff (latency vs occupancy)
//! and is ablated in `benches/e2e_serving.rs`.

use std::time::{Duration, Instant};

/// A queued generation item (opaque payload `T` travels with it).
#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// How a formed batch executes on the island.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    /// Run-to-completion: each popped chunk executes whole requests in one
    /// shot (the pre-continuous behavior; still what the Real backend's
    /// `execute_batch` does).
    Coalesce,
    /// Decode-step granularity: the per-island step loop interleaves
    /// `decode_step` calls across the in-flight batch and admits newly
    /// routed requests between steps (sim backend).
    Continuous,
}

/// Batching policy configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Compiled batch-size variants, ascending (from meta.json). On the
    /// continuous path this caps the in-flight decode batch per island.
    pub max_batch: usize,
    /// Max time the oldest item may wait before a forced flush.
    pub max_wait: Duration,
    /// Continuous mode: tokens decoded per request per step-loop round
    /// before the loop re-checks admissions, deadlines and cancels.
    pub decode_chunk: usize,
    /// Execution mode for formed batches.
    pub mode: BatchMode,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), decode_chunk: 4, mode: BatchMode::Continuous }
    }
}

/// Accumulates items and decides when a batch should be released.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: Vec<Pending<T>>,
    policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { queue: Vec::new(), policy }
    }

    pub fn push(&mut self, payload: T) {
        self.queue.push(Pending { payload, enqueued: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should we flush now? True when the queue fills the largest variant or
    /// the oldest item has waited past the deadline.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.first() {
            Some(p) => now.duration_since(p.enqueued) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Remove and return up to `max_batch` items (FIFO).
    pub fn take_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).map(|p| p.payload).collect()
    }

    /// Drain everything regardless of policy (shutdown).
    pub fn drain_all(&mut self) -> Vec<T> {
        self.queue.drain(..).map(|p| p.payload).collect()
    }
}

/// Split an already-collected group into policy-sized FIFO chunks
/// (`<= max_batch` each) without standing up a live queue. The
/// orchestrator's coalescing paths — `submit_many_requests` and the admission-queue
/// drain — group co-routed requests per island and chunk each group this
/// way before dispatching one `execute_batch` per chunk.
pub fn chunk_by_policy<T>(items: Vec<T>, policy: BatchPolicy) -> Vec<Vec<T>> {
    let max = policy.max_batch.max(1);
    let mut out = Vec::with_capacity((items.len() + max - 1) / max);
    let mut cur: Vec<T> = Vec::with_capacity(max.min(items.len()));
    for item in items {
        cur.push(item);
        if cur.len() == max {
            out.push(std::mem::replace(&mut cur, Vec::with_capacity(max)));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10), ..BatchPolicy::default() };
        let mut b = Batcher::new(policy);
        for i in 0..3 {
            b.push(i);
        }
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_timeout() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), ..BatchPolicy::default() };
        let mut b = Batcher::new(policy);
        b.push("x");
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["x"]);
    }

    #[test]
    fn take_batch_caps_at_max() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), ..BatchPolicy::default() };
        let mut b = Batcher::new(policy);
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.drain_all(), vec![2, 3, 4]);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn fifo_order_preserved() {
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(0), ..BatchPolicy::default() };
        let mut b = Batcher::new(policy);
        for i in 0..3 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1, 2]);
    }

    #[test]
    fn chunk_by_policy_splits_fifo_groups() {
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(1), ..BatchPolicy::default() };
        let chunks = chunk_by_policy((0..7).collect(), policy);
        assert_eq!(chunks, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
        let empty: Vec<Vec<u32>> = chunk_by_policy(Vec::new(), policy);
        assert!(empty.is_empty());
        // degenerate max_batch=0 is clamped to 1 rather than looping forever
        let degenerate = BatchPolicy { max_batch: 0, max_wait: Duration::from_millis(1), ..BatchPolicy::default() };
        let ones = chunk_by_policy(vec![1, 2], degenerate);
        assert_eq!(ones, vec![vec![1], vec![2]]);
    }
}
