//! Loader for `artifacts/meta.json` — the contract between the AOT compile
//! path (python) and the rust runtime.

use std::path::Path;

use crate::config::json::Json;

/// Parsed artifact metadata.
#[derive(Clone, Debug)]
pub struct Meta {
    pub vocab: usize,
    pub seq_len: usize,
    pub feat_dim: usize,
    pub n_classes: usize,
    pub embed_dim: usize,
    pub lm_batch_variants: Vec<usize>,
    pub cls_batch: usize,
    /// Sensitivity score for each classifier class (public/internal/
    /// confidential/restricted → 0.2/0.5/0.8/1.0).
    pub class_sensitivity: Vec<f64>,
    pub classifier_val_acc: f64,
    /// (step, loss) pairs recorded at AOT time.
    pub lm_loss_curve: Vec<(u64, f64)>,
    pub golden: Vec<Golden>,
}

/// Cross-language golden vector (see runtime::features).
#[derive(Clone, Debug)]
pub struct Golden {
    pub text: String,
    pub feat_nonzero_idx: Vec<usize>,
    pub feat_nonzero_val: Vec<f64>,
    pub class_argmax: usize,
    pub emb_head: Vec<f64>,
}

impl Meta {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Meta> {
        let path = artifacts_dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(Meta::from_json(&v)?)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Meta> {
        let usize_field = |name: &str| -> anyhow::Result<usize> {
            v.get(name).as_i64().map(|x| x as usize).ok_or_else(|| anyhow::anyhow!("meta.json missing {name}"))
        };
        let golden = v
            .get("golden")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|g| Golden {
                text: g.get("text").as_str().unwrap_or("").to_string(),
                feat_nonzero_idx: g
                    .get("feat_nonzero_idx")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_i64().map(|i| i as usize))
                    .collect(),
                feat_nonzero_val: g
                    .get("feat_nonzero_val")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .collect(),
                class_argmax: g.get("class_argmax").as_i64().unwrap_or(0) as usize,
                emb_head: g.get("emb_head").as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_f64()).collect(),
            })
            .collect();
        Ok(Meta {
            vocab: usize_field("vocab")?,
            seq_len: usize_field("seq_len")?,
            feat_dim: usize_field("feat_dim")?,
            n_classes: usize_field("n_classes")?,
            embed_dim: usize_field("embed_dim")?,
            lm_batch_variants: v
                .get("lm_batch_variants")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_i64().map(|i| i as usize))
                .collect(),
            cls_batch: usize_field("cls_batch")?,
            class_sensitivity: v.get("class_sensitivity").as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_f64()).collect(),
            classifier_val_acc: v.get("classifier_val_acc").as_f64().unwrap_or(0.0),
            lm_loss_curve: v
                .get("lm_loss_curve")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|p| Some((p.idx(0).as_i64()? as u64, p.idx(1).as_f64()?)))
                .collect(),
            golden,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "vocab": 256, "seq_len": 64, "d_model": 64, "n_heads": 4, "n_layers": 2,
      "feat_dim": 512, "ngram_sizes": [2,3], "n_classes": 4, "embed_dim": 64,
      "lm_batch_variants": [1,4,8], "cls_batch": 8,
      "class_sensitivity": [0.2,0.5,0.8,1.0],
      "lm_loss_curve": [[0, 5.56],[19, 3.85]],
      "classifier_train_acc": 1.0, "classifier_val_acc": 0.99,
      "golden": [{"text":"x","feat_nonzero_idx":[3,5],"feat_nonzero_val":[0.5,0.5],
                  "class_argmax":2,"emb_head":[0.1,-0.2]}]
    }"#;

    #[test]
    fn parses_sample() {
        let v = Json::parse(SAMPLE).unwrap();
        let m = Meta::from_json(&v).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.lm_batch_variants, vec![1, 4, 8]);
        assert_eq!(m.class_sensitivity, vec![0.2, 0.5, 0.8, 1.0]);
        assert_eq!(m.lm_loss_curve[1], (19, 3.85));
        assert_eq!(m.golden[0].class_argmax, 2);
        assert_eq!(m.golden[0].feat_nonzero_idx, vec![3, 5]);
    }

    #[test]
    fn missing_field_is_error() {
        let v = Json::parse(r#"{"vocab": 256}"#).unwrap();
        assert!(Meta::from_json(&v).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = std::path::Path::new("artifacts");
        if dir.join("meta.json").exists() {
            let m = Meta::load(dir).unwrap();
            assert_eq!(m.seq_len, 64);
            assert!(m.classifier_val_acc > 0.8);
            assert_eq!(m.golden.len(), 3);
        }
    }
}
