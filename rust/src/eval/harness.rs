//! Trace-driven evaluation harness: runs a routing [`Policy`] over a
//! workload trace against a simulated [`Fleet`], collecting the §XI metrics
//! every experiment reports (privacy violations, cost, latency distribution,
//! local-execution share, failures).

use crate::baselines::{Policy, PolicyDecision};
use crate::substrate::trace::{SensClass, TraceItem};
use crate::types::TrustTier;
use crate::util::stats;
use crate::islands::Fleet;

/// Aggregated results of one (policy, trace) run.
#[derive(Clone, Debug)]
pub struct PolicyStats {
    pub policy: &'static str,
    pub requests: usize,
    /// Requests executed on an island with `privacy < truth score`.
    pub privacy_violations: usize,
    /// Fail-closed (or policy) rejections.
    pub rejections: usize,
    /// Requests whose total latency exceeded their deadline.
    pub deadline_misses: usize,
    pub total_cost: f64,
    /// Fraction executed on Tier-1 personal islands.
    pub local_share: f64,
    pub latencies_ms: Vec<f64>,
    /// Latencies split by ground-truth class (for E4 tier bands).
    pub latencies_by_class: [Vec<f64>; 3],
    /// Mean queueing delay (ms).
    pub mean_queue_ms: f64,
}

impl PolicyStats {
    pub fn p(&self, q: f64) -> f64 {
        stats::percentile(&self.latencies_ms, q)
    }

    pub fn cost_per_1k(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_cost * 1000.0 / self.requests as f64
        }
    }

    pub fn violation_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.privacy_violations as f64 / self.requests as f64
        }
    }
}

fn class_index(c: SensClass) -> usize {
    match c {
        SensClass::Low => 0,
        SensClass::Moderate => 1,
        SensClass::High => 2,
    }
}

/// Options controlling a harness run.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    /// Mean inter-arrival time between requests (virtual ms).
    pub interarrival_ms: f64,
    /// Sensitivity source: true = use ground truth (isolates routing from
    /// classifier error), false = MIST heuristic.
    pub oracle_sensitivity: bool,
    /// Added per-request latency when island discovery is broken (E6
    /// "No LIGHTHOUSE: re-discovers islands per request").
    pub discovery_penalty_ms: f64,
    /// Override: sensitivity fed to the policy is forced to this value
    /// (E6 "No MIST" ablation feeds 0.0 — blind routing).
    pub force_s_r: Option<f64>,
    /// Override: capacity fed to the policy is forced to this value (E6
    /// "No TIDE" ablation feeds 1.0 — blind to exhaustion).
    pub force_capacity: Option<f64>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            interarrival_ms: 50.0,
            oracle_sensitivity: true,
            discovery_penalty_ms: 0.0,
            force_s_r: None,
            force_capacity: None,
        }
    }
}

/// Drive `policy` over `trace` against a fresh fleet of `specs`.
pub fn run_policy(
    policy: &mut dyn Policy,
    trace: &[TraceItem],
    specs: Vec<crate::types::Island>,
    seed: u64,
    opts: RunOpts,
) -> PolicyStats {
    let mist = crate::agents::mist::Mist::heuristic();
    let fleet = Fleet::new(specs, seed);
    let mut st = PolicyStats {
        policy: "",
        requests: trace.len(),
        privacy_violations: 0,
        rejections: 0,
        deadline_misses: 0,
        total_cost: 0.0,
        local_share: 0.0,
        latencies_ms: Vec::with_capacity(trace.len()),
        latencies_by_class: [Vec::new(), Vec::new(), Vec::new()],
        mean_queue_ms: 0.0,
    };
    st.policy = policy.name();

    let mut local_count = 0usize;
    let mut queue_sum = 0.0;
    let mut executed = 0usize;

    for item in trace {
        fleet.advance(opts.interarrival_ms);
        let truth = item.truth.score();
        let s_r = opts.force_s_r.unwrap_or(if opts.oracle_sensitivity {
            truth
        } else {
            mist.analyze(&item.request).score
        });
        let mut states = fleet.states();
        if let Some(c) = opts.force_capacity {
            for s in states.iter_mut() {
                s.capacity = c;
            }
        }
        let local_capacity = opts.force_capacity.unwrap_or(fleet.local_capacity());

        match policy.route(&item.request, s_r, &states, local_capacity) {
            PolicyDecision::Reject => {
                st.rejections += 1;
            }
            PolicyDecision::Island(id) => {
                let island = fleet.get(id).expect("policy chose a known island").spec.clone();
                if island.privacy < truth {
                    st.privacy_violations += 1;
                }
                if island.tier == TrustTier::Personal {
                    local_count += 1;
                }
                let rep = fleet.execute(id, &item.request).unwrap();
                let latency = rep.latency_ms + opts.discovery_penalty_ms;
                st.total_cost += rep.cost;
                queue_sum += rep.queued_ms;
                executed += 1;
                if latency > item.request.deadline_ms {
                    st.deadline_misses += 1;
                }
                st.latencies_ms.push(latency);
                st.latencies_by_class[class_index(item.truth)].push(latency);
            }
        }
    }
    if executed > 0 {
        st.local_share = local_count as f64 / executed as f64;
        st.mean_queue_ms = queue_sum / executed as f64;
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{CloudOnly, IslandRunPolicy, LatencyGreedy};
    use crate::config::{preset_personal_group, Config};
    use crate::substrate::trace::paper_mix;

    #[test]
    fn cloud_only_violates_all_high_sensitivity() {
        let trace = paper_mix(200, 1);
        let st = run_policy(&mut CloudOnly, &trace, preset_personal_group(), 1, RunOpts::default());
        // 40% high (0.9 > cloud 0.3/0.4) + 35% moderate (0.5 > 0.4) = 75%
        assert_eq!(st.privacy_violations, 150, "{st:?}");
        assert!(st.total_cost > 0.0);
    }

    #[test]
    fn islandrun_zero_violations() {
        let trace = paper_mix(200, 2);
        let mut p = IslandRunPolicy::new(Config::default());
        let st = run_policy(&mut p, &trace, preset_personal_group(), 2, RunOpts::default());
        assert_eq!(st.privacy_violations, 0, "{st:?}");
        assert_eq!(st.rejections, 0);
    }

    #[test]
    fn latency_greedy_fast_but_dirty() {
        // fast arrivals saturate the personal devices: latency-greedy then
        // falls through to low-privacy islands and violates
        let trace = paper_mix(600, 3);
        let opts = RunOpts { interarrival_ms: 3.0, ..RunOpts::default() };
        let grd = run_policy(&mut LatencyGreedy, &trace, preset_personal_group(), 3, opts);
        let mut ir = IslandRunPolicy::new(Config::default());
        let isr = run_policy(&mut ir, &trace, preset_personal_group(), 3, opts);
        assert!(grd.privacy_violations > 0, "{grd:?}");
        assert_eq!(isr.privacy_violations, 0);
    }

    #[test]
    fn stats_helpers() {
        let trace = paper_mix(100, 4);
        let mut p = IslandRunPolicy::new(Config::default());
        let st = run_policy(&mut p, &trace, preset_personal_group(), 4, RunOpts::default());
        assert!(st.p(0.5) > 0.0);
        assert!(st.p(0.99) >= st.p(0.5));
        assert!(st.local_share > 0.0);
    }
}
