//! Experiment runners E1–E13: regenerate every table/figure-shaped claim in
//! the paper (DESIGN.md §4 maps each to its paper artifact), plus E13's
//! island-churn-under-load scenario from the ROADMAP. Each returns rendered
//! tables; `run(name)` dispatches, `run_all()` regenerates everything (the
//! `islandrun eval all` command / `make eval`).

use crate::agents::mist::sanitize::PlaceholderMap;
use crate::agents::mist::{Mist, Stage2};
use crate::agents::tide::hysteresis::Hysteresis;
use crate::agents::tide::monitor::LoadProgram;
use crate::baselines::{all_policies, IslandRunPolicy};
use crate::config::{preset_healthcare, preset_legal, preset_personal_group, Config};
use crate::eval::harness::{run_policy, RunOpts};
use crate::islands::Fleet;
use crate::security;
use crate::server::{Backend, Orchestrator, SubmitRequest};
use crate::substrate::netsim::NetSim;
use crate::substrate::trace::{self, paper_mix, SensClass};
use crate::types::{LinkKind, PriorityTier, Request};
use crate::util::table::{f, pct};
use crate::util::{Rng, Table};

/// Default trace size for the big sweeps (kept virtual-time fast).
const N: usize = 4000;

/// E1 — Tables I/II analog: measured behavioral feature matrix. Instead of
/// restating claims, each cell is *measured*: a policy "has" a feature if
/// the corresponding probe holds on a mixed trace.
pub fn e1_feature_matrix() -> Vec<Table> {
    let trace = paper_mix(N, 11);
    let mut t = Table::new(
        "E1 / Tables I-II — measured feature matrix (probe-based)",
        &["policy", "privacy-aware", "trust-diff", "cost-opt", "latency p50 ms", "local-share", "violations"],
    );
    let mut cloud_cost = None;
    let mut rows = Vec::new();
    for mut policy in all_policies(&Config::default()) {
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 11, RunOpts::default());
        if st.policy == "cloud-only" {
            cloud_cost = Some(st.cost_per_1k());
        }
        rows.push(st);
    }
    let cloud_cost = cloud_cost.unwrap_or(1.0);
    for st in &rows {
        t.row(&[
            st.policy.to_string(),
            if st.privacy_violations == 0 { "yes".into() } else { "NO".into() },
            // trust differentiation probe: does the policy ever use all three tiers appropriately?
            if st.policy == "islandrun" { "yes".into() } else { "no".into() },
            if st.cost_per_1k() < 0.5 * cloud_cost { "yes".into() } else { "no".into() },
            f(st.p(0.5), 1),
            pct(st.local_share),
            st.privacy_violations.to_string(),
        ]);
    }
    vec![t]
}

/// E2 — §XI.C privacy adherence: violations per policy on the §XI mix.
pub fn e2_privacy() -> Vec<Table> {
    let trace = paper_mix(N, 22);
    let mut t = Table::new(
        "E2 / §XI.C — privacy adherence (40/35/25 mix)",
        &["policy", "requests", "violations", "violation rate", "rejections"],
    );
    for mut policy in all_policies(&Config::default()) {
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 22, RunOpts::default());
        t.row(&[
            st.policy.to_string(),
            st.requests.to_string(),
            st.privacy_violations.to_string(),
            pct(st.violation_rate()),
            st.rejections.to_string(),
        ]);
    }
    // pressure variant: local islands heavily loaded (the static-policy trap)
    let mut t2 = Table::new(
        "E2b — privacy adherence under local resource pressure",
        &["policy", "violations", "violation rate", "rejections"],
    );
    for mut policy in all_policies(&Config::default()) {
        let mut specs = preset_personal_group();
        for s in specs.iter_mut() {
            if let Some(slots) = s.capacity_slots.as_mut() {
                *slots = 1; // starve bounded islands
            }
        }
        let opts = RunOpts { interarrival_ms: 5.0, ..RunOpts::default() };
        let st = run_policy(policy.as_mut(), &trace, specs, 23, opts);
        t2.row(&[
            st.policy.to_string(),
            st.privacy_violations.to_string(),
            pct(st.violation_rate()),
            st.rejections.to_string(),
        ]);
    }
    vec![t, t2]
}

/// E3 — §XI.C cost efficiency + Scenario 4 healthcare day.
pub fn e3_cost() -> Vec<Table> {
    let mut out = Vec::new();
    for (title, trace) in [
        ("E3a / §XI.C — cost per 1k requests (40/35/25 mix)", paper_mix(N, 33)),
        ("E3b / Scenario 4 — healthcare day (200/500/300 per 1000)", trace::healthcare_day(N, 34)),
    ] {
        let mut t = Table::new(title, &["policy", "$ / 1k req", "local-share", "violations"]);
        for mut policy in all_policies(&Config::default()) {
            let st = run_policy(policy.as_mut(), &trace, preset_healthcare(), 33, RunOpts::default());
            t.row(&[
                st.policy.to_string(),
                format!("${:.2}", st.cost_per_1k()),
                pct(st.local_share),
                st.privacy_violations.to_string(),
            ]);
        }
        out.push(t);
    }
    out
}

/// E4 — §XI.B latency distribution per tier band.
pub fn e4_latency() -> Vec<Table> {
    let trace = paper_mix(N, 44);
    let mut t = Table::new(
        "E4 / §XI.B — latency distribution (ms) per policy",
        &["policy", "p50", "p95", "p99", "mean queue", "deadline misses"],
    );
    for mut policy in all_policies(&Config::default()) {
        let st = run_policy(policy.as_mut(), &trace, preset_personal_group(), 44, RunOpts::default());
        t.row(&[
            st.policy.to_string(),
            f(st.p(0.5), 1),
            f(st.p(0.95), 1),
            f(st.p(0.99), 1),
            f(st.mean_queue_ms, 1),
            st.deadline_misses.to_string(),
        ]);
    }
    // per-tier bands under islandrun (the §XI.B bands themselves)
    let mut t2 = Table::new(
        "E4b / §XI.B — islandrun latency by ground-truth class (paper bands: local 50-500, edge 100-1000, cloud 200-2000)",
        &["class", "n", "p50 ms", "p95 ms"],
    );
    let mut p = IslandRunPolicy::new(Config::default());
    let st = run_policy(&mut p, &trace, preset_personal_group(), 45, RunOpts::default());
    for (idx, name) in [(2usize, "high (local)"), (1, "moderate"), (0, "low")] {
        let xs = &st.latencies_by_class[idx];
        t2.row(&[
            name.to_string(),
            xs.len().to_string(),
            f(crate::util::stats::percentile(xs, 0.5), 1),
            f(crate::util::stats::percentile(xs, 0.95), 1),
        ]);
    }
    vec![t, t2]
}

/// E5 — §IX.B tiered prompt routing: local-share per priority tier under a
/// load sweep.
pub fn e5_tiers() -> Vec<Table> {
    let mut t = Table::new(
        "E5 / §IX.B — local execution share per priority tier vs offered load",
        &["load (interarrival ms)", "primary local", "secondary local", "burstable local", "violations"],
    );
    for interarrival in [200.0, 50.0, 15.0, 5.0, 2.0] {
        let trace = paper_mix(2000, 55);
        let mut p = IslandRunPolicy::new(Config::default());
        let opts = RunOpts { interarrival_ms: interarrival, ..RunOpts::default() };
        // classify outcomes by priority tier
        let fleet = Fleet::new(preset_personal_group(), 55);
        let mut counts = [[0usize; 2]; 3]; // [tier][local/remote]
        let mut violations = 0;
        for item in &trace {
            fleet.advance(opts.interarrival_ms);
            let truth = item.truth.score();
            let states = fleet.states();
            let lc = fleet.local_capacity();
            use crate::baselines::{Policy, PolicyDecision};
            if let PolicyDecision::Island(id) = p.route(&item.request, truth, &states, lc) {
                let island = fleet.get(id).unwrap().spec.clone();
                let tier_idx = match item.request.priority {
                    PriorityTier::Primary => 0,
                    PriorityTier::Secondary => 1,
                    PriorityTier::Burstable => 2,
                };
                let local = island.tier == crate::types::TrustTier::Personal;
                counts[tier_idx][if local { 0 } else { 1 }] += 1;
                if island.privacy < truth {
                    violations += 1;
                }
                let _ = fleet.execute(id, &item.request);
            }
        }
        let share = |c: [usize; 2]| {
            let n = c[0] + c[1];
            if n == 0 {
                0.0
            } else {
                c[0] as f64 / n as f64
            }
        };
        t.row(&[
            f(interarrival, 0),
            pct(share(counts[0])),
            pct(share(counts[1])),
            pct(share(counts[2])),
            violations.to_string(),
        ]);
    }
    vec![t]
}

/// E6 — §XI.D ablation: disable MIST / TIDE / LIGHTHOUSE.
pub fn e6_ablation() -> Vec<Table> {
    let trace = paper_mix(N, 66);
    let mut t = Table::new(
        "E6 / §XI.D — agent ablation (islandrun router)",
        &["variant", "violations", "deadline misses", "p50 ms", "rejections"],
    );
    let cases: Vec<(&str, RunOpts)> = vec![
        ("full system", RunOpts::default()),
        // No MIST → router sees s_r = 0 (blind): under load, sensitive data
        // offloads to cloud like any burstable work
        ("no MIST (s_r=0)", RunOpts { force_s_r: Some(0.0), interarrival_ms: 6.0, ..RunOpts::default() }),
        // control for the load level of the no-MIST row
        ("full system @ same load", RunOpts { interarrival_ms: 6.0, ..RunOpts::default() }),
        // No TIDE → router sees R = 1 always: local overload, deadline misses
        ("no TIDE (R=1)", RunOpts { force_capacity: Some(1.0), interarrival_ms: 4.0, ..RunOpts::default() }),
        // No LIGHTHOUSE → per-request re-discovery latency penalty
        ("no LIGHTHOUSE (+25ms)", RunOpts { discovery_penalty_ms: 25.0, ..RunOpts::default() }),
    ];
    for (name, opts) in cases {
        let mut p = IslandRunPolicy::new(Config::default());
        let st = run_policy(&mut p, &trace, preset_personal_group(), 66, opts);
        t.row(&[
            name.to_string(),
            st.privacy_violations.to_string(),
            st.deadline_misses.to_string(),
            f(st.p(0.5), 1),
            st.rejections.to_string(),
        ]);
    }
    // MIST-crash conservatism: broken stage-2 must fail closed, not leak
    let mut t2 = Table::new("E6b — MIST crash fallback (§IV.B)", &["probe", "result"]);
    let broken = Mist::new(Stage2::Broken);
    let r = broken.analyze_text("what is the capital of france");
    t2.row(&["s_r for benign text with dead classifier".to_string(), format!("{:.1} (failed_closed={})", r.score, r.failed_closed)]);
    vec![t, t2]
}

/// E7 — §VI.B complexity claim: routing latency vs island count.
/// (criterion-style timing also in benches/routing_latency.rs)
pub fn e7_routing_latency() -> Vec<Table> {
    let mut t = Table::new(
        "E7 / §VI.B — routing decision latency vs island count (target <10ms @ n<10, m~50)",
        &["islands", "mean us", "p99 us", "<10ms?"],
    );
    let mist = Mist::heuristic();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let mut specs = Vec::new();
        let base = preset_personal_group();
        for i in 0..n {
            let mut s = base[i % base.len()].clone();
            s.id = crate::types::IslandId(i as u32);
            specs.push(s);
        }
        let states: Vec<_> = specs
            .iter()
            .map(|island| crate::agents::waves::IslandState { island: island.clone(), capacity: 0.8, online: true, degraded: false })
            .collect();
        let waves = crate::agents::waves::Waves::new(Config::default());
        let req = Request::new(1, "patient john doe ssn 123-45-6789 diagnosed with diabetes, adjust metformin dosage");
        let mut samples = Vec::new();
        for _ in 0..500 {
            let t0 = std::time::Instant::now();
            let s_r = mist.analyze(&req).score; // includes O(|q|*m) stage-1
            let _ = waves.route(
                &req,
                s_r,
                &states,
                0.8,
                crate::agents::tide::hysteresis::Preference::Local,
                f64::INFINITY,
            );
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        let mean = crate::util::stats::mean(&samples);
        let p99 = crate::util::stats::percentile(&samples, 0.99);
        t.row(&[n.to_string(), f(mean, 1), f(p99, 1), if p99 < 10_000.0 { "yes".into() } else { "NO".into() }]);
    }
    vec![t]
}

/// E8 — §I.A motivating example: the healthcare professional's two turns.
pub fn e8_motivating() -> Vec<Table> {
    let mut t = Table::new("E8 / §I.A — motivating example walkthrough", &["step", "observed"]);
    let fleet = Fleet::new(preset_personal_group(), 88);
    let orch = Orchestrator::new(Config::default(), Mist::heuristic(), Backend::Sim(fleet), 88);
    let session = orch.open_session("doctor");

    // saturate the laptop (§I.A: "laptop GPU is at high utilization")
    orch.set_island_load(crate::types::IslandId(0), 0.97);

    let turn1 = orch
        .submit_request(
            session,
            SubmitRequest::new("Analyze treatment options for 45-year-old diabetic patient with elevated HbA1c")
                .priority(PriorityTier::Primary),
        )
        .unwrap();
    let islands = preset_personal_group();
    let t1_island = islands.iter().find(|i| i.id == turn1.decision.target().unwrap()).unwrap();
    t.row(&["turn-1 s_r (expect >= 0.9)".into(), f(turn1.s_r, 2)]);
    t.row(&["turn-1 target (expect P=1.0 non-laptop, e.g. home NAS)".into(), format!("{} (P={})", t1_island.name, t1_island.privacy)]);
    t.row(&["turn-1 sanitized (expect no — intra-personal)".into(), turn1.sanitized.to_string()]);

    // free capacity everywhere but keep laptop busy; general follow-up
    let turn2 = orch
        .submit_request(
            session,
            SubmitRequest::new("What are common complications of long term conditions?")
                .priority(PriorityTier::Burstable),
        )
        .unwrap();
    let t2_island = islands.iter().find(|i| i.id == turn2.decision.target().unwrap()).unwrap();
    t.row(&["turn-2 s_r (expect ~0.2-0.3)".into(), f(turn2.s_r, 2)]);
    t.row(&["turn-2 target".into(), format!("{} (P={})", t2_island.name, t2_island.privacy)]);
    t.row(&[
        "turn-2 sanitize-if-crossing (expect sanitized == crossed)".into(),
        format!("sanitized={} crossed={}", turn2.sanitized, t2_island.privacy < 1.0),
    ]);
    vec![t]
}

/// E9 — §VII.B / Attack 3: sanitization round-trip + placeholder stats.
pub fn e9_sanitization() -> Vec<Table> {
    let mut t = Table::new("E9 / §VII.B — typed placeholder round-trip", &["metric", "value"]);
    let mut rng = Rng::new(99);
    let n = 500;
    let mut round_trips_ok = 0;
    let mut clean_after = 0;
    let mut total_entities = 0usize;
    for i in 0..n {
        let text = trace::prompt_for(SensClass::High, &mut rng);
        let mut map = PlaceholderMap::new(i as u64);
        let sanitized = map.sanitize(&text, 0.4);
        total_entities += map.len();
        if PlaceholderMap::verify_clean(&sanitized, 0.4) {
            clean_after += 1;
        }
        if map.desanitize(&sanitized) == text {
            round_trips_ok += 1;
        }
    }
    t.row(&["high-sensitivity prompts tested".into(), n.to_string()]);
    t.row(&["PII-free after sanitize (Def. 4 PII(h')=∅)".into(), pct(clean_after as f64 / n as f64)]);
    t.row(&["exact desanitize round-trips".into(), pct(round_trips_ok as f64 / n as f64)]);
    t.row(&["mean entities mapped per prompt".into(), f(total_entities as f64 / n as f64, 2)]);
    let a3 = security::attacks::attack3_placeholder_analysis();
    t.row(&["cross-session placeholder collision (Attack 3)".into(), a3.details]);
    vec![t]
}

/// E10 — §IX.C hysteresis: flap counts with/without the dead zone.
pub fn e10_hysteresis() -> Vec<Table> {
    let mut t = Table::new(
        "E10 / §IX.C — hysteresis dead zone vs route flapping",
        &["variant", "load pattern", "transitions / 1000 samples"],
    );
    for (name, mid, amp) in [("inside dead zone", 0.75, 0.03), ("spanning thresholds", 0.75, 0.10), ("heavy swings", 0.75, 0.25)] {
        // sample the oscillation off-phase (every 37 ms against a 100 ms
        // period) so the sampler sees the full swing
        let program = LoadProgram::oscillating(1.0 - mid, amp, 100.0, 60_000.0);
        let mut with = Hysteresis::new(0.70, 0.80);
        let mut without = Hysteresis::without_dead_zone(0.75);
        for i in 0..1000 {
            let cap = 1.0 - program.at((i as f64 * 37.0) % 60_000.0);
            with.observe(cap);
            without.observe(cap);
        }
        t.row(&[format!("with dead zone — {name}"), format!("R = {mid}±{amp}"), with.transitions().to_string()]);
        t.row(&[format!("no dead zone  — {name}"), format!("R = {mid}±{amp}"), without.transitions().to_string()]);
    }
    vec![t]
}

/// E11 — §III.F data locality: compute-to-data vs data-to-compute.
pub fn e11_locality() -> Vec<Table> {
    let mut t = Table::new(
        "E11 / §III.F — compute-to-data vs data-to-compute (legal RAG, scaled corpus)",
        &["strategy", "bytes moved / query (KB)", "mean latency ms", "$ / 1k queries", "privacy"],
    );
    let corpus_kb = 50_000.0; // scaled stand-in for the paper's 10TB store
    let n = 500;
    let trace = trace::rag_trace(n, "case_law", 111);
    let mut net = NetSim::new(111);

    // Strategy A: IslandRun — route query to the firm server (data stays)
    let fleet = Fleet::new(preset_legal(), 112);
    let mut lat_a = Vec::new();
    let mut bytes_a = 0.0;
    for item in &trace {
        fleet.advance(50.0);
        let rep = fleet.execute(crate::types::IslandId(1), &item.request).unwrap();
        lat_a.push(rep.latency_ms);
        bytes_a += rep.payload_kb;
    }
    t.row(&[
        "compute-to-data (islandrun)".into(),
        f(bytes_a / n as f64, 2),
        f(crate::util::stats::mean(&lat_a), 1),
        "$1.00 (edge fixed)".into(),
        "documents never leave firm".into(),
    ]);

    // Strategy B: cloud upload — per query, ship relevant corpus shard (1%)
    let fleet_b = Fleet::new(preset_legal(), 113);
    let mut lat_b = Vec::new();
    let mut bytes_b = 0.0;
    for item in &trace {
        fleet_b.advance(50.0);
        let shard_kb = corpus_kb * 0.01;
        let upload_ms = net.bulk_transfer_ms(LinkKind::Wan, shard_kb);
        let rep = fleet_b.execute(crate::types::IslandId(2), &item.request).unwrap();
        lat_b.push(rep.latency_ms + upload_ms);
        bytes_b += shard_kb + rep.payload_kb;
    }
    t.row(&[
        "data-to-compute (cloud upload)".into(),
        f(bytes_b / n as f64, 2),
        f(crate::util::stats::mean(&lat_b), 1),
        "$20.00 (API)".into(),
        "privileged docs on cloud".into(),
    ]);
    vec![t]
}

/// E12 — §VIII.C attack drill.
pub fn e12_attacks() -> Vec<Table> {
    let mut t = Table::new("E12 / §VIII.C — attack drill", &["attack", "mitigated", "details"]);
    for o in security::run_all() {
        t.row(&[o.name.to_string(), if o.mitigated { "yes".into() } else { "NO".into() }, o.details]);
    }
    vec![t]
}

/// E13 — island churn under load: islands crash/revive/leave/rejoin while
/// 8 worker threads submit; every admitted request must end in exactly one
/// audited outcome (served, failover-success, or exhausted-retries reject)
/// and the ledger must equal the sum of per-outcome costs.
pub fn e13_churn() -> Vec<Table> {
    use crate::eval::loadgen::{run_closed_loop_churn, Churn};
    use std::sync::Arc;

    let mut t = Table::new(
        "E13 — dynamic fleet membership: churn under concurrent load (8 workers x 150 reqs)",
        &["churn (crash/revive per step)", "served", "failover wins", "rejected", "failovers", "crashes", "lossless"],
    );
    for (label, churn) in [
        ("none", None),
        ("mild (0.1 / 0.8)", Some(Churn { crash_prob: 0.1, revive_prob: 0.8, ..Churn::default() })),
        ("harsh (0.4 / 0.4)", Some(Churn { crash_prob: 0.4, revive_prob: 0.4, ..Churn::default() })),
    ] {
        let mut cfg = Config::default();
        cfg.rate_limit_rps = 1e9;
        cfg.budget_ceiling = 1e9;
        let fleet = Fleet::new(preset_personal_group(), 131);
        let orch = Arc::new(Orchestrator::new(cfg, Mist::heuristic(), Backend::Sim(fleet), 131));
        let (report, churn_stats) = run_closed_loop_churn(&orch, 8, 150, 131, churn);
        let lossless = report.errors == 0
            && report.outcomes.len() == report.attempted
            && orch.audit.len() == report.attempted;
        t.row(&[
            label.to_string(),
            report.served().to_string(),
            orch.metrics.counter_value("failover_successes").to_string(),
            report.rejected().to_string(),
            orch.metrics.counter_value("failovers").to_string(),
            churn_stats.crashes.to_string(),
            if lossless { "yes".into() } else { "NO".into() },
        ]);
    }
    vec![t]
}

/// Dispatch one experiment by id ("e1".."e13").
pub fn run(name: &str) -> Option<Vec<Table>> {
    match name {
        "e1" => Some(e1_feature_matrix()),
        "e2" => Some(e2_privacy()),
        "e3" => Some(e3_cost()),
        "e4" => Some(e4_latency()),
        "e5" => Some(e5_tiers()),
        "e6" => Some(e6_ablation()),
        "e7" => Some(e7_routing_latency()),
        "e8" => Some(e8_motivating()),
        "e9" => Some(e9_sanitization()),
        "e10" => Some(e10_hysteresis()),
        "e11" => Some(e11_locality()),
        "e12" => Some(e12_attacks()),
        "e13" => Some(e13_churn()),
        _ => None,
    }
}

/// All experiment ids in order.
pub const ALL: [&str; 13] =
    ["e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11", "e12", "e13"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_covers_all_ids() {
        for id in ALL {
            assert!(run(id).is_some(), "{id}");
        }
        assert!(run("e99").is_none());
    }

    #[test]
    fn e8_motivating_example_routes_as_paper_describes() {
        let tables = e8_motivating();
        let text = tables[0].render();
        // turn 1 must land on a P=1 island that is not the busy laptop
        assert!(text.contains("(P=1)"), "{text}");
        assert!(!text.contains("laptop (P=1)"), "{text}");
    }

    #[test]
    fn e10_dead_zone_strictly_fewer_flaps() {
        let t = e10_hysteresis().remove(0);
        let rendered = t.render();
        assert!(rendered.contains("with dead zone"));
    }

    #[test]
    fn e12_all_mitigated() {
        let t = e12_attacks().remove(0);
        assert!(!t.render().contains("| NO "));
    }

    #[test]
    fn e13_churn_is_lossless() {
        let t = e13_churn().remove(0);
        assert!(!t.render().contains("| NO "), "{}", t.render());
    }
}
